"""Docs CI gate (ISSUE 2 satellite, extended by ISSUE 3): three checks over
the repo's markdown.

1. **Internal links resolve** — every relative `[text](path)` target in the
   checked files must exist (anchors are stripped; external schemes are
   skipped).
2. **Quickstart commands run as written** — every fenced code block
   immediately preceded by an `<!-- ci:run -->` marker is executed line by
   line with the repo root as cwd. A failing command fails the job, so the
   README cannot drift from the code.
3. **Launcher flags match the operator guide** — the `--flags` documented in
   docs/OPERATOR.md's "Launcher flags" section are diffed against
   `repro.launch.serve.build_parser()`. Drift in *either* direction fails:
   a flag added to the code must be documented, a flag documented must
   exist.
4. **Report schema matches the design doc** (ISSUE 6 satellite) — the field
   rows in DESIGN.md's "Report schema" table are diffed against
   `ServeReport.SUMMARY_FIELDS`. A summary field added to the code must be
   documented and vice versa.

Usage:  python tools/check_docs.py [--no-run] [--no-flags] [--no-schema]
"""
from __future__ import annotations

import argparse
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = ["README.md", "DESIGN.md", "EXPERIMENTS.md", "docs/OPERATOR.md",
        "docs/SCHEDULING.md", "ROADMAP.md", "PAPER.md"]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
RUN_MARKER = "<!-- ci:run -->"
FLAGS_DOC = "docs/OPERATOR.md"
FLAGS_HEADING = "Launcher flags"
FLAG_RE = re.compile(r"`(--[a-z][a-z0-9-]*)`")
SCHEMA_DOC = "DESIGN.md"
SCHEMA_HEADING = "Report schema"
SCHEMA_ROW_RE = re.compile(r"^\|\s*`([a-z][a-z0-9_]*)`", re.MULTILINE)


def check_links() -> list:
    errors = []
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            errors.append(f"{doc}: file missing")
            continue
        for m in LINK_RE.finditer(path.read_text()):
            target = m.group(1).split("#")[0]
            if not target or "://" in target or target.startswith("mailto:"):
                continue
            resolved = (path.parent / target).resolve()
            if not resolved.exists():
                errors.append(f"{doc}: broken link -> {m.group(1)}")
    return errors


def run_blocks(doc: str = "README.md") -> list:
    """Execute every `<!-- ci:run -->`-marked fenced block in ``doc``."""
    text = (ROOT / doc).read_text()
    lines = text.splitlines()
    errors = []
    i = 0
    while i < len(lines):
        if lines[i].strip() == RUN_MARKER:
            j = i + 1
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            k = j + 1
            while k < len(lines) and not lines[k].startswith("```"):
                k += 1
            block = "\n".join(lines[j + 1:k])
            print(f"$ {block}", flush=True)
            proc = subprocess.run(["bash", "-euo", "pipefail", "-c", block],
                                  cwd=ROOT)
            if proc.returncode != 0:
                errors.append(f"{doc}: ci:run block at line {j + 1} exited "
                              f"{proc.returncode}")
            i = k
        i += 1
    return errors


def _flags_section(text: str) -> str:
    """The body of the '## … Launcher flags …' section (up to the next H2)."""
    lines = text.splitlines()
    start = next((i for i, ln in enumerate(lines)
                  if ln.startswith("## ") and FLAGS_HEADING in ln), None)
    if start is None:
        return ""
    end = next((i for i in range(start + 1, len(lines))
                if lines[i].startswith("## ")), len(lines))
    return "\n".join(lines[start:end])


def check_flags() -> list:
    """Diff documented launcher flags against the argparse surface."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.launch.serve import build_parser
    code = {opt for a in build_parser()._actions
            for opt in a.option_strings if opt.startswith("--")} - {"--help"}
    section = _flags_section((ROOT / FLAGS_DOC).read_text())
    if not section:
        return [f"{FLAGS_DOC}: no '## {FLAGS_HEADING}' section found "
                f"(the flag table is required — see tools/check_docs.py)"]
    documented = set(FLAG_RE.findall(section))
    errors = []
    for f in sorted(code - documented):
        errors.append(f"{FLAGS_DOC}: flag {f} exists in repro.launch.serve "
                      f"but is missing from the '{FLAGS_HEADING}' table")
    for f in sorted(documented - code):
        errors.append(f"{FLAGS_DOC}: flag {f} is documented in the "
                      f"'{FLAGS_HEADING}' table but repro.launch.serve does "
                      f"not define it")
    return errors


def _heading_section(text: str, heading: str) -> str:
    """The body of the first heading (any level) containing ``heading``,
    up to the next heading of the same or higher level."""
    lines = text.splitlines()
    start = level = None
    for i, ln in enumerate(lines):
        m = re.match(r"(#{2,6}) ", ln)
        if m and heading in ln:
            start, level = i, len(m.group(1))
            break
    if start is None:
        return ""
    end = next((i for i in range(start + 1, len(lines))
                if re.match(r"#{2,%d} " % level, lines[i])), len(lines))
    return "\n".join(lines[start:end])


def check_report_schema() -> list:
    """Diff DESIGN.md's report-schema table against ServeReport's summary
    field list — the report line operators grep must be documented."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.core.serving import ServeReport
    code = set(ServeReport.SUMMARY_FIELDS)
    section = _heading_section((ROOT / SCHEMA_DOC).read_text(), SCHEMA_HEADING)
    if not section:
        return [f"{SCHEMA_DOC}: no '{SCHEMA_HEADING}' section found "
                f"(the summary-field table is required — see "
                f"tools/check_docs.py)"]
    documented = set(SCHEMA_ROW_RE.findall(section))
    errors = []
    for f in sorted(code - documented):
        errors.append(f"{SCHEMA_DOC}: summary field '{f}' exists in "
                      f"ServeReport.SUMMARY_FIELDS but is missing from the "
                      f"'{SCHEMA_HEADING}' table")
    for f in sorted(documented - code):
        errors.append(f"{SCHEMA_DOC}: summary field '{f}' is documented in "
                      f"the '{SCHEMA_HEADING}' table but ServeReport does "
                      f"not emit it")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-run", action="store_true",
                    help="only check links/flags; skip executing ci:run "
                         "blocks")
    ap.add_argument("--no-flags", action="store_true",
                    help="skip the launcher-flag drift check")
    ap.add_argument("--no-schema", action="store_true",
                    help="skip the report-schema drift check")
    args = ap.parse_args(argv)
    errors = check_links()
    if errors:
        for e in errors:
            print(f"FAIL {e}", file=sys.stderr)
        return 1
    print("links: OK")
    if not args.no_flags:
        errors = check_flags()
        if errors:
            for e in errors:
                print(f"FAIL {e}", file=sys.stderr)
            return 1
        print("launcher flags: OK")
    if not args.no_schema:
        errors = check_report_schema()
        if errors:
            for e in errors:
                print(f"FAIL {e}", file=sys.stderr)
            return 1
        print("report schema: OK")
    if not args.no_run:
        errors = run_blocks()
        if errors:
            for e in errors:
                print(f"FAIL {e}", file=sys.stderr)
            return 1
        print("ci:run blocks: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
