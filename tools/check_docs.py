"""Docs CI gate (ISSUE 2 satellite): two checks over the repo's markdown.

1. **Internal links resolve** — every relative `[text](path)` target in the
   checked files must exist (anchors are stripped; external schemes are
   skipped).
2. **Quickstart commands run as written** — every fenced code block
   immediately preceded by an `<!-- ci:run -->` marker is executed line by
   line with the repo root as cwd. A failing command fails the job, so the
   README cannot drift from the code.

Usage:  python tools/check_docs.py [--no-run]
"""
from __future__ import annotations

import argparse
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = ["README.md", "DESIGN.md", "docs/OPERATOR.md", "ROADMAP.md",
        "PAPER.md"]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
RUN_MARKER = "<!-- ci:run -->"


def check_links() -> list:
    errors = []
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            errors.append(f"{doc}: file missing")
            continue
        for m in LINK_RE.finditer(path.read_text()):
            target = m.group(1).split("#")[0]
            if not target or "://" in target or target.startswith("mailto:"):
                continue
            resolved = (path.parent / target).resolve()
            if not resolved.exists():
                errors.append(f"{doc}: broken link -> {m.group(1)}")
    return errors


def run_blocks(doc: str = "README.md") -> list:
    """Execute every `<!-- ci:run -->`-marked fenced block in ``doc``."""
    text = (ROOT / doc).read_text()
    lines = text.splitlines()
    errors = []
    i = 0
    while i < len(lines):
        if lines[i].strip() == RUN_MARKER:
            j = i + 1
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            k = j + 1
            while k < len(lines) and not lines[k].startswith("```"):
                k += 1
            block = "\n".join(lines[j + 1:k])
            print(f"$ {block}", flush=True)
            proc = subprocess.run(["bash", "-euo", "pipefail", "-c", block],
                                  cwd=ROOT)
            if proc.returncode != 0:
                errors.append(f"{doc}: ci:run block at line {j + 1} exited "
                              f"{proc.returncode}")
            i = k
        i += 1
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-run", action="store_true",
                    help="only check links; skip executing ci:run blocks")
    args = ap.parse_args(argv)
    errors = check_links()
    if errors:
        for e in errors:
            print(f"FAIL {e}", file=sys.stderr)
        return 1
    print("links: OK")
    if not args.no_run:
        errors = run_blocks()
        if errors:
            for e in errors:
                print(f"FAIL {e}", file=sys.stderr)
            return 1
        print("ci:run blocks: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
