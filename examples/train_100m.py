"""Train a ~100M-parameter qwen3-family model for a few hundred steps on the
synthetic pipeline (deliverable b: end-to-end training driver).

The default is CPU-sized ("--full-100m" selects the true ~100M config; a few
hundred steps of that is a several-hour CPU run — the assertion logic is
identical either way: loss must fall).

Run:  PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse

from repro.configs import get_config
from repro.launch import train as train_launcher


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_100m.msgpack")
    args = ap.parse_args()

    if args.full_100m:
        # ~100M: qwen3 geometry shrunk to 12L x 768
        cfg = get_config("qwen3-1.7b").replace(
            arch_id="qwen3-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
            dtype="float32")
        train_launcher.main(["--steps", str(args.steps), "--batch", "4",
                             "--seq", "512", "--ckpt", args.ckpt],
                            cfg_override=cfg)
    else:
        train_launcher.main(["--arch", "qwen3-1.7b", "--smoke", "--steps",
                             str(args.steps), "--batch", "8", "--seq", "128",
                             "--ckpt", args.ckpt])


if __name__ == "__main__":
    main()
