"""Cluster-scale what-if: replay a production-style trace against an 8-instance
TPU v5e cluster under every scheduling policy and print the Fig.7-style table.
Uses the unified ServingSystem API (replay_trace + drain), i.e. exactly the
same request/trace/reporting path as the real-compute engine.

Run:  PYTHONPATH=src python examples/simulate_cluster.py --trace azure_code
"""
import argparse

from repro.configs import get_config
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

ap = argparse.ArgumentParser()
ap.add_argument("--trace", default="azure_code", choices=list(TRACE_PRESETS))
ap.add_argument("--arch", default="gemma-2b")
ap.add_argument("--rates", nargs="*", type=float,
                default=[4.0, 8.0, 16.0, 24.0, 32.0])
ap.add_argument("--duration", type=float, default=120.0)
args = ap.parse_args()

cfg = get_config(args.arch)
p = TRACE_PRESETS[args.trace]
slo = SLO(p.slo_ttft, p.slo_tpot)
policies = ["arrow", "minimal_load", "round_robin", "colocated"]

print(f"trace={args.trace} arch={args.arch} SLO(ttft={slo.ttft}s, "
      f"tpot={slo.tpot}s) 8 instances x 4 chips")
hdr = f"{'rate':>6} {'req/s':>7} " + " ".join(f"{pol:>13}" for pol in policies)
print(hdr)
for rate in args.rates:
    trace = load_trace(args.trace, rate_scale=rate, seed=0,
                       duration=args.duration)
    row = f"x{rate:<5} {len(trace)/args.duration:7.2f} "
    for pol in policies:
        sim = Simulator(cfg, n_instances=8, n_prefill=4, policy=pol, slo=slo)
        replay_trace(sim, trace)
        report = sim.drain()
        row += f" {report.attainment:12.3f}"
    print(row)
print("\n(attainment >= 0.90 = inside SLO target; arrow column should stay "
      "high the longest)")
