"""Quickstart: the public API in ~60 lines.

1. Build a model from the architecture registry (reduced config, CPU-sized).
2. Train it a few steps on the synthetic pipeline.
3. Serve two requests through the Arrow scheduler on a 2-instance cluster,
   watching a KV-cache transfer happen between stateless instances.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.slo import SLO
from repro.engine import ArrowEngineCluster, ServeRequest
from repro.models import build_model
from repro.optim import adamw_init, adamw_update

# ---------------------------------------------------------------- 1. model
cfg = get_smoke_config("qwen3-1.7b")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"arch={cfg.arch_id} layers={cfg.n_layers} d_model={cfg.d_model}")

# ---------------------------------------------------------------- 2. train
from repro.data import SyntheticTokenPipeline

pipe = iter(SyntheticTokenPipeline(cfg.vocab_size, seq_len=64, batch_size=4))
opt = adamw_init(params)


@jax.jit
def step(params, opt, batch):
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    params, opt = adamw_update(params, grads, opt, lr=1e-3)
    return params, opt, loss


for i in range(5):
    import jax.numpy as jnp
    batch = {"tokens": jnp.asarray(next(pipe)["tokens"])}
    params, opt, loss = step(params, opt, batch)
    print(f"  train step {i}: loss={float(loss):.4f}")

# ---------------------------------------------------------------- 3. serve
cluster = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(ttft=5.0, tpot=2.0),
                             params=params)
rng = np.random.default_rng(0)
reqs = [ServeRequest(rid=i, prompt=rng.integers(1, cfg.vocab_size, 24).astype(np.int32),
                     max_new_tokens=4) for i in range(2)]
out = cluster.serve(reqs, timeout=60.0)
for sr in out:
    print(f"  request {sr.rid}: prefill@inst{sr.req.prefill_instance} -> "
          f"decode@inst{sr.req.decode_instance}  tokens={sr.output_tokens}  "
          f"ttft={sr.req.ttft*1e3:.0f}ms")
print("done.")
