"""End-to-end serving driver (deliverable b): a burst of requests streams
through the unified ServingSystem API into a 3-instance Arrow cluster with
real JAX compute. The burst forces the SLO-aware scheduler to flip a decode
instance into the prefill pool (Algorithm 1 + 3) — we print the pool timeline
to make the elastic pools visible, and tokens are observed as they land
(per-request on_token callbacks), so TTFT here is measured at the stream, not
reconstructed afterwards.

Run:  PYTHONPATH=src python examples/serve_arrow.py
"""
import numpy as np

from repro.configs import get_smoke_config
from repro.core import Request
from repro.core.pools import Pool
from repro.core.slo import SLO, SchedulerConfig
from repro.engine import ArrowEngineCluster

cfg = get_smoke_config("gemma-2b")
# NB: one process emulates 3 instances cooperatively, so wall-clock latency is
# ~3x a real deployment; the TTFT SLO below is tight against *predicted*
# per-instance compute, which is what Algorithm 1 schedules on.
cluster = ArrowEngineCluster(
    cfg, n_instances=3, n_prefill=1, n_slots=8, capacity=192,
    slo=SLO(ttft=0.08, tpot=5.0), chunk_tokens=64,   # §5.4 chunked prefill
    sched_cfg=SchedulerConfig(max_running_tokens=1536, monitor_interval=0.05))

# pool-timeline instrumentation
timeline = []
orig_tick = cluster.policy.on_monitor_tick


def tick(now):
    orig_tick(now)
    timeline.append((now, {p.value: cluster.pools.members(p)
                           for p in Pool if cluster.pools.members(p)}))


cluster.policy.on_monitor_tick = tick

# streaming observation: first-token latencies as the tokens actually land
first_seen = {}


def on_token(handle, tok, t):
    if handle.rid not in first_seen:
        first_seen[handle.rid] = t - handle.req.arrival


rng = np.random.default_rng(1)
handles = []
for i in range(18):
    # burst: first 12 arrive nearly together with long-ish prompts; the burst
    # is submitted as 'interactive' (tight SLO tier), the tail as 'standard'
    offset = 0.01 * i if i < 12 else 0.4 + 0.05 * i
    prompt = rng.integers(1, cfg.vocab_size,
                          size=int(rng.integers(48, 160))).astype(np.int32)
    req = Request(rid=i, arrival=offset, input_len=len(prompt),
                  output_len=int(rng.integers(2, 8)))
    handles.append(cluster.submit(
        req, prompt=prompt, tier="interactive" if i < 12 else "standard",
        on_token=on_token))

report = cluster.drain(timeout=240.0)

print(report.summary())
print("attainment by tier: " +
      " ".join(f"{k}={v:.2f}" for k, v in report.attainment_by_tier().items()))
print(f"pool flips: {report.flip_detail['total']} "
      f"(D->P {report.flip_detail['d2p']}, P->D {report.flip_detail['p2d']})")
migrated = sum(1 for h in handles
               if h.req.decode_instance not in (None, h.req.prefill_instance))
print(f"KV transfers between instances: {migrated}")
streamed = sorted(first_seen.values())
p50 = f"{streamed[len(streamed) // 2] * 1e3:.0f}ms" if streamed else "n/a"
print(f"TTFT observed at the stream: p50={p50}")
print("\npool timeline (sampled):")
for t, pools in timeline[:: max(len(timeline) // 12, 1)]:
    print(f"  t={t:5.2f}s  " + "  ".join(f"{k}:{v}" for k, v in pools.items()))
