"""End-to-end serving driver (deliverable b): a burst of batched requests hits
a 3-instance Arrow cluster with real JAX compute. The burst forces the
SLO-aware scheduler to flip a decode instance into the prefill pool
(Algorithm 1 + 3) — we print the pool timeline to make the elastic pools
visible.

Run:  PYTHONPATH=src python examples/serve_arrow.py
"""
import numpy as np

from repro.configs import get_smoke_config
from repro.core.pools import Pool
from repro.core.slo import SLO, SchedulerConfig
from repro.engine import ArrowEngineCluster, ServeRequest

cfg = get_smoke_config("gemma-2b")
# NB: one process emulates 3 instances cooperatively, so wall-clock latency is
# ~3x a real deployment; the TTFT SLO below is tight against *predicted*
# per-instance compute, which is what Algorithm 1 schedules on.
cluster = ArrowEngineCluster(
    cfg, n_instances=3, n_prefill=1, n_slots=8, capacity=192,
    slo=SLO(ttft=0.08, tpot=5.0), chunk_tokens=64,   # §5.4 chunked prefill
    sched_cfg=SchedulerConfig(max_running_tokens=1536, monitor_interval=0.05))

# pool-timeline instrumentation
timeline = []
orig_tick = cluster.gs.on_monitor_tick


def tick(now):
    orig_tick(now)
    timeline.append((now, {p.value: cluster.pools.members(p)
                           for p in Pool if cluster.pools.members(p)}))


cluster.gs.on_monitor_tick = tick

rng = np.random.default_rng(1)
reqs = []
for i in range(18):
    # burst: first 12 arrive nearly together with long-ish prompts
    offset = 0.01 * i if i < 12 else 0.4 + 0.05 * i
    reqs.append(ServeRequest(
        rid=i,
        prompt=rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(48, 160))).astype(np.int32),
        max_new_tokens=int(rng.integers(2, 8)),
        arrival_offset=offset))

out = cluster.serve(reqs, timeout=240.0)

done = [r for r in out if r.req and r.req.finish_time is not None]
print(f"finished {len(done)}/{len(out)} requests; "
      f"pool flips: {cluster.pools.flips} "
      f"(D->P {cluster.gs.n_d2p_flips}, P->D {cluster.gs.n_p2d_flips})")
ttfts = sorted(r.req.ttft for r in done)
print(f"TTFT p50={ttfts[len(ttfts)//2]*1e3:.0f}ms p90="
      f"{ttfts[int(len(ttfts)*0.9)]*1e3:.0f}ms")
migrated = sum(1 for r in done
               if r.req.decode_instance not in (None, r.req.prefill_instance))
print(f"KV transfers between instances: {migrated}")
print("\npool timeline (sampled):")
for t, pools in timeline[:: max(len(timeline) // 12, 1)]:
    print(f"  t={t:5.2f}s  " + "  ".join(f"{k}:{v}" for k, v in pools.items()))
