"""Paper Fig. 4 (Insight 5): prefill vs decode load over time under a rising
burst — prefill peaks earlier than decode."""
from __future__ import annotations

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.request import Request
from repro.core.serving import replay_trace
from repro.core.slo import SLO, SchedulerConfig
from repro.sim import Simulator


def main() -> None:
    cfg = get_config("gemma-2b")
    burst = [Request(rid=i, arrival=0.02 * i, input_len=16384, output_len=400)
             for i in range(64)]
    sim = Simulator(cfg, n_instances=8, n_prefill=4, policy="minimal_load",
                    slo=SLO(2.0, 0.15),
                    sched_cfg=SchedulerConfig(monitor_interval=0.05))
    series = []
    orig = sim.policy.on_monitor_tick

    def tick(now):
        orig(now)
        series.append({
            "t": now,
            "prefill_queued": sum(len(sim.locals[i].prefill_queue)
                                  for i in range(8)),
            "decode_running": sum(len(sim.locals[i].decode_running)
                                  for i in range(8)),
        })

    sim.policy.on_monitor_tick = tick
    with Timer() as t:
        replay_trace(sim, burst)
        sim.drain()
    tp = max(series, key=lambda s: s["prefill_queued"])["t"]
    td = max(series, key=lambda s: s["decode_running"])["t"]
    emit("load_difference", t.us,
         f"prefill_peak_t={tp:.2f}s;decode_peak_t={td:.2f}s;lead={td - tp:.2f}s")
    save_json("load_difference", {"series": series, "prefill_peak": tp,
                                  "decode_peak": td})


if __name__ == "__main__":
    main()
