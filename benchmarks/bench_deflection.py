"""Cross-pool prefill deflection study (DESIGN.md §11): ``arrow_deflect``
vs flip-only ``arrow_elastic`` on the spike trace.

Both systems share the same AutoScaler bounds and replay the identical
trace. The question is what happens *during the ramp*: a flip or a WARMING
spawn takes seconds, while deflection routes bounded prefill chunks onto
decode instances within the very next fused step. Reported per rate point:

  * goodput          — SLO-attaining requests per second of trace time
  * attainment       — fraction of requests finishing inside the SLO
  * ramp_p90_ttft    — p90 TTFT over requests arriving inside the spike
                       window (the paper's pain interval)
  * deflected/refused — DeflectionPolicy accounting (refusals by reason
                       are in results/deflection.json)

The run *asserts* the §11 headline on every point: deflection's goodput is
never below flip-only, its ramp p90 TTFT is strictly lower, and the
ratio=0 control run is byte-identical to ``arrow_elastic`` (same summary
line, decisions, and flips) — deflection off is exactly the old system.

CSV contract: name,us_per_call,derived. Full curves go to
results/deflection.json.

  PYTHONPATH=src python benchmarks/bench_deflection.py
  PYTHONPATH=src python benchmarks/bench_deflection.py --smoke   # CI docs job
"""
from __future__ import annotations

import argparse
import math
import pathlib
import sys

if __package__ in (None, ""):     # `python benchmarks/bench_deflection.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.autoscaler import AutoScalerConfig
from repro.core.global_scheduler import DeflectionConfig
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

SCALER = dict(n_instances=4, n_prefill=2,
              autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                              max_instances=12))

SYSTEMS = {
    "arrow_elastic": dict(policy="arrow_elastic", **SCALER),
    "arrow_deflect": dict(policy="arrow_deflect", **SCALER),
}

RATES = [8.0, 10.0, 12.0]


def ramp_p90_ttft(report, trace_name: str):
    """p90 TTFT (nearest-rank) over requests arriving inside the trace's
    spike window — the interval where flip-only rebalancing lags."""
    lo, hi = TRACE_PRESETS[trace_name].spike_window
    span = max((h.req.arrival for h in report.handles), default=0.0)
    vals = sorted(h.ttft for h in report.handles
                  if h.ttft is not None
                  and lo * span <= h.req.arrival < hi * span)
    if not vals:
        return None
    return vals[min(max(math.ceil(0.9 * len(vals)), 1), len(vals)) - 1]


def run_point(cfg, trace_name: str, sys_name: str, rate: float,
              duration=None, **extra):
    p = TRACE_PRESETS[trace_name]
    trace = load_trace(trace_name, rate_scale=rate, seed=0, duration=duration)
    sim = Simulator(cfg, slo=SLO(p.slo_ttft, p.slo_tpot),
                    **SYSTEMS[sys_name], **extra)
    replay_trace(sim, trace)
    report = sim.drain()
    span = max(report.duration, 1e-9)
    good = sum(1 for h in report.handles if h.meets_slo())
    return {
        "rate_scale": rate,
        "attainment": report.attainment,
        "goodput_req_s": good / span,
        "ramp_p90_ttft": ramp_p90_ttft(report, trace_name),
        "deflected": report.deflection.get("requests_deflected", 0),
        "refused": sum(v for k, v in report.deflection.items()
                       if k.startswith("refused_")),
        "deflection": dict(report.deflection),
        "summary": report.summary(),
        "decisions": dict(report.decisions),
        "flips": report.flips,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--trace", default="spike")
    ap.add_argument("--rates", nargs="*", type=float, default=RATES)
    ap.add_argument("--duration", type=float, default=60.0,
                    help="trace duration (seconds at scale 1.0)")
    ap.add_argument("--smoke", action="store_true",
                    help="single fast point (CI docs job)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rates = [10.0]
        args.duration = min(args.duration, 60.0)

    cfg = get_config(args.arch)
    out = {}
    for sys_name in SYSTEMS:
        curve = []
        with Timer() as t:
            for rate in args.rates:
                curve.append(run_point(cfg, args.trace, sys_name, rate,
                                       duration=args.duration))
        out[sys_name] = curve
        for pt in curve:
            ramp = pt["ramp_p90_ttft"]
            emit(f"deflection.{args.trace}.{sys_name}.x{pt['rate_scale']:g}",
                 t.us / len(curve),
                 f"attainment={pt['attainment']:.3f};"
                 f"goodput={pt['goodput_req_s']:.2f}req/s;"
                 f"ramp_p90_ttft={'n/a' if ramp is None else f'{ramp:.3f}s'};"
                 f"deflected={pt['deflected']};refused={pt['refused']}")

    # ---- §11 headline assertions (ISSUE 7 acceptance criteria)
    for e, d in zip(out["arrow_elastic"], out["arrow_deflect"]):
        rate = e["rate_scale"]
        assert d["goodput_req_s"] >= e["goodput_req_s"] - 1e-9, (
            f"x{rate:g}: deflection goodput {d['goodput_req_s']:.3f} req/s "
            f"below flip-only {e['goodput_req_s']:.3f} req/s")
        assert d["ramp_p90_ttft"] < e["ramp_p90_ttft"], (
            f"x{rate:g}: deflection ramp p90 TTFT {d['ramp_p90_ttft']:.3f}s "
            f"not strictly below flip-only {e['ramp_p90_ttft']:.3f}s")
        gain = 1.0 - d["ramp_p90_ttft"] / e["ramp_p90_ttft"]
        emit(f"deflection.{args.trace}.ramp_gain.x{rate:g}", 0.0,
             f"ramp_p90_ttft_cut={gain:.0%};"
             f"goodput_delta={d['goodput_req_s'] - e['goodput_req_s']:+.2f}"
             f"req/s")

    # ---- ratio=0 control: deflection disarmed is *byte-identical* to
    # arrow_elastic (same scheduler decisions, flips, and summary line)
    rate = args.rates[0]
    ctl = run_point(cfg, args.trace, "arrow_deflect", rate,
                    duration=args.duration,
                    deflection=DeflectionConfig(ratio=0.0))
    ref = out["arrow_elastic"][0]
    assert not ctl["deflection"], (
        f"ratio=0 control still reports deflection: {ctl['deflection']}")
    for key in ("summary", "decisions", "flips"):
        assert ctl[key] == ref[key], (
            f"ratio=0 control diverges from arrow_elastic on {key}: "
            f"{ctl[key]!r} != {ref[key]!r}")
    emit(f"deflection.{args.trace}.control.x{rate:g}", 0.0,
         "ratio0_byte_identical=True")

    if not args.smoke:
        save_json("deflection", out)


if __name__ == "__main__":
    main()
