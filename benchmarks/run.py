"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.

  Fig.1/2  bench_trace_stats       workload diversity of synthesized traces
  Fig.4    bench_load_difference   prefill load leads decode load
  Fig.7    bench_e2e               Arrow vs vLLM / vLLM-disagg / DistServe
  Fig.8    bench_ablation          SLO-aware vs minimal-load vs round-robin
  Fig.9    bench_scalability       attainment vs instance count
  (ours)   bench_elastic           elastic vs static provisioning (DESIGN §6)
  (ours)   bench_deflection        cross-pool prefill deflection vs flip-only (DESIGN §11)
  (ours)   bench_prefix            prefix-aware KV reuse on multi-turn (DESIGN §7)
  (ours)   bench_faults            goodput under crashes vs no-recovery (DESIGN §8)
  (ours)   bench_chaos             self-healing vs detection-off under chaos (DESIGN §14)
  (ours)   bench_engine_step       fused+donated engine step vs per-rid path (DESIGN §9)
  (ours)   bench_speculative       self-speculative decode vs sequential (DESIGN §12)
  (ours)   bench_ssm               SSM/recurrent decode-state serving economics (DESIGN §13)
  (ours)   bench_tenants           credit admission vs FIFO under a flooder (DESIGN §10)
  (ours)   bench_kernels           Pallas kernels (interpret) vs jnp oracle
  (ours)   roofline                terms from the dry-run records, if present
"""
from __future__ import annotations

import os
import sys


def main() -> None:
    fast = os.environ.get("BENCH_FAST", "")
    duration = "60" if fast else "120"

    from benchmarks import (bench_ablation, bench_chaos, bench_deflection,
                            bench_e2e, bench_elastic, bench_engine_step,
                            bench_faults, bench_flip_latency, bench_kernels,
                            bench_load_difference, bench_prefix,
                            bench_scalability, bench_speculative,
                            bench_ssm, bench_tenants, bench_trace_stats)
    print("name,us_per_call,derived")
    bench_trace_stats.main()
    bench_load_difference.main()
    bench_e2e.main(["--duration", duration])
    bench_ablation.main(["--duration", duration])
    bench_scalability.main(["--duration", duration])
    bench_flip_latency.main(["--duration", duration])
    bench_elastic.main(["--duration", duration])
    bench_deflection.main(["--duration", duration])
    bench_prefix.main(["--duration", duration])
    bench_faults.main([])
    bench_chaos.main(["--smoke"] if fast else [])
    # needs its full 120 s window: the FIFO collapse the headline asserts
    # takes that long to build, so BENCH_FAST does not shorten it
    bench_tenants.main([])
    bench_engine_step.main([])
    bench_speculative.main(["--smoke"] if fast else [])
    bench_ssm.main(["--smoke"] if fast else [])
    bench_kernels.main()
    try:
        from benchmarks import roofline
        roofline.main([])
    except Exception as e:  # noqa: BLE001 — dry-run records may be absent
        print(f"roofline,0,skipped({type(e).__name__})", file=sys.stderr)


if __name__ == "__main__":
    main()
