"""Paper Fig. 1 / Fig. 2 + §3.1: workload diversity statistics of the four
synthesized traces vs the published targets."""
from __future__ import annotations

from benchmarks.common import Timer, emit, save_json
from repro.traces import TRACE_PRESETS, load_trace, trace_stats

# published targets (paper §3.1)
TARGETS = {
    "azure_code": {"input_cv_per_min": 0.80, "in_out_corr": 0.95},
    "azure_conv": {"in_out_corr": 0.29},
    "burstgpt": {"input_cv_per_min": 1.11},
    "mooncake": {"input_cv_per_min": 0.16},
}


def main() -> None:
    out = {}
    for name in TRACE_PRESETS:
        with Timer() as t:
            tr = load_trace(name, seed=0)
            s = trace_stats(tr)
        out[name] = {"stats": s, "targets": TARGETS.get(name, {})}
        derived = (f"cv={s['input_cv_per_min']:.2f};r={s['in_out_corr']:.2f};"
                   f"med_in={s['input_median']:.0f};n={s['n_requests']}")
        emit(f"trace_stats.{name}", t.us / max(len(tr), 1), derived)
    save_json("trace_stats", out)


if __name__ == "__main__":
    main()
