"""Paper Fig. 9: SLO attainment of SLO-Aware vs Minimal-Load under varying
instance counts, scaled to cluster sizes where scheduler *host* overhead
becomes the story (ISSUE 8).

Two parts:

  * the Fig. 9 sweep — attainment for ``arrow`` vs ``minimal_load`` at
    2..64 instances over a shared trace;
  * a scheduler-overhead budget point — one 64-instance / 100k-request
    replay (``arrow``) asserting the host-side cost per scheduling decision
    stays within budget. The global scheduler is O(instances) per placement
    and the event loop O(log events) per token, so per-request overhead must
    stay flat as the cluster grows; a super-linear regression (e.g. an
    accidental O(instances) scan per *token*) blows the budget immediately.

Budgets are ~10x the measured baseline (≈220 us/request, ≈5.5 us/token on a
dev box) so only algorithmic regressions — not CI machine jitter — trip them.

``--smoke`` shrinks both parts for CI but keeps every assertion live.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

if __package__ in (None, ""):    # `python benchmarks/bench_scalability.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import InstanceProfile, Simulator
from repro.traces import TRACE_PRESETS, load_trace

# host-overhead ceilings for the budget point (see module docstring)
US_PER_REQUEST_BUDGET = 2000.0
US_PER_TOKEN_BUDGET = 50.0


def run_point(cfg, n: int, trace, slo: SLO, policy: str):
    with Timer() as t:
        sim = Simulator(cfg, n_instances=n, n_prefill=max(n // 2, 1),
                        policy=policy, slo=slo,
                        profile=InstanceProfile(chips=4))
        replay_trace(sim, trace)
        report = sim.drain()
    assert report.n_finished == len(trace), \
        f"scalability run dropped requests at n={n}"
    return report, t


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: 2-instance sweep + ~1.5k-request "
                         "overhead point; same assertions")
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    p = TRACE_PRESETS["azure_code"]
    slo = SLO(p.slo_ttft, p.slo_tpot)

    # ---------------------------------------------------- Fig. 9 sweep
    sweep_ns = (2, 4) if args.smoke else (2, 4, 8, 16, 32, 64)
    duration = 10.0 if args.smoke else args.duration
    trace = load_trace("azure_code", rate_scale=args.rate, seed=0,
                       duration=duration)
    out = {}
    for n in sweep_ns:
        out[n] = {}
        for strat in ("arrow", "minimal_load"):
            report, t = run_point(cfg, n, trace, slo, strat)
            out[n][strat] = report.attainment
            emit(f"scalability.n{n}.{strat}", t.us,
                 f"attainment={report.attainment:.3f}")

    # --------------------------------- scheduler-overhead budget point
    n_big = 8 if args.smoke else 64
    big_rate, big_dur = (150.0, 10.0) if args.smoke else (800.0, 100.0)
    big = load_trace("azure_code", rate_scale=big_rate, seed=0,
                     duration=big_dur)
    if not args.smoke:
        assert len(big) >= 100_000, \
            f"overhead trace too small: {len(big)} requests"
    report, t = run_point(cfg, n_big, big, slo, "arrow")
    tokens = sum(len(h.tokens) for h in report.handles)
    us_req = t.us / len(big)
    us_tok = t.us / max(tokens, 1)
    emit(f"scalability.overhead.n{n_big}", t.us,
         f"requests={len(big)} us_per_request={us_req:.1f} "
         f"us_per_token={us_tok:.2f}")
    assert us_req < US_PER_REQUEST_BUDGET, (
        f"scheduler host overhead {us_req:.0f} us/request exceeds the "
        f"{US_PER_REQUEST_BUDGET:.0f} us budget at {n_big} instances")
    assert us_tok < US_PER_TOKEN_BUDGET, (
        f"event-loop overhead {us_tok:.1f} us/token exceeds the "
        f"{US_PER_TOKEN_BUDGET:.0f} us budget at {n_big} instances")
    out["overhead"] = {"n": n_big, "requests": len(big),
                       "us_per_request": us_req, "us_per_token": us_tok}
    save_json("scalability", out)


if __name__ == "__main__":
    main()
