"""Paper Fig. 9: SLO attainment of SLO-Aware vs Minimal-Load under varying
instance counts (scalability)."""
from __future__ import annotations

import argparse

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.slo import SLO
from repro.sim import InstanceProfile, Simulator
from repro.traces import TRACE_PRESETS, load_trace


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--rate", type=float, default=16.0)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    p = TRACE_PRESETS["azure_code"]
    trace = load_trace("azure_code", rate_scale=args.rate, seed=0,
                       duration=args.duration)

    out = {}
    for n in (2, 4, 8, 16):
        out[n] = {}
        for strat in ("arrow", "minimal_load"):
            with Timer() as t:
                sim = Simulator(cfg, n_instances=n, n_prefill=max(n // 2, 1),
                                policy=strat, slo=SLO(p.slo_ttft, p.slo_tpot),
                                profile=InstanceProfile(chips=4))
                res = sim.run(trace)
            out[n][strat] = res.attainment
            emit(f"scalability.n{n}.{strat}", t.us,
                 f"attainment={res.attainment:.3f}")
    save_json("scalability", out)


if __name__ == "__main__":
    main()
