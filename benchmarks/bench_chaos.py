"""Self-healing chaos study (DESIGN.md §14): goodput under a combined
slowdown + dropped-transfer + crash storm, with the health layer on versus a
detection-off control.

Two deterministic simulator runs per rate point on the spike trace, both
under ``arrow_elastic`` with the *same* fault plan:

  * healing  — ``--health`` on: the straggler is quarantined and drained
               after ``sustain_s``, dropped transfers climb the retry
               ladder, and the memory gate may preempt (§14)
  * control  — detection off: the straggler keeps its residents for the
               whole slow window and every dropped transfer falls straight
               through to re-prefill recovery

Reported per point: attainment, goodput, quarantine/restore/retry counts,
and the healing:control goodput ratio. The headline asserts healing goodput
strictly above the control at every point, that at least one quarantine
fired and every quarantined instance returned to ACTIVE — the §14
self-healing loop, end to end, or the bench fails.

The engine leg replays a small chaos plan (transfer drops + netslow + a
crash) on the real cluster and asserts every stream — greedy *and*
seeded-sampled — is bit-identical to the fault-free sequential reference:
recovery and retries may change *when* tokens appear, never *which* tokens
(the §12 replay guarantee extended across §14 healing).

CSV contract: name,us_per_call,derived. Full curves go to
results/chaos.json.

  PYTHONPATH=src python benchmarks/bench_chaos.py
  PYTHONPATH=src python benchmarks/bench_chaos.py --smoke   # CI docs job
"""
from __future__ import annotations

import argparse
import pathlib
import sys

if __package__ in (None, ""):       # `python benchmarks/bench_chaos.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core import HealthConfig
from repro.core.autoscaler import AutoScalerConfig
from repro.core.faults import FaultPlan
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

RATES = [4.0, 5.0]
# instance 4 sits in the decode pool (prefill is 0..2): the slowdown is
# pinned there so the straggler is always detectable from decode intervals
PLAN = ("slow@10:factor=8,duration=20,target=4;"
        "droptransfer@15:p=0.6,duration=10;"
        "crash@30")
HEALTH = HealthConfig(sustain_s=1.0, probation_s=2.0,
                      xfer_backoff_s=0.05, preemption=True)


def run_point(cfg, rate: float, healing: bool, duration: float):
    p = TRACE_PRESETS["spike"]
    trace = load_trace("spike", rate_scale=rate, seed=0, duration=duration)
    sim = Simulator(cfg, n_instances=6, n_prefill=3, policy="arrow_elastic",
                    slo=SLO(p.slo_ttft, p.slo_tpot),
                    autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                    max_instances=12),
                    fault_plan=FaultPlan.parse(PLAN),
                    health=HEALTH if healing else False)
    replay_trace(sim, trace)
    report = sim.drain()
    assert not sim.pools.degraded_ids(), \
        "an instance was left quarantined after drain"
    span = max(report.duration, 1e-9)
    good = sum(1 for h in report.handles if h.meets_slo())
    h = report.health
    return {
        "rate_scale": rate,
        "n_requests": len(trace),
        "n_finished": report.n_finished,
        "attainment": report.attainment,
        "goodput_req_s": good / span,
        "quarantines": h.get("quarantines", 0),
        "restores": h.get("restores", 0),
        "xfer_retries": h.get("xfer_retries", 0),
        "xfer_failures": h.get("xfer_failures", 0),
        "preemptions": h.get("preemptions", 0),
        "recovered": report.faults.get("requests_recovered", 0),
    }


def run_engine_leg():
    """Real-cluster chaos replay: transfer drops + netslow + a crash under
    the health layer, every stream (greedy and seeded-sampled) bit-identical
    to the fault-free sequential reference."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core import Request, SamplingParams
    from repro.engine import ArrowEngineCluster, EngineInstance
    from repro.models import build_model

    cfg = get_smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    run_seed, n, out_len = 3, 6, 12
    rng = np.random.default_rng(5)
    prompts = {i: rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
               for i in range(n)}
    sp = SamplingParams(temperature=0.8, top_p=0.9)

    eng = ArrowEngineCluster(
        cfg, n_instances=3, n_prefill=1, n_slots=4, capacity=128,
        slo=SLO(5.0, 2.0), params=params, seed=run_seed,
        health=HealthConfig(xfer_backoff_s=0.01),
        fault_plan=FaultPlan.parse("droptransfer@0.05:p=0.7,duration=1;"
                                   "netslow@0.2:factor=3,duration=1;"
                                   "crash@1.0:target=2"))
    handles = [eng.submit(Request(rid=i, arrival=0.0, input_len=16,
                                  output_len=out_len,
                                  sampling=sp if i % 2 else None),
                          prompt=prompts[i]) for i in range(n)]
    report = eng.drain(timeout=300.0)
    assert report.n_finished == n, "engine chaos leg lost requests"

    ref = EngineInstance(99, cfg, params, n_slots=4, capacity=128,
                         run_seed=run_seed)
    mismatches = 0
    for h in handles:
        if h.rid % 2:
            ref.set_sampling(h.rid, sp)
        got = [ref.run_prefill(h.rid, prompts[h.rid])]
        ref.local.start_local_decode(h.rid, len(prompts[h.rid]), out_len - 1)
        for _ in range(out_len - 1):
            got.append(ref.run_decode_iteration([h.rid])[h.rid])
        if [int(t) for t in h.tokens] != got:
            mismatches += 1
        ref.drop(h.rid)
    hd = report.health
    return {
        "n_requests": n,
        "n_sampled": n // 2,
        "mismatched_streams": mismatches,
        "xfer_drops": hd.get("xfer_drops", 0),
        "xfer_retries": hd.get("xfer_retries", 0),
        "crashes": report.faults.get("crashes", 0),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--rates", nargs="*", type=float, default=RATES)
    ap.add_argument("--duration", type=float, default=60.0,
                    help="trace duration (seconds at scale 1.0)")
    ap.add_argument("--smoke", action="store_true",
                    help="single fast point (CI docs job)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rates = [4.0]

    cfg = get_config(args.arch)
    out = {}
    for mode, healing in (("healing", True), ("control", False)):
        curve = []
        with Timer() as t:
            for rate in args.rates:
                curve.append(run_point(cfg, rate, healing, args.duration))
        out[mode] = curve
        for pt in curve:
            emit(f"chaos.spike.{mode}.x{pt['rate_scale']:g}",
                 t.us / len(curve),
                 f"attainment={pt['attainment']:.3f};"
                 f"goodput={pt['goodput_req_s']:.2f}req/s;"
                 f"finished={pt['n_finished']}/{pt['n_requests']};"
                 f"quarantines={pt['quarantines']:.0f};"
                 f"retries={pt['xfer_retries']:.0f}")
    # headline: the self-healing loop must pay for itself at every point
    for heal, ctl in zip(out["healing"], out["control"]):
        assert heal["n_finished"] == heal["n_requests"], \
            "healing run lost requests"
        assert heal["quarantines"] >= 1, "no quarantine fired — plan is stale"
        assert heal["restores"] >= heal["quarantines"], \
            "a quarantined instance never returned to ACTIVE"
        assert heal["goodput_req_s"] > ctl["goodput_req_s"], (
            f"healing did not beat detection-off control at "
            f"x{heal['rate_scale']:g}: {heal['goodput_req_s']:.3f} <= "
            f"{ctl['goodput_req_s']:.3f}")
        ratio = heal["goodput_req_s"] / max(ctl["goodput_req_s"], 1e-9)
        emit(f"chaos.spike.headline.x{heal['rate_scale']:g}", 0.0,
             f"goodput_gain={ratio:.2f}x;"
             f"quarantines={heal['quarantines']:.0f};"
             f"restores={heal['restores']:.0f};"
             f"retries={heal['xfer_retries']:.0f};"
             f"preemptions={heal['preemptions']:.0f}")

    with Timer() as t:
        eng = run_engine_leg()
    out["engine"] = eng
    assert eng["mismatched_streams"] == 0, \
        "a healed engine stream diverged from the fault-free reference"
    emit("chaos.engine.identity", t.us,
         f"streams={eng['n_requests']}({eng['n_sampled']}sampled);"
         f"mismatched={eng['mismatched_streams']};"
         f"drops={eng['xfer_drops']:.0f};retries={eng['xfer_retries']:.0f};"
         f"crashes={eng['crashes']:.0f}")
    if not args.smoke:
        save_json("chaos", out)


if __name__ == "__main__":
    main()
