"""SSM/recurrent serving economics on the real engine (DESIGN.md §13).

Arrow's scheduling math (§5.3–§5.5) assumes attention KV that grows O(L)
with context: migration cost, prefix-cache value and the pressure signals
all scale with tokens. Constant-state architectures (Mamba-2 ssd, the
RecurrentGemma conv/RG-LRU recurrence) flip those economics — the decode
state is a fixed-size summary, so a migration moves the same bytes whether
the request holds 12 or 5000 context tokens.

This bench serves the spike trace through the real engine (reduced smoke
configs, Pallas kernels on the decode hot path — ``ssd_scan``/``rglru_scan``
in interpret mode on CPU) and checks the claims end to end:

  * **O(1) migration** — every entry in ``RuntimeCore.migration_log`` for
    the ssm arch carries identical ``bytes`` across differing
    ``ctx_tokens`` (asserted); the dense run's bytes grow proportionally
    with context (asserted), which is the economics gap the cost model
    encodes (``CostModel.migration_bytes``).
  * **State transfer is exact** — sampled streams are bit-identical between
    a ``colocated`` run (no migration) and an ``arrow`` run where every
    decode migrates prefill → decode pool (asserted): the exported/imported
    recurrent state reproduces the same logits, token for token.
  * **Replay** — re-running the migrating configuration with the same seed
    reproduces every sampled stream bit-for-bit (asserted).
  * **arrow_elastic headline** — the ssm arch serves the spike trace under
    the elastic policy (scale-ups share the module-level jitted step, so a
    spawned instance pays no recompile).

CSV contract: name,us_per_call,derived. Full curves go to
results/ssm.json.

  PYTHONPATH=src python benchmarks/bench_ssm.py
  PYTHONPATH=src python benchmarks/bench_ssm.py --smoke   # CI docs job
"""
from __future__ import annotations

import argparse
import pathlib
import sys

if __package__ in (None, ""):       # `python benchmarks/bench_ssm.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_smoke_config
from repro.core.autoscaler import AutoScalerConfig
from repro.core.request import SamplingParams
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.traces import load_trace

SSM_ARCH = "mamba2-370m"
DENSE_ARCH = "qwen3-1.7b"


def serve(arch: str, policy: str, *, rate: float, duration: float,
          seed: int = 0, n_instances: int = 2, autoscaler_cfg=None):
    """One engine run over the spike trace: returns (report, migration_log,
    {rid: token tuple}). Sampled decoding (temperature 0.7) so the
    bit-identity checks cover the replayable-sampling path, not just greedy
    argmax."""
    from repro.engine import ArrowEngineCluster
    cfg = get_smoke_config(arch).replace(attn_impl="pallas")
    cluster = ArrowEngineCluster(
        cfg, n_instances=n_instances, n_prefill=max(n_instances // 2, 1),
        n_slots=8, capacity=160, slo=SLO(5.0, 2.0), policy=policy,
        seed=seed, autoscaler_cfg=autoscaler_cfg)
    trace = load_trace("spike", rate_scale=rate, seed=0, duration=duration)
    for r in trace:
        r.sampling = SamplingParams(temperature=0.7, top_p=0.9, seed=None)
    replay_trace(cluster, trace)
    report = cluster.drain(timeout=600)
    streams = {h.req.rid: tuple(h.tokens)
               for h in cluster.handles.values()}
    return report, list(cluster.migration_log), streams


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=1.5)
    ap.add_argument("--duration", type=float, default=20.0,
                    help="trace duration in seconds (wall-clock: the engine "
                         "replays arrivals in real time)")
    ap.add_argument("--smoke", action="store_true",
                    help="short trace (CI docs job)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.duration = min(args.duration, 6.0)

    out = {}

    # ---- forced migration vs no migration: streams must be bit-identical.
    # Under 'arrow' with a 1-prefill/1-decode split every request's decode
    # migrates (real export_state/import_state); 'colocated' never migrates.
    with Timer() as t:
        rep_m, log_m, streams_m = serve(SSM_ARCH, "arrow", rate=args.rate,
                                        duration=args.duration)
    assert log_m, "arrow run produced no migrations — bench misconfigured"
    assert streams_m and all(len(s) > 0 for s in streams_m.values())
    rep_c, log_c, streams_c = serve(SSM_ARCH, "colocated", rate=args.rate,
                                    duration=args.duration)
    assert not log_c, "colocated run must not migrate"
    assert streams_m == streams_c, \
        "sampled streams diverged across forced migration"
    emit("ssm.spike.forced_migration", t.us,
         f"migrations={len(log_m)};identical=True;"
         f"finished={len(streams_m)}")

    # ---- O(1) migration bytes in context length (the §13 economics)
    ssm_bytes = {m["bytes"] for m in log_m}
    ssm_ctx = {m["ctx_tokens"] for m in log_m}
    assert len(ssm_bytes) == 1, \
        f"ssm migration bytes must be constant, got {sorted(ssm_bytes)}"
    assert len(ssm_ctx) > 1, \
        "trace produced uniform context lengths; O(1) claim untested"
    emit("ssm.spike.migration_bytes", 0.0,
         f"bytes={next(iter(ssm_bytes))};"
         f"ctx_min={min(ssm_ctx)};ctx_max={max(ssm_ctx)};constant=True")

    # ---- replay: same trace + seed => bit-identical sampled streams
    _, _, streams_r = serve(SSM_ARCH, "arrow", rate=args.rate,
                            duration=args.duration)
    assert streams_r == streams_m, "replay with same seed diverged"
    emit("ssm.spike.replay", 0.0, "identical=True")

    # ---- dense contrast: bytes grow proportionally with context
    _, log_d, _ = serve(DENSE_ARCH, "arrow", rate=args.rate,
                        duration=args.duration)
    assert log_d, "dense arrow run produced no migrations"
    per_tok = {m["bytes"] / m["ctx_tokens"] for m in log_d}
    assert max(per_tok) - min(per_tok) < 1e-9, \
        "dense migration bytes must be proportional to context tokens"
    emit("dense.spike.migration_bytes", 0.0,
         f"bytes_per_token={next(iter(per_tok)):.0f};"
         f"ctx_min={min(m['ctx_tokens'] for m in log_d)};"
         f"ctx_max={max(m['ctx_tokens'] for m in log_d)};linear=True")

    # ---- arrow_elastic headline on the spike trace
    with Timer() as t:
        rep_e, log_e, streams_e = serve(
            SSM_ARCH, "arrow_elastic", rate=args.rate,
            duration=args.duration,
            autoscaler_cfg=AutoScalerConfig(min_instances=1,
                                            max_instances=3))
    emit("ssm.spike.arrow_elastic", t.us,
         f"attainment={rep_e.attainment:.3f};finished={len(streams_e)};"
         f"migrations={len(log_e)};"
         f"ups={rep_e.scaling.get('scale_ups', 0)};"
         f"downs={rep_e.scaling.get('scale_downs', 0)}")

    out["forced_migration"] = {"migrations": len(log_m),
                               "finished": len(streams_m),
                               "identical": True}
    out["migration_bytes"] = {
        "ssm": {"bytes": next(iter(ssm_bytes)),
                "ctx": sorted(ssm_ctx)},
        "dense": {"bytes_per_token": next(iter(per_tok)),
                  "ctx": sorted(m["ctx_tokens"] for m in log_d)}}
    out["elastic"] = {"attainment": rep_e.attainment,
                      "migrations": len(log_e),
                      "scaling": rep_e.scaling}
    if not args.smoke:
        save_json("ssm", out)


if __name__ == "__main__":
    main()
