"""Shared benchmark helpers: CSV emission per the harness contract
(``name,us_per_call,derived``) + result persistence."""
from __future__ import annotations

import json
import pathlib
import time

RESULTS_DIR = pathlib.Path(__file__).resolve().parent / "results"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def save_json(name: str, obj) -> None:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{name}.json").write_text(json.dumps(obj, indent=1))


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.s * 1e6
