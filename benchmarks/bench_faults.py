"""Fault-tolerance study (DESIGN.md §8): goodput retention under injected
instance crashes, against a no-recovery strawman.

Three deterministic simulator runs per rate point on the spike trace, all
under ``arrow_elastic`` (the AutoScaler replaces crashed instances):

  * baseline     — fault-free
  * recovery     — the same trace with two scripted crashes; lost requests
                   are re-dispatched (KV-loss recovery, §8.2)
  * strawman     — the same crashes with recovery disabled: in-flight
                   requests on the dead instance are stranded for good

Reported per point: attainment, goodput (SLO-attaining requests per second
of trace time), goodput *retention* vs the fault-free baseline, requests
recovered/lost, and the re-prefill tokens recovery paid. Expected picture:
recovery retains >= ~90% of fault-free goodput (it loses only the recompute
and queueing of the lost work) while the strawman permanently forfeits every
stranded request — and every recovery run finishes all requests.

CSV contract: name,us_per_call,derived. Full curves go to
results/faults.json.

  PYTHONPATH=src python benchmarks/bench_faults.py
  PYTHONPATH=src python benchmarks/bench_faults.py --smoke   # CI docs job
"""
from __future__ import annotations

import argparse
import pathlib
import sys

if __package__ in (None, ""):       # `python benchmarks/bench_faults.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.autoscaler import AutoScalerConfig
from repro.core.faults import FaultPlan
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

RATES = [2.0, 4.0, 6.0]
PLAN = "crash@15;crash@30"          # inside the 60 s spike window


def run_point(cfg, rate: float, mode: str, duration: float):
    p = TRACE_PRESETS["spike"]
    trace = load_trace("spike", rate_scale=rate, seed=0, duration=duration)
    plan = None
    if mode != "baseline":
        plan = FaultPlan.parse(PLAN, recovery=(mode == "recovery"))
    sim = Simulator(cfg, n_instances=6, n_prefill=3, policy="arrow_elastic",
                    slo=SLO(p.slo_ttft, p.slo_tpot),
                    autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                    max_instances=12),
                    fault_plan=plan)
    replay_trace(sim, trace)
    report = sim.drain()
    span = max(report.duration, 1e-9)
    good = sum(1 for h in report.handles if h.meets_slo())
    f = report.faults
    return {
        "rate_scale": rate,
        "n_requests": len(trace),
        "n_finished": report.n_finished,
        "attainment": report.attainment,
        "goodput_req_s": good / span,
        "recovered": f.get("requests_recovered", 0),
        "lost": f.get("requests_lost", 0),
        "re_prefill_tokens": f.get("re_prefill_tokens", 0),
        "replacements": f.get("replacements", 0),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--rates", nargs="*", type=float, default=RATES)
    ap.add_argument("--duration", type=float, default=60.0,
                    help="trace duration (seconds at scale 1.0)")
    ap.add_argument("--smoke", action="store_true",
                    help="single fast point (CI docs job)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rates = [4.0]

    cfg = get_config(args.arch)
    out = {}
    for mode in ("baseline", "recovery", "strawman"):
        curve = []
        with Timer() as t:
            for rate in args.rates:
                curve.append(run_point(cfg, rate, mode, args.duration))
        out[mode] = curve
        for pt in curve:
            emit(f"faults.spike.{mode}.x{pt['rate_scale']:g}",
                 t.us / len(curve),
                 f"attainment={pt['attainment']:.3f};"
                 f"goodput={pt['goodput_req_s']:.2f}req/s;"
                 f"finished={pt['n_finished']}/{pt['n_requests']};"
                 f"recovered={pt['recovered']:.0f};lost={pt['lost']:.0f}")
    # headline: goodput retention vs fault-free, recovery vs strawman
    for rec, straw, base in zip(out["recovery"], out["strawman"],
                                out["baseline"]):
        denom = max(base["goodput_req_s"], 1e-9)
        r_ret = rec["goodput_req_s"] / denom
        s_ret = straw["goodput_req_s"] / denom
        # recovery must complete everything and dominate the strawman — the
        # whole point of the subsystem; assert so the bench can't rot
        assert rec["n_finished"] == rec["n_requests"], "recovery lost requests"
        assert rec["goodput_req_s"] >= straw["goodput_req_s"], \
            "recovery underperformed the no-recovery strawman"
        emit(f"faults.spike.headline.x{rec['rate_scale']:g}", 0.0,
             f"retention_recovery={r_ret:.0%};"
             f"retention_strawman={s_ret:.0%};"
             f"re_prefill_toks={rec['re_prefill_tokens']:.0f}")
    if not args.smoke:
        save_json("faults", out)


if __name__ == "__main__":
    main()
