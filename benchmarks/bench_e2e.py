"""Paper Fig. 7: end-to-end SLO attainment vs request rate on the four traces,
Arrow vs baselines. Baseline deployments mirror §7.1:

  vllm            PD-colocated, one big TP engine (1 instance × 32 chips,
                  TP-scaling efficiency penalty)
  vllm_disagg     static 1 prefill + 1 decode instance (TP=16 each)
  distserve       static 4P+4D, lower engine efficiency (unmaintained engine)
  arrow           8 stateless instances × 4 chips, adaptive scheduling

Emits the max sustainable rate at 90% attainment per (trace, system) and the
full attainment curves to results/e2e.json.
"""
from __future__ import annotations

import argparse

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import InstanceProfile, Simulator
from repro.traces import TRACE_PRESETS, load_trace

SYSTEMS = {
    "arrow": dict(policy="arrow", n_instances=8, n_prefill=4,
                  profile=InstanceProfile(chips=4)),
    "vllm": dict(policy="colocated", n_instances=1, n_prefill=1,
                 profile=InstanceProfile(chips=32, flop_eff=0.4, mem_eff=0.6),
                 token_budget=32768),
    "vllm_disagg": dict(policy="minimal_load", n_instances=2, n_prefill=1,
                        profile=InstanceProfile(chips=16, flop_eff=0.45,
                                                mem_eff=0.65)),
    "distserve": dict(policy="minimal_load", n_instances=8, n_prefill=4,
                      profile=InstanceProfile(chips=4, flop_eff=0.25,
                                              mem_eff=0.4)),
}

RATES = [1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 10.0, 12.0, 16.0, 20.0, 24.0, 28.0,
         32.0, 40.0, 48.0]
TARGET = 0.9


def run_system(trace_name: str, sys_name: str, arch: str, duration: float,
               rates=RATES):
    cfg = get_config(arch)
    p = TRACE_PRESETS[trace_name]
    slo = SLO(p.slo_ttft, p.slo_tpot)
    spec = SYSTEMS[sys_name]
    curve = []
    for rate in rates:
        trace = load_trace(trace_name, rate_scale=rate, seed=0,
                           duration=duration)
        sim = Simulator(cfg, n_instances=spec["n_instances"],
                        n_prefill=spec["n_prefill"], policy=spec["policy"],
                        slo=slo, profile=spec["profile"],
                        token_budget=spec.get("token_budget", 8192))
        # unified ServingSystem path: same replay/report code as the engine
        replay_trace(sim, trace)
        report = sim.drain()
        p90 = lambda m: report.percentile(m, 0.9)  # noqa: E731
        curve.append({
            "rate_scale": rate,
            "req_s": len(trace) / max(duration, 1e-9),
            "attainment": report.attainment,
            "p90_ttft": p90("ttft") if p90("ttft") is not None else float("inf"),
            "p90_tpot": p90("tpot") if p90("tpot") is not None else float("inf"),
            "flips": report.flips,
        })
    return curve


def max_sustainable(curve):
    best = 0.0
    for pt in curve:
        if pt["attainment"] >= TARGET:
            best = max(best, pt["req_s"])
    return best


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--traces", nargs="*", default=list(TRACE_PRESETS))
    args = ap.parse_args(argv)

    out = {}
    for trace_name in args.traces:
        out[trace_name] = {}
        sustain = {}
        for sys_name in SYSTEMS:
            with Timer() as t:
                curve = run_system(trace_name, sys_name, args.arch,
                                   args.duration)
            out[trace_name][sys_name] = curve
            sustain[sys_name] = max_sustainable(curve)
            emit(f"e2e.{trace_name}.{sys_name}", t.us,
                 f"max_rate@90%={sustain[sys_name]:.2f}req/s")
        for base in ("vllm", "vllm_disagg"):
            if sustain.get(base):
                ratio = sustain["arrow"] / sustain[base]
                emit(f"e2e.{trace_name}.arrow_vs_{base}", 0.0,
                     f"speedup={ratio:.2f}x")
    save_json("e2e", out)


if __name__ == "__main__":
    main()
