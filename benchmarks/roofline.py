"""Roofline analysis (deliverable g): per (arch × shape × mesh) derive the
three roofline terms from the dry-run records and identify the dominant
bottleneck.

  compute term    = HLO_FLOPs_per_device / (peak_FLOP/s per chip)
  memory term     = HLO_bytes_per_device / HBM_bw per chip
  collective term = collective_bytes_per_device / ICI link bw

(dry-run cost analysis is per-device — each device is one chip.)
MODEL_FLOPS: analytic 6·N·D (train) / 2·N_active·D + attention (serving),
whole-cluster, divided by device count for the per-device useful-flops ratio.
"""
from __future__ import annotations

import argparse
import json
import math

from benchmarks.common import emit, save_json
from repro.configs import INPUT_SHAPES, get_config
from repro.launch.dryrun import load_results

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops(arch: str, shape_name: str) -> float:
    """Whole-cluster useful model FLOPs for one step."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * B * S
    if shape.kind == "prefill":
        base = 2.0 * n_active * B * S
        if cfg.family not in ("ssm",):
            w = cfg.sliding_window
            ctx = S / 2 if w is None else min(S / 2, w)
            frac = 1.0
            if cfg.family == "hybrid":
                frac = cfg.hybrid.pattern.count("attn") / len(cfg.hybrid.pattern)
            base += 4.0 * cfg.n_layers * frac * cfg.q_dim * B * S * ctx
        return base
    # decode: one token per sequence
    base = 2.0 * n_active * B
    if cfg.family not in ("ssm", "encdec"):
        w = cfg.sliding_window or (cfg.long_context_window
                                   if shape_name == "long_500k" else None)
        ctx = S if w is None else min(S, w)
        frac = 1.0
        if cfg.family == "hybrid":
            frac = cfg.hybrid.pattern.count("attn") / len(cfg.hybrid.pattern)
        base += 4.0 * cfg.n_layers * frac * cfg.q_dim * B * ctx
    return base


def analyse(rec: dict) -> dict:
    n_dev = 512 if rec["mesh"] == "2x16x16" else 256
    flops = rec["flops"]
    bytes_ = rec["bytes_accessed"]
    coll = sum(rec["collective_bytes"].values())
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    ratio = mf / max(flops * n_dev, 1e-9)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": flops * n_dev,
        "useful_flops_ratio": ratio,
        "collective_breakdown": rec["collective_bytes"],
        "memory_per_device_gb": (rec["memory"]["argument_bytes"]
                                 + rec["memory"]["temp_bytes"]) / 2**30,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    rows = []
    for rec in load_results():
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "dominant": "skipped"})
            continue
        if rec.get("status") != "ok":
            continue
        a = analyse(rec)
        rows.append(a)
        if rec["mesh"] == args.mesh:
            emit(f"roofline.{a['arch']}.{a['shape']}",
                 max(a["compute_s"], a["memory_s"], a["collective_s"]) * 1e6,
                 f"dominant={a['dominant']};useful={a['useful_flops_ratio']:.2f};"
                 f"comp={a['compute_s']*1e3:.2f}ms;mem={a['memory_s']*1e3:.2f}ms;"
                 f"coll={a['collective_s']*1e3:.2f}ms")
    save_json("roofline", rows)


if __name__ == "__main__":
    main()
