"""Self-speculative decoding headline (DESIGN.md §12): on a seeded decode
burst the speculative engine must beat sequential decode by >= 1.3x
tokens/s while holding draft acceptance >= 0.6.

The burst is greedy (temperature 0) so acceptance is a pure function of how
well the truncated-layer draft model tracks the full model on this config —
on the seeded smoke weights the draft agrees almost always, which makes the
run a *throughput* benchmark: every accepted draft removes one full
model pass plus one host<->device round trip, which is exactly the win
self-speculation exists to buy. Both modes run on the same process (jit
caches warm, same weights, same prompts) and each mode gets an untimed
warm-up burst first so compilation never lands in the timed window.

A modeled-speedup line from the simulator's SpeculationModel rides along so
the analytic cost model (sim) and the measured engine stay comparable.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

if __package__ in (None, ""):   # `python benchmarks/bench_speculative.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_smoke_config
from repro.core import Request, SLO
from repro.engine import ArrowEngineCluster
from repro.models import build_model
from repro.sim import CostModel, SpeculationModel

SPEEDUP_FLOOR = 1.3
ACCEPT_FLOOR = 0.6
K_DRAFT = 4


def run_burst(cfg, params, *, speculate: int, n: int, out_len: int,
              rid_base: int):
    cluster = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=8,
                                 capacity=128, slo=SLO(5.0, 2.0),
                                 params=params, seed=0, speculate=speculate)
    rng = np.random.default_rng(0xBEE)
    handles = []
    for i in range(n):
        prompt = rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)
        handles.append(cluster.submit(
            Request(rid=rid_base + i, arrival=0.0, input_len=24,
                    output_len=out_len), prompt=prompt))
    with Timer() as t:
        report = cluster.drain()
    assert report.n_finished == n
    tokens = sum(len(h.tokens) for h in handles)
    return tokens / t.s, report, handles


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized burst; same floors asserted")
    args = ap.parse_args(argv)
    n, out_len = (4, 24) if args.smoke else (8, 64)

    cfg = get_smoke_config("qwen3-1.7b")
    import jax
    params = build_model(cfg).init(jax.random.PRNGKey(7))

    # untimed warm-ups: compile both step paths before any timed window
    run_burst(cfg, params, speculate=0, n=2, out_len=8, rid_base=90_000)
    run_burst(cfg, params, speculate=K_DRAFT, n=2, out_len=8,
              rid_base=91_000)

    base_tps, _, base_h = run_burst(cfg, params, speculate=0, n=n,
                                    out_len=out_len, rid_base=0)
    spec_tps, rep, spec_h = run_burst(cfg, params, speculate=K_DRAFT, n=n,
                                      out_len=out_len, rid_base=0)
    accept = rep.speculation["acceptance"]
    speedup = spec_tps / base_tps

    # content check before the throughput claim: speculation must not have
    # changed a single token of the burst
    for b, s in zip(base_h, spec_h):
        assert list(b.tokens) == list(s.tokens), \
            f"rid {b.rid}: speculative stream diverged"

    mdl = SpeculationModel(k=K_DRAFT, accept=accept)
    cm = CostModel(cfg)
    ctx = [24 + out_len // 2] * n
    modeled = (cm.iteration_time([], ctx) * mdl.expected_emitted
               / cm.spec_iteration_time(ctx, mdl))
    emit("speculative.baseline", 1e6 / base_tps, f"tok_s={base_tps:.1f}")
    emit("speculative.k4", 1e6 / spec_tps,
         f"tok_s={spec_tps:.1f} accept={accept:.2f} "
         f"speedup={speedup:.2f} modeled={modeled:.2f}")
    assert accept >= ACCEPT_FLOOR, (
        f"draft acceptance {accept:.2f} below {ACCEPT_FLOOR} — the "
        f"truncated-layer draft no longer tracks the full model")
    assert speedup >= SPEEDUP_FLOOR, (
        f"speculative speedup {speedup:.2f}x below {SPEEDUP_FLOOR}x "
        f"(base {base_tps:.1f} tok/s, spec {spec_tps:.1f} tok/s)")
    save_json("speculative", {
        "baseline_tok_s": base_tps, "spec_tok_s": spec_tps,
        "speedup": speedup, "acceptance": accept,
        "modeled_speedup": modeled, "k": K_DRAFT})


if __name__ == "__main__":
    main()
