"""Motivation quantified (§3.2 'lagging instance scheduling'): the same
adaptive policy with a non-zero per-flip penalty (model reload / drain, as in
DistServe/Splitwise/TetriInfer) vs Arrow's zero-cost stateless flip."""
from __future__ import annotations

import argparse

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

LATENCIES = [0.0, 5.0, 30.0, 120.0]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--duration", type=float, default=120.0)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    p = TRACE_PRESETS["azure_code"]
    trace = load_trace("azure_code", rate_scale=args.rate, seed=0,
                       duration=args.duration)
    out = {}
    for lat in LATENCIES:
        with Timer() as t:
            sim = Simulator(cfg, n_instances=8, n_prefill=4, policy="arrow",
                            slo=SLO(p.slo_ttft, p.slo_tpot), flip_latency=lat)
            replay_trace(sim, trace)
            res = sim.drain()
        out[lat] = {"attainment": res.attainment, "flips": res.flips}
        emit(f"flip_latency.{lat:g}s", t.us,
             f"attainment={res.attainment:.3f};flips={res.flips}")
    save_json("flip_latency", out)


if __name__ == "__main__":
    main()
