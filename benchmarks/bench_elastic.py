"""Elastic-scaling study (DESIGN.md §6): goodput + provisioning cost of
``arrow_elastic`` vs the static 8-instance Arrow deployment across a request-
rate ramp on the spike/diurnal traces.

For each (trace, rate) point both systems replay the identical trace through
the unified ServingSystem API. Reported per point:

  * goodput        — SLO-attaining requests per second of trace time
  * attainment     — fraction of requests finishing inside the SLO
  * instance_s     — Σ per-instance alive seconds (the provisioning bill;
                     static pays n_instances × duration by construction)
  * goodput/inst_s — the efficiency headline: requests served in SLO per
                     instance-second paid
  * scale_ups/downs — AutoScaler actions (elastic only)

The expected picture: at low and mid rates the elastic cluster matches the
static one's attainment at a fraction of the instance-seconds (it idles at
``min_instances`` off-peak); at rates where the spike is comparable to the
scaler's reaction time (warm-up + patience + cooldown), elasticity lags and
the static over-provisioned cluster wins attainment — the trade the operator
guide quantifies (docs/OPERATOR.md).

CSV contract: name,us_per_call,derived. Full curves go to
results/elastic.json.

  PYTHONPATH=src python benchmarks/bench_elastic.py
  PYTHONPATH=src python benchmarks/bench_elastic.py --smoke   # CI docs job
"""
from __future__ import annotations

import argparse
import pathlib
import sys

if __package__ in (None, ""):       # `python benchmarks/bench_elastic.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.autoscaler import AutoScalerConfig
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

SYSTEMS = {
    "arrow_static8": dict(policy="arrow", n_instances=8, n_prefill=4),
    "arrow_elastic": dict(policy="arrow_elastic", n_instances=4, n_prefill=2,
                          autoscaler_cfg=AutoScalerConfig(
                              min_instances=2, max_instances=12)),
}

RATES = [1.0, 2.0, 4.0, 6.0]


def run_point(cfg, trace_name: str, sys_name: str, rate: float,
              duration=None):
    p = TRACE_PRESETS[trace_name]
    trace = load_trace(trace_name, rate_scale=rate, seed=0, duration=duration)
    sim = Simulator(cfg, slo=SLO(p.slo_ttft, p.slo_tpot),
                    **SYSTEMS[sys_name])
    replay_trace(sim, trace)
    report = sim.drain()
    span = max(report.duration, 1e-9)
    good = sum(1 for h in report.handles if h.meets_slo())
    inst_s = report.scaling["instance_seconds"]
    return {
        "rate_scale": rate,
        "req_s": len(trace) / span,
        "attainment": report.attainment,
        "goodput_req_s": good / span,
        "instance_seconds": inst_s,
        "goodput_per_kinst_s": 1e3 * good / max(inst_s, 1e-9),
        "scale_ups": report.scaling.get("scale_ups", 0),
        "scale_downs": report.scaling.get("scale_downs", 0),
        "flips": report.flips,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--traces", nargs="*", default=["spike", "diurnal"])
    ap.add_argument("--rates", nargs="*", type=float, default=RATES)
    ap.add_argument("--duration", type=float, default=None,
                    help="override trace duration (seconds at scale 1.0)")
    ap.add_argument("--smoke", action="store_true",
                    help="single fast point per trace (CI docs job)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rates = [4.0]
        args.traces = ["spike"]

    cfg = get_config(args.arch)
    out = {}
    for trace_name in args.traces:
        out[trace_name] = {}
        for sys_name in SYSTEMS:
            curve = []
            with Timer() as t:
                for rate in args.rates:
                    curve.append(run_point(cfg, trace_name, sys_name, rate,
                                           duration=args.duration))
            out[trace_name][sys_name] = curve
            for pt in curve:
                emit(f"elastic.{trace_name}.{sys_name}.x{pt['rate_scale']:g}",
                     t.us / len(curve),
                     f"attainment={pt['attainment']:.3f};"
                     f"goodput={pt['goodput_req_s']:.2f}req/s;"
                     f"instance_s={pt['instance_seconds']:.0f};"
                     f"ups={pt['scale_ups']};downs={pt['scale_downs']}")
        # headline: instance-second savings at equal-or-better attainment
        for e, s in zip(out[trace_name]["arrow_elastic"],
                        out[trace_name]["arrow_static8"]):
            if e["attainment"] >= s["attainment"] - 1e-9:
                saving = 1.0 - e["instance_seconds"] / \
                    max(s["instance_seconds"], 1e-9)
                emit(f"elastic.{trace_name}.saving.x{e['rate_scale']:g}", 0.0,
                     f"instance_s_saved={saving:.0%}")
    if not args.smoke:
        save_json("elastic", out)


if __name__ == "__main__":
    main()
