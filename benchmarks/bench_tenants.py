"""Multi-tenant admission study (DESIGN.md §10): does credit-based
admission control actually protect well-behaved tenants from an
adversarial flooder?

Three deterministic simulator runs per rate point on the ``tenants`` trace
(4 well-behaved tenants + the ``flood`` tenant ramping 10x mid-trace), all
under ``arrow_elastic`` on a capacity-capped cluster (the point where
elastic scale-up alone cannot absorb the flood):

  * off   — the tenancy subsystem disarmed: no registry, no admission,
            legacy FIFO dispatch. The flooder's backlog head-of-line
            blocks everyone.
  * wdrr  — registry armed, admission off: weighted deficit round-robin
            dispatch isolates prefill queues but admits everything.
  * full  — registry + credit admission: the flooder's own SLO violations
            drain its credits; its excess is deferred, then rejected or
            shed at the watermarks.

Headline (asserted so the bench can't rot): at the top rate point the
*full* leg keeps every well-behaved tenant's attainment >= 0.9 while the
*off* leg drops at least one below 0.6 — and the full leg does it with
fewer instance-seconds (shedding is cheaper than scaling into a flood).

CSV contract: name,us_per_call,derived. Full curves go to
results/tenants.json.

  PYTHONPATH=src python benchmarks/bench_tenants.py
  PYTHONPATH=src python benchmarks/bench_tenants.py --smoke   # CI docs job
"""
from __future__ import annotations

import argparse
import pathlib
import sys

if __package__ in (None, ""):       # `python benchmarks/bench_tenants.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.autoscaler import AutoScalerConfig
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.core.tenants import default_registry
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

RATES = [16.0, 24.0, 32.0]
MODES = ("off", "wdrr", "full")
WELL_BEHAVED = ("t0", "t1", "t2", "t3")


def run_point(cfg, rate: float, mode: str, duration: float):
    p = TRACE_PRESETS["tenants"]
    trace = load_trace("tenants", rate_scale=rate, seed=0, duration=duration)
    kw = {}
    if mode != "off":
        kw = dict(tenants=default_registry(4),
                  admission=(mode == "full"))
    sim = Simulator(cfg, n_instances=4, n_prefill=2, policy="arrow_elastic",
                    slo=SLO(p.slo_ttft, p.slo_tpot),
                    autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                    max_instances=6),
                    **kw)
    replay_trace(sim, trace)
    report = sim.drain()
    # per-tenant attainment computed uniformly from handles (the `off` leg
    # has no registry, so report.per_tenant is empty there by design)
    by = {}
    for h in report.handles:
        by.setdefault(h.req.tenant_id, []).append(h)
    tenants = {}
    for tid, hs in sorted(by.items()):
        fin = [h for h in hs if h.req.finish_time is not None]
        tenants[tid] = {
            "submitted": len(hs),
            "finished": len(fin),
            "attainment": (sum(h.meets_slo() for h in fin) / len(fin)
                           if fin else None),
            "rejected": sum(1 for h in hs if h.rejected),
        }
    return {
        "rate_scale": rate,
        "mode": mode,
        "n_requests": len(trace),
        "attainment": report.attainment,
        "instance_s": report.scaling["instance_seconds"],
        "admission": report.admission,
        "tenants": tenants,
        "per_tenant": report.per_tenant,   # credits etc. (registry legs)
    }


def min_well_behaved(pt) -> float:
    return min(pt["tenants"][t]["attainment"] or 0.0 for t in WELL_BEHAVED)


def flood_rejections(pt) -> int:
    return pt["tenants"].get("flood", {}).get("rejected", 0)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--rates", nargs="*", type=float, default=RATES)
    ap.add_argument("--duration", type=float, default=120.0,
                    help="trace duration (seconds at scale 1.0)")
    ap.add_argument("--smoke", action="store_true",
                    help="single fast point (CI docs job): relative checks "
                         "only, no JSON artifact")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rates, args.duration = [16.0], 40.0

    cfg = get_config(args.arch)
    out = {}
    for mode in MODES:
        curve = []
        with Timer() as t:
            for rate in args.rates:
                curve.append(run_point(cfg, rate, mode, args.duration))
        out[mode] = curve
        for pt in curve:
            emit(f"tenants.{mode}.x{pt['rate_scale']:g}",
                 t.us / len(curve),
                 f"min_wb_attainment={min_well_behaved(pt):.2f};"
                 f"flood_rejected={flood_rejections(pt)};"
                 f"instance_s={pt['instance_s']:.0f}")

    # headline: the subsystem must protect the compliant tenants under the
    # heaviest flood, and rejection must actually be exercised
    for off, full in zip(out["off"], out["full"]):
        assert flood_rejections(full) > 0, \
            "admission never rejected the flooder — the gate is dead"
        assert min_well_behaved(full) >= 0.9, \
            (f"admission-on dropped a well-behaved tenant to "
             f"{min_well_behaved(full):.2f} at x{full['rate_scale']:g}")
        emit(f"tenants.headline.x{full['rate_scale']:g}", 0.0,
             f"wb_off={min_well_behaved(off):.2f};"
             f"wb_full={min_well_behaved(full):.2f};"
             f"instance_s_off={off['instance_s']:.0f};"
             f"instance_s_full={full['instance_s']:.0f}")
    if args.smoke:
        off, full = out["off"][-1], out["full"][-1]
        assert min_well_behaved(off) < min_well_behaved(full) - 0.1, \
            "admission showed no protection over the FIFO baseline"
        print("tenants smoke OK:",
              f"wb {min_well_behaved(off):.2f} -> "
              f"{min_well_behaved(full):.2f}", file=sys.stderr)
        return
    # full run: the top rate point must show the collapse admission avoids
    top_off = out["off"][-1]
    assert min_well_behaved(top_off) < 0.6, \
        (f"FIFO baseline survived the flood (min well-behaved "
         f"{min_well_behaved(top_off):.2f}) — raise the rate so the bench "
         f"measures an actual overload")
    save_json("tenants", out)


if __name__ == "__main__":
    main()
