"""Paper Fig. 8 (§7.3 ablation): SLO-Aware (Arrow) vs Minimal-Load vs
Round-Robin, 4P+4D instances, azure_code + azure_conv."""
from __future__ import annotations

import argparse

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import InstanceProfile, Simulator
from repro.traces import TRACE_PRESETS, load_trace

# arrow_proactive = beyond-paper extension (burst-detector pre-flipping)
STRATEGIES = ["arrow", "arrow_proactive", "minimal_load", "round_robin"]
RATES = [2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--duration", type=float, default=120.0)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)

    out = {}
    for trace_name in ("azure_code", "azure_conv"):
        p = TRACE_PRESETS[trace_name]
        out[trace_name] = {}
        sustain = {}
        for strat in STRATEGIES:
            curve = []
            with Timer() as t:
                for rate in RATES:
                    trace = load_trace(trace_name, rate_scale=rate, seed=0,
                                       duration=args.duration)
                    sim = Simulator(cfg, n_instances=8, n_prefill=4,
                                    policy=strat, slo=SLO(p.slo_ttft, p.slo_tpot),
                                    profile=InstanceProfile(chips=4))
                    replay_trace(sim, trace)
                    res = sim.drain()
                    curve.append({"rate_scale": rate,
                                  "req_s": len(trace) / args.duration,
                                  "attainment": res.attainment,
                                  "flips": res.flips})
            out[trace_name][strat] = curve
            best = max((c["req_s"] for c in curve if c["attainment"] >= 0.9),
                       default=0.0)
            sustain[strat] = best
            emit(f"ablation.{trace_name}.{strat}", t.us,
                 f"max_rate@90%={best:.2f}req/s")
        if sustain["minimal_load"]:
            emit(f"ablation.{trace_name}.slo_aware_vs_minimal", 0.0,
                 f"speedup={sustain['arrow'] / sustain['minimal_load']:.2f}x")
    save_json("ablation", out)


if __name__ == "__main__":
    main()
