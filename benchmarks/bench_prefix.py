"""Prefix-reuse study (DESIGN.md §7): goodput and prefill-seconds saved of
``arrow`` with the prefix cache on vs off, on the multi-turn conversation
trace — plus a control showing the non-session presets (spike) are untouched
when the cache is off.

For each rate point the identical ``multiturn`` trace replays through two
simulators differing only in ``prefix_cache``. Reported per point:

  * goodput          — SLO-attaining requests per second of trace time
  * attainment       — fraction of requests finishing inside the SLO
  * prefill_saved    — predicted prefill-seconds not recomputed, as a
                       fraction of the total predicted prefill time
                       (``ServeReport.prefix['saved_prefill_frac']``)
  * hit_rate         — index hits / lookups
  * p50/p90 TTFT     — the latency the reuse actually buys

Expected picture: every follow-up turn hits (hit_rate ≈ share of follow-up
turns), well over 30% of prefill seconds are saved (the shared history
dominates the prompt), and goodput with the cache on is >= the cache-off run
at every rate — at high rates, where the prefill queue is the bottleneck,
the gap is largest.

CSV contract: name,us_per_call,derived. Full curves go to
results/prefix.json.

  PYTHONPATH=src python benchmarks/bench_prefix.py
  PYTHONPATH=src python benchmarks/bench_prefix.py --smoke   # CI docs job
"""
from __future__ import annotations

import argparse
import pathlib
import sys

if __package__ in (None, ""):       # `python benchmarks/bench_prefix.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.common import Timer, emit, save_json
from repro.configs import get_config
from repro.core.serving import replay_trace
from repro.core.slo import SLO
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

SYSTEMS = {
    "arrow": dict(prefix_cache=False),
    "arrow_prefix": dict(prefix_cache=True),
}

RATES = [2.0, 4.0, 6.0]


def run_point(cfg, trace_name: str, sys_name: str, rate: float,
              duration=None):
    p = TRACE_PRESETS[trace_name]
    trace = load_trace(trace_name, rate_scale=rate, seed=0, duration=duration)
    sim = Simulator(cfg, n_instances=8, n_prefill=4, policy="arrow",
                    slo=SLO(p.slo_ttft, p.slo_tpot), **SYSTEMS[sys_name])
    replay_trace(sim, trace)
    report = sim.drain()
    span = max(report.duration, 1e-9)
    good = sum(1 for h in report.handles if h.meets_slo())
    px = report.prefix
    return {
        "rate_scale": rate,
        "n_requests": len(trace),
        "attainment": report.attainment,
        "goodput_req_s": good / span,
        "p50_ttft": report.percentile("ttft", 0.5),
        "p90_ttft": report.percentile("ttft", 0.9),
        "prefill_saved_frac": px.get("saved_prefill_frac", 0.0),
        "hits": px.get("hits", 0),
        "lookups": px.get("lookups", 0),
        "evictions": px.get("evictions", 0),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--rates", nargs="*", type=float, default=RATES)
    ap.add_argument("--duration", type=float, default=None,
                    help="override trace duration (seconds at scale 1.0)")
    ap.add_argument("--smoke", action="store_true",
                    help="single fast point (CI docs job)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.rates = [4.0]
        args.duration = args.duration or 120.0

    cfg = get_config(args.arch)
    out = {}
    for sys_name in SYSTEMS:
        curve = []
        with Timer() as t:
            for rate in args.rates:
                curve.append(run_point(cfg, "multiturn", sys_name, rate,
                                       duration=args.duration))
        out[sys_name] = curve
        for pt in curve:
            emit(f"prefix.multiturn.{sys_name}.x{pt['rate_scale']:g}",
                 t.us / len(curve),
                 f"attainment={pt['attainment']:.3f};"
                 f"goodput={pt['goodput_req_s']:.2f}req/s;"
                 f"p90_ttft={pt['p90_ttft'] * 1e3:.1f}ms;"
                 f"saved={pt['prefill_saved_frac']:.0%};"
                 f"hits={pt['hits']:.0f}/{pt['lookups']:.0f}")
    # headline: goodput delta + prefill-seconds saved at each rate
    for on, off in zip(out["arrow_prefix"], out["arrow"]):
        emit(f"prefix.multiturn.headline.x{on['rate_scale']:g}", 0.0,
             f"goodput_delta={on['goodput_req_s'] - off['goodput_req_s']:+.2f}"
             f"req/s;prefill_s_saved={on['prefill_saved_frac']:.0%}")
    # control: a non-session preset with the cache *off* is byte-identical
    # to plain arrow (same code path) — assert instead of just reporting
    p = TRACE_PRESETS["spike"]
    spike = load_trace("spike", rate_scale=2.0, seed=0,
                       duration=args.duration)
    ttfts = []
    for kw in (dict(), dict(prefix_cache=False)):
        sim = Simulator(cfg, n_instances=8, n_prefill=4, policy="arrow",
                        slo=SLO(p.slo_ttft, p.slo_tpot), **kw)
        replay_trace(sim, spike)
        rep = sim.drain()
        ttfts.append([h.ttft for h in rep.handles])
    assert ttfts[0] == ttfts[1], "cache-off run diverged from plain arrow"
    emit("prefix.spike.cache_off_control", 0.0, "identical=yes")
    if not args.smoke:
        save_json("prefix", out)


if __name__ == "__main__":
    main()
