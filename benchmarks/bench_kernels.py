"""Pallas kernel micro-benchmarks: interpret-mode wall time vs the pure-jnp
reference on CPU (correctness-weighted; TPU wall-time is out of scope on this
container — see EXPERIMENTS.md §Roofline for the compiled-cost view)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json


def timeit(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def main() -> None:
    out = {}
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    from repro.kernels.flash_prefill import flash_prefill, flash_prefill_ref
    B, H, Hk, S, D = 1, 4, 2, 256, 64
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hk, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hk, S, D), jnp.float32)
    t_k = timeit(lambda *a: flash_prefill(*a, bq=128, bk=128, interpret=True),
                 q, k, v)
    t_r = timeit(jax.jit(flash_prefill_ref), q, k, v)
    emit("kernel.flash_prefill.interp", t_k, f"ref_us={t_r:.1f}")
    out["flash_prefill"] = {"interp_us": t_k, "ref_us": t_r}

    from repro.kernels.paged_attention import (paged_attention,
                                               paged_attention_ref)
    qd = jax.random.normal(ks[3], (4, 8, 64), jnp.float32)
    kp = jax.random.normal(ks[4], (64, 16, 2, 64), jnp.float32)
    vp = jax.random.normal(ks[5], (64, 16, 2, 64), jnp.float32)
    pt = jax.random.randint(ks[6], (4, 8), 0, 64)
    ln = jnp.full((4,), 100, jnp.int32)
    t_k = timeit(lambda *a: paged_attention(*a, interpret=True), qd, kp, vp, pt, ln)
    t_r = timeit(jax.jit(paged_attention_ref), qd, kp, vp, pt, ln)
    emit("kernel.paged_attention.interp", t_k, f"ref_us={t_r:.1f}")
    out["paged_attention"] = {"interp_us": t_k, "ref_us": t_r}

    from repro.kernels.ssd_scan import ssd_scan_op, ssd_scan_ref
    x = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    la = -jnp.abs(jax.random.normal(ks[1], (1, 256, 4))) * 0.5
    Bm = jax.random.normal(ks[2], (1, 256, 4, 64)) * 0.3
    Cm = jax.random.normal(ks[3], (1, 256, 4, 64)) * 0.3
    t_k = timeit(lambda *a: ssd_scan_op(*a, chunk=64, interpret=True), x, la, Bm, Cm)
    t_r = timeit(jax.jit(ssd_scan_ref), x, la, Bm, Cm)
    emit("kernel.ssd_scan.interp", t_k, f"ref_us={t_r:.1f}")
    out["ssd_scan"] = {"interp_us": t_k, "ref_us": t_r}

    from repro.kernels.rglru_scan import rglru_scan_op, rglru_scan_ref
    la2 = -jnp.abs(jax.random.normal(ks[4], (2, 256, 512))) * 0.3
    b2 = jax.random.normal(ks[5], (2, 256, 512))
    t_k = timeit(lambda *a: rglru_scan_op(*a, bs=128, bw=512, interpret=True),
                 la2, b2)
    t_r = timeit(jax.jit(lambda a, b: rglru_scan_ref(a, b)), la2, b2)
    emit("kernel.rglru_scan.interp", t_k, f"ref_us={t_r:.1f}")
    out["rglru_scan"] = {"interp_us": t_k, "ref_us": t_r}

    save_json("kernels", out)


if __name__ == "__main__":
    main()
