"""Engine-step throughput (DESIGN.md §9): the fused+donated per-instance
step vs the pre-fusion per-rid path.

Both modes replay the *same* burst of mixed prefill+decode work (same seed,
same prompts, same model params) through a real ArrowEngineCluster on CPU:

  * legacy — the pre-PR path: one jitted decode over a functionally-copied
    KV cache plus an eager logits fetch per iteration, one jitted call and
    a host pos_map round-trip per prefill chunk, per-request
    ``int(jnp.argmax(...))`` syncs at prefill completion.
  * fused  — the whole LocalScheduler plan (decode batch + every prefill
    chunk) as ONE jitted call per instance pass with donated KV buffers and
    a single lazily-fetched token array.

Greedy streams must be bit-identical across the two modes — the speedup is
pure mechanics, not semantics. Engine tokens/s counts prefill + decoded
tokens over the serving wall-clock.

CSV contract: name,us_per_call,derived. Full run *appends* a ``{pr, ...}``
entry to the ``trajectory`` list in <repo>/BENCH_engine.json — the perf
history ROADMAP.md asks for ("tokens/s per PR") accumulates instead of
being overwritten; pass ``--pr N`` to label the entry (default: last
recorded pr + 1, or re-stamp with the same number to replace a noisy run).

  PYTHONPATH=src python benchmarks/bench_engine_step.py
  PYTHONPATH=src python benchmarks/bench_engine_step.py --smoke   # CI: docs job
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

if __package__ in (None, ""):       # `python benchmarks/bench_engine_step.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import numpy as np

from benchmarks.common import Timer, emit
from repro.configs import get_smoke_config
from repro.core import Request, SLO
from repro.models import build_model

ROOT = pathlib.Path(__file__).resolve().parents[1]


def make_workload(cfg, n: int, seed: int = 0):
    """Mixed prefill+decode burst: prompts 48-96 tokens, 8-24 new tokens."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=int(rng.integers(48, 97))).astype(np.int32)
        reqs.append((prompt, int(rng.integers(8, 25))))
    return reqs


def run_mode(cfg, params, reqs, mode: str):
    """One serving run; returns (tokens/s, {rid: stream}, report)."""
    import jax  # noqa: F401  (engine import path needs the backend up)
    from repro.engine import ArrowEngineCluster

    cluster = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=8,
                                 capacity=128, slo=SLO(ttft=5.0, tpot=2.0),
                                 params=params, chunk_tokens=32,
                                 step_mode=mode)
    # warm-up batch: pay every jit compile outside the measured window
    warm = [cluster.submit(Request(rid=10_000 + i, arrival=0.0,
                                   input_len=len(p), output_len=m),
                           prompt=p) for i, (p, m) in enumerate(reqs[:2])]
    cluster.drain(timeout=300.0)
    del warm
    handles = [cluster.submit(Request(rid=i, arrival=0.0, input_len=len(p),
                                      output_len=m), prompt=p)
               for i, (p, m) in enumerate(reqs)]
    with Timer() as t:
        cluster.drain(timeout=600.0)
    streams = {h.rid: [tok for tok in h.tokens] for h in handles}
    tokens = sum(len(p) for p, _ in reqs) + sum(len(s) for s in streams.values())
    return tokens / max(t.s, 1e-9), streams, tokens


ENTRY_KEYS = ("pr", "tokens_total", "legacy_tokens_per_s",
              "fused_tokens_per_s", "speedup", "streams_identical")


def check_trajectory(doc: dict) -> None:
    """Schema guard (ISSUE 7): every trajectory entry carries the full key
    set and the list is strictly monotone in ``pr`` — a hand-edited or
    legacy-shape artifact fails loudly here instead of silently dropping
    perf history on the next write."""
    traj = doc.get("trajectory")
    assert isinstance(traj, list) and traj, \
        "BENCH_engine.json: empty/missing trajectory"
    for e in traj:
        missing = [k for k in ENTRY_KEYS if k not in e]
        assert not missing, \
            f"BENCH_engine.json: entry pr={e.get('pr')} missing {missing}"
    prs = [e["pr"] for e in traj]
    assert prs == sorted(prs) and len(set(prs)) == len(prs), \
        f"BENCH_engine.json: trajectory prs not strictly monotone: {prs}"


def load_trajectory(path: pathlib.Path) -> dict:
    """Read BENCH_engine.json, migrating the pre-PR-6 flat single-run shape
    into ``{"workload": ..., "trajectory": [entry...]}``."""
    if not path.exists():
        return {"workload": None, "trajectory": []}
    doc = json.loads(path.read_text())
    if "trajectory" in doc:
        check_trajectory(doc)
        return doc
    # legacy flat artifact (written by PR 5): keep it as the first point
    entry = {k: doc[k] for k in ("tokens_total", "legacy_tokens_per_s",
                                 "fused_tokens_per_s", "speedup",
                                 "streams_identical") if k in doc}
    entry["pr"] = 5
    return {"workload": doc.get("workload"), "trajectory": [entry],
            "note": doc.get("note")}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--pr", type=int, default=None,
                    help="trajectory label for this run; default = last "
                         "recorded pr + 1. Re-using a number replaces that "
                         "entry (re-measure after a noisy run)")
    ap.add_argument("--smoke", action="store_true",
                    help="small run for CI: asserts stream identity and "
                         "fused tokens/s >= the legacy baseline measured in "
                         "the same run (relative check, no wall-clock "
                         "thresholds); skips the JSON artifact")
    args = ap.parse_args(argv)

    import jax
    cfg = get_smoke_config(args.arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    n = 6 if args.smoke else args.requests
    reqs = make_workload(cfg, n)

    tps_legacy, streams_legacy, tokens = run_mode(cfg, params, reqs, "legacy")
    tps_fused, streams_fused, _ = run_mode(cfg, params, reqs, "fused")

    assert streams_fused == streams_legacy, \
        "fused step changed greedy token streams vs the per-rid baseline"
    speedup = tps_fused / max(tps_legacy, 1e-9)
    emit("engine_step_legacy_tokens_per_s", 1e6 / max(tps_legacy, 1e-9),
         f"{tps_legacy:.1f} tok/s")
    emit("engine_step_fused_tokens_per_s", 1e6 / max(tps_fused, 1e-9),
         f"{tps_fused:.1f} tok/s")
    emit("engine_step_fused_speedup", 0.0, f"{speedup:.2f}x")

    if args.smoke:
        assert speedup >= 1.0, \
            f"fused step slower than the per-rid baseline ({speedup:.2f}x)"
        print("engine-step smoke OK:", f"{speedup:.2f}x", file=sys.stderr)
        return

    # regression guard only: a loaded/slow box must not abort the whole
    # benchmark suite (benchmarks/run.py) over a noisy ratio — the recorded
    # artifact documents the >= 2x result on a quiet machine
    assert speedup >= 1.0, \
        f"fused step slower than the per-rid baseline ({speedup:.2f}x)"
    if speedup < 2.0:
        print(f"WARNING: speedup {speedup:.2f}x is under the 2x recorded in "
              f"BENCH_engine.json — noisy machine? re-run quiet before "
              f"updating the artifact", file=sys.stderr)
    path = ROOT / "BENCH_engine.json"
    doc = load_trajectory(path)
    doc["workload"] = {"arch": args.arch, "n_requests": n,
                       "prompt_tokens": "48-96", "new_tokens": "8-24",
                       "chunk_tokens": 32, "instances": 2, "n_slots": 8,
                       "capacity": 128, "seed": 0}
    doc.setdefault("note", "CPU, interpret-free reference attention both "
                           "sides; the delta is fusion + donation + single "
                           "lazy token fetch (DESIGN.md §9)")
    pr = args.pr if args.pr is not None else (
        max((e["pr"] for e in doc["trajectory"]), default=5) + 1)
    entry = {
        "pr": pr,
        "tokens_total": tokens,
        "legacy_tokens_per_s": round(tps_legacy, 1),
        "fused_tokens_per_s": round(tps_fused, 1),
        "speedup": round(speedup, 2),
        "streams_identical": True,
    }
    doc["trajectory"] = sorted(
        [e for e in doc["trajectory"] if e.get("pr") != pr] + [entry],
        key=lambda e: e["pr"])
    check_trajectory(doc)                 # never write a broken artifact
    path.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"BENCH_engine.json[pr={pr}]: {entry['legacy_tokens_per_s']} -> "
          f"{entry['fused_tokens_per_s']} tok/s ({entry['speedup']}x; "
          f"{len(doc['trajectory'])} trajectory points)", file=sys.stderr)


if __name__ == "__main__":
    main()
