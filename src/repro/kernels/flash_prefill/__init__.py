from repro.kernels.flash_prefill.flash_prefill import (flash_prefill,  # noqa: F401
                                                       flash_prefill_dyn)
from repro.kernels.flash_prefill.ops import (flash_chunk_op,  # noqa: F401
                                             flash_prefill_op, flash_seq_op)
from repro.kernels.flash_prefill.ref import flash_prefill_ref  # noqa: F401
