from repro.kernels.flash_prefill.flash_prefill import flash_prefill  # noqa: F401
from repro.kernels.flash_prefill.ops import flash_prefill_op  # noqa: F401
from repro.kernels.flash_prefill.ref import flash_prefill_ref  # noqa: F401
