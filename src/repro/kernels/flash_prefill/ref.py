"""Pure-jnp oracle for flash (chunked-)prefill attention."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_prefill_ref(q, k, v, *, q_offset: int = 0,
                      window: Optional[int] = None, causal: bool = True):
    """q (B,H,Sq,D); k,v (B,Hk,T,D). Query row i has absolute position
    q_offset+i; kv column j has absolute position j (chunked prefill: the
    query chunk starts at q_offset into the already-filled KV).

    Returns (B,H,Sq,D) in q.dtype.
    """
    B, H, Sq, D = q.shape
    Hk = k.shape[1]
    G = H // Hk
    qg = q.reshape(B, Hk, G, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, kf) / math.sqrt(D)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    ok = jnp.ones((Sq, k.shape[2]), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
