"""Jit'd public wrapper for the flash prefill kernel.

``flash_prefill_op`` takes model-layout tensors (B, S, H, D) and handles the
(B, H, S, D) kernel layout, GQA head mapping and interpret-mode selection
(CPU: interpret=True; TPU: compiled Mosaic kernel).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_prefill.flash_prefill import flash_prefill


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_prefill_op(q, k, v, *, q_offset: int = 0,
                     window: Optional[int] = None, causal: bool = True,
                     bq: int = 128, bk: int = 128,
                     interpret: Optional[bool] = None):
    """q (B,S,H,D); k,v (B,T,Hk,D) -> (B,S,H,D)."""
    if interpret is None:
        interpret = _on_cpu()
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_prefill(qt, kt, vt, q_offset=q_offset, window=window,
                      causal=causal, bq=bq, bk=bk, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
