"""Jit'd public wrapper for the flash prefill kernel.

``flash_prefill_op`` takes model-layout tensors (B, S, H, D) and handles the
(B, H, S, D) kernel layout, GQA head mapping and interpret-mode selection
(CPU: interpret=True; TPU: compiled Mosaic kernel).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_prefill.flash_prefill import (flash_prefill,
                                                       flash_prefill_dyn)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _block_size(n: int, cap: int = 128) -> int:
    """Largest power-of-two divisor of ``n``, capped at ``cap`` (the MXU
    tile). The engine pads sequences to 32-token buckets, so this is >= 32
    on the serving path; odd generic shapes degrade gracefully."""
    return min(cap, n & -n)


def flash_prefill_op(q, k, v, *, q_offset: int = 0,
                     window: Optional[int] = None, causal: bool = True,
                     bq: int = 128, bk: int = 128,
                     interpret: Optional[bool] = None):
    """q (B,S,H,D); k,v (B,T,Hk,D) -> (B,S,H,D)."""
    if interpret is None:
        interpret = _on_cpu()
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_prefill(qt, kt, vt, q_offset=q_offset, window=window,
                      causal=causal, bq=bq, bk=bk, interpret=interpret)
    return o.transpose(0, 2, 1, 3)


def flash_seq_op(q, k, v, *, window: Optional[int] = None,
                 interpret: Optional[bool] = None):
    """Full-sequence causal attention in model layout — q (B,S,H,D);
    k,v (B,T,Hk,D) -> (B,S,H,D) with block sizes derived from the shapes
    (the serving engine's prompts are padded to 32-token buckets)."""
    if interpret is None:
        interpret = _on_cpu()
    S, T = q.shape[1], k.shape[1]
    return flash_prefill_op(q, k, v, q_offset=T - S, window=window,
                            causal=True, bq=_block_size(S), bk=_block_size(T),
                            interpret=interpret)


def flash_chunk_op(q, k, v, q_offset, *, window: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """Chunked-prefill attention in model layout with a *traced* chunk
    offset — q (B,Sq,H,D) at absolute positions [q_offset, q_offset+Sq);
    k,v (B,C,Hk,D) the full slot cache, positions [0, q_offset) assumed
    contiguously valid (the engine's KV prefix contract, DESIGN.md §9).
    Returns (B,Sq,H,D)."""
    if interpret is None:
        interpret = _on_cpu()
    Sq, C = q.shape[1], k.shape[1]
    o = flash_prefill_dyn(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), q_offset, window=window,
                          causal=True, bq=_block_size(Sq), bk=_block_size(C),
                          interpret=interpret)
    return o.transpose(0, 2, 1, 3)
