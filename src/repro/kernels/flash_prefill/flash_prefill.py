"""Flash attention Pallas TPU kernel for (chunked) prefill.

Design (TPU-native, not a CUDA port):
  * grid = (B, H, num_q_blocks, num_kv_blocks); last axis "arbitrary"
    (sequential) so the online-softmax running state lives in VMEM scratch
    across kv iterations — the TPU grid is executed sequentially per core, so
    scratch carries state the way a CUDA kernel would carry registers.
  * BlockSpecs tile q/o as (1, 1, bq, D) and k/v as (1, 1, bk, D); the kv-head
    index map folds GQA (q head h reads kv head h // (H//Hk)), so no
    repeat-interleave materialisation of K/V ever happens in HBM.
  * MXU alignment: bq/bk default 128 and D is a multiple of 128 for all
    assigned archs except whisper (64) and stablelm (160) — Mosaic pads the
    lane dim; correctness is unaffected.
  * Causal + sliding-window masking is positional (q_offset supports chunked
    prefill against an existing KV prefix); fully-masked kv blocks are skipped
    via pl.when on block bounds.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 bq: int, bk: int, q_offset, window: Optional[int],
                 causal: bool, sm_scale: float, num_kv_blocks: int):
    """Shared online-softmax body. ``q_offset`` is either a Python int
    (static variant) or an i32 scalar read from SMEM scalar-prefetch memory
    (dynamic variant — chunked prefill passes the chunk offset as a traced
    value so jit traces are reused across offsets)."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq + q_offset
    k_start = ik * bk

    # block-level skip: block is live unless causal/window excludes all of it
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                               # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[:, :1]                          # (bq, 1)
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        acc_scr[...] = acc

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, q_offset: int, window: Optional[int],
            causal: bool, sm_scale: float, num_kv_blocks: int):
    """Static-offset variant (whole-prompt prefill; offset known at trace)."""
    _kernel_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                 bq=bq, bk=bk, q_offset=q_offset, window=window,
                 causal=causal, sm_scale=sm_scale, num_kv_blocks=num_kv_blocks)


def _dyn_kernel(qoff_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, bq: int, bk: int, window: Optional[int], causal: bool,
                sm_scale: float, num_kv_blocks: int):
    """Dynamic-offset variant: the chunk offset rides in scalar-prefetch
    SMEM, so the serving engine's fused step reuses one trace across all
    chunk offsets (DESIGN.md §9)."""
    _kernel_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                 bq=bq, bk=bk, q_offset=qoff_ref[0], window=window,
                 causal=causal, sm_scale=sm_scale, num_kv_blocks=num_kv_blocks)


@functools.partial(
    jax.jit,
    static_argnames=("q_offset", "window", "causal", "bq", "bk", "interpret"))
def flash_prefill(q, k, v, *, q_offset: int = 0, window: Optional[int] = None,
                  causal: bool = True, bq: int = 128, bk: int = 128,
                  interpret: bool = False):
    """q (B,H,Sq,D); k,v (B,Hk,T,D) -> (B,H,Sq,D). See ref.py for semantics."""
    B, H, Sq, D = q.shape
    Hk, T = k.shape[1], k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, T)
    assert Sq % bq == 0 and T % bk == 0, (Sq, bq, T, bk)
    grid = (B, H, Sq // bq, T // bk)
    G = H // Hk

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, q_offset=q_offset, window=window, causal=causal,
        sm_scale=1.0 / math.sqrt(D), num_kv_blocks=T // bk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (col 0 used)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


@functools.partial(
    jax.jit, static_argnames=("window", "causal", "bq", "bk", "interpret"))
def flash_prefill_dyn(q, k, v, q_offset, *, window: Optional[int] = None,
                      causal: bool = True, bq: int = 128, bk: int = 128,
                      interpret: bool = False):
    """Like :func:`flash_prefill`, but ``q_offset`` is a traced i32 scalar
    (0-d array or Python int) delivered to the kernel via scalar prefetch —
    chunked prefill against a growing KV prefix retraces only on new chunk
    *shapes*, never on new offsets."""
    B, H, Sq, D = q.shape
    Hk, T = k.shape[1], k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, T)
    assert Sq % bq == 0 and T % bk == 0, (Sq, bq, T, bk)
    grid = (B, H, Sq // bq, T // bk)
    G = H // Hk

    kernel = functools.partial(
        _dyn_kernel, bq=bq, bk=bk, window=window, causal=causal,
        sm_scale=1.0 / math.sqrt(D), num_kv_blocks=T // bk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik, qo: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, qo: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, qo: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik, qo: (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
    )
    qoff = jnp.asarray(q_offset, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        interpret=interpret,
    )(qoff, q, k, v)
