"""Pallas TPU kernels for the serving engine's compute hot spots.

Each subpackage: ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py`` (jit'd
wrapper, interpret-mode on CPU), ``ref.py`` (pure-jnp oracle):
  flash_prefill   — chunked-prefill flash attention (causal + sliding window, GQA)
  paged_attention — decode attention over a paged KV pool (scalar-prefetch page table)
  ssd_scan        — Mamba2 SSD chunked scan (VMEM-carried inter-chunk state)
  rglru_scan      — RG-LRU diagonal linear recurrence (VPU scan, width-tiled)
"""
