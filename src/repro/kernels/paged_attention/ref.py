"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """q (B,H,D); k_pages/v_pages (P, page, Hk, D); page_table (B, MP) int32;
    lengths (B,) int32. Returns (B,H,D)."""
    B, H, D = q.shape
    page = k_pages.shape[1]
    Hk = k_pages.shape[2]
    MP = page_table.shape[1]
    G = H // Hk
    # gather into dense (B, MP*page, Hk, D)
    k = k_pages[page_table].reshape(B, MP * page, Hk, D)
    v = v_pages[page_table].reshape(B, MP * page, Hk, D)
    qg = q.reshape(B, Hk, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) / math.sqrt(D)
    pos = jnp.arange(MP * page)[None]
    s = jnp.where((pos < lengths[:, None])[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
