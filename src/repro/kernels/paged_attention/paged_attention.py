"""Paged decode attention — Pallas TPU kernel.

TPU-native design notes (vs. the CUDA PagedAttention of vLLM):
  * The page table and context lengths ride in **scalar prefetch** memory
    (SMEM) via ``pltpu.PrefetchScalarGridSpec``: the BlockSpec index map reads
    ``page_table[b, ip]`` to pick which KV page the next HBM→VMEM DMA fetches.
    This is the TPU analogue of vLLM's pointer-chasing warp loads — the Mosaic
    pipeline overlaps the gathered page DMA with compute on the previous page.
  * grid = (B, Hk, num_pages); the last axis is sequential, carrying the
    online-softmax state (m, l, acc) for one (batch, kv-head) in VMEM scratch.
  * GQA: q is laid out (B, Hk, G, D) so the G query heads sharing a kv head
    are processed together as the MXU's M dimension; no KV duplication.
  * Pages whose start offset exceeds the context length are skipped with
    pl.when — the DMA still runs (static grid) but the FLOPs don't.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table, lengths, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, page: int, num_pages: int,
            sm_scale: float):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths[b]
    start = ip * page

    @pl.when(start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                               # (G, page)
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            alpha * l_prev + jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ip == num_pages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    interpret: bool = False):
    """q (B,H,D); k_pages/v_pages (P, page, Hk, D); page_table (B,MP) int32;
    lengths (B,). Returns (B,H,D)."""
    B, H, D = q.shape
    P, page, Hk, _ = k_pages.shape
    MP = page_table.shape[1]
    G = H // Hk
    qh = q.reshape(B, Hk, G, D)

    grid = (B, Hk, MP)
    kernel = functools.partial(_kernel, page=page, num_pages=MP,
                               sm_scale=1.0 / math.sqrt(D))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, kh, ip, pt, ln: (b, kh, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, kh, ip, pt, ln: (pt[b, ip], 0, kh, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, kh, ip, pt, ln: (pt[b, ip], 0, kh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, kh, ip, pt, ln: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, D), q.dtype),
        interpret=interpret,
    )(page_table, lengths, qh, k_pages, v_pages)
    return out.reshape(B, H, D)
