"""Jit'd wrapper: interpret on CPU, Mosaic on TPU."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention


def paged_attention_op(q, k_pages, v_pages, page_table, lengths, *,
                       interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return paged_attention(q, k_pages, v_pages, page_table, lengths,
                           interpret=interpret)
