"""Jit'd wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.rglru_scan import rglru_scan


def rglru_scan_op(log_a, gated, h0=None, *, bs: int = 128, bw: int = 512,
                  interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if h0 is None:
        h0 = jnp.zeros(log_a.shape[::2], jnp.float32)  # (B, W)
    return rglru_scan(log_a.astype(jnp.float32), gated.astype(jnp.float32),
                      h0, bs=bs, bw=bw, interpret=interpret)
