"""Oracle for the RG-LRU linear recurrence (associative-scan based — a
different algorithm from the kernel's sequential in-VMEM scan)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def rglru_scan_ref(log_a, gated, h0=None):
    """log_a, gated (B,S,W) f32. h_t = exp(log_a_t) h_{t-1} + gated_t.
    Returns (h (B,S,W), h_final (B,W))."""
    a = jnp.exp(log_a)

    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    acc_a, h = lax.associative_scan(comb, (a, gated), axis=1)
    if h0 is not None:
        h = h + acc_a * h0[:, None, :]
    return h, h[:, -1]
