"""RG-LRU (Griffin/RecurrentGemma) diagonal linear recurrence — Pallas TPU kernel.

TPU adaptation: the recurrence h_t = a_t ⊙ h_{t-1} + b_t is elementwise over
the LRU width, so it maps to the VPU, not the MXU. The kernel tiles the width
across a parallel grid axis (lane dimension, 128-aligned) and walks the
sequence axis sequentially, carrying h in VMEM scratch — one HBM read per
input element and one write per output element, i.e. the memory-bound roofline
for a scan. (The gate projections that *produce* log_a/gated are plain matmuls
and stay in XLA.)

grid = (B, W // bw, S // bs)  (sequence axis innermost/sequential)
  log_a, gated (B, S, W)   blocks (1, bs, bw)
  h0 (B, W)                block (1, bw)
outputs: h (B, S, W) blocks (1, bs, bw); h_final (B, W) block (1, bw)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(la_ref, b_ref, h0_ref, y_ref, hout_ref, h_scr, *, bs: int,
            num_sblocks: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    def step(i, h):
        a = jnp.exp(la_ref[0, i, :].astype(jnp.float32))
        b = b_ref[0, i, :].astype(jnp.float32)
        h = a * h[0] + b
        y_ref[0, i, :] = h.astype(y_ref.dtype)
        return h[None]

    h = lax.fori_loop(0, bs, step, h_scr[...])
    h_scr[...] = h

    @pl.when(s == num_sblocks - 1)
    def _final():
        hout_ref[...] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "bw", "interpret"))
def rglru_scan(log_a, gated, h0, *, bs: int = 128, bw: int = 512,
               interpret: bool = False):
    """log_a, gated (B,S,W); h0 (B,W). Returns (h (B,S,W), h_final (B,W))."""
    B, S, W = log_a.shape
    bs = min(bs, S)
    bw = min(bw, W)
    assert S % bs == 0 and W % bw == 0, (S, bs, W, bw)
    grid = (B, W // bw, S // bs)
    kernel = functools.partial(_kernel, bs=bs, num_sblocks=S // bs)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda b, iw, i_s: (b, i_s, iw)),
            pl.BlockSpec((1, bs, bw), lambda b, iw, i_s: (b, i_s, iw)),
            pl.BlockSpec((1, bw), lambda b, iw, i_s: (b, iw)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bw), lambda b, iw, i_s: (b, i_s, iw)),
            pl.BlockSpec((1, bw), lambda b, iw, i_s: (b, iw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(log_a, gated, h0)
    return y, hout
