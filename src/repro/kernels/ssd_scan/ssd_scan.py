"""Mamba2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv:2405.21060 §6): the original CUDA
kernel tiles over thread blocks with warp-level matmuls; here each grid step
processes one (batch, head, chunk) with the chunk-local quadratic term on the
MXU and the inter-chunk recurrent state carried in VMEM scratch across the
sequential chunk axis — the state never round-trips to HBM between chunks.

grid = (B, H, num_chunks)   (last axis sequential)
  x  (B,H,nc,Q,P)  inputs pre-scaled by dt      block (1,1,1,Q,P)
  la (B,H,nc,Q,1)  log decay per step           block (1,1,1,Q,1)
  Bm (B,H,nc,Q,N)  input projection             block (1,1,1,Q,N)
  Cm (B,H,nc,Q,N)  output projection            block (1,1,1,Q,N)
  h0 (B,H,P,N)     initial state                block (1,1,P,N)
outputs:
  y  (B,H,nc,Q,P), h_final (B,H,P,N) (written on the last chunk)

``h0`` seeds the VMEM state scratch on the first chunk, so the serving
engine's chunked prefill can resume a sequence mid-stream (decode-state
slots, DESIGN.md §13) instead of always scanning from zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, la_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref, state_scr, *,
            num_chunks: int, Q: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = h0_ref[0, 0].astype(state_scr.dtype)

    la = la_ref[0, 0, 0, :, 0].astype(jnp.float32)     # (Q,)
    cum = jnp.cumsum(la)                               # (Q,)
    x = x_ref[0, 0, 0].astype(jnp.float32)             # (Q,P)
    bm = b_ref[0, 0, 0].astype(jnp.float32)            # (Q,N)
    cm = c_ref[0, 0, 0].astype(jnp.float32)            # (Q,N)

    # intra-chunk: (C B^T ⊙ decay) @ x   — MXU matmuls
    seg = cum[:, None] - cum[None, :]                  # (Q,Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(jnp.where(tri, seg, -jnp.inf))
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,Q)
    y = jax.lax.dot_general(cb * decay, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q,P)

    # inter-chunk: exp(cum) * C @ state^T
    state = state_scr[...]                             # (P,N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: h <- exp(Σla) h + Σ_q exp(cum_Q - cum_q) x_q ⊗ B_q
    tail = jnp.exp(cum[-1] - cum)                      # (Q,)
    new_state = jnp.exp(cum[-1]) * state + jax.lax.dot_general(
        x * tail[:, None], bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (P,N)
    state_scr[...] = new_state

    @pl.when(c == num_chunks - 1)
    def _final():
        hout_ref[0, 0] = new_state.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(x, la, Bm, Cm, h0=None, *, interpret: bool = False):
    """x (B,H,nc,Q,P); la (B,H,nc,Q); Bm/Cm (B,H,nc,Q,N); h0 (B,H,P,N)
    optional initial state (zeros when omitted).
    Returns (y (B,H,nc,Q,P), h_final (B,H,P,N))."""
    B, H, nc, Q, P = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    grid = (B, H, nc)
    kernel = functools.partial(_kernel, num_chunks=nc, Q=Q)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, la[..., None], Bm, Cm, h0.astype(jnp.float32))
    return y, hout
