from repro.kernels.ssd_scan.ops import ssd_scan_op  # noqa: F401
from repro.kernels.ssd_scan.ref import ssd_scan_ref  # noqa: F401
from repro.kernels.ssd_scan.ssd_scan import ssd_scan  # noqa: F401
