"""Jit'd wrapper: model layout (B,S,H,P) -> kernel layout (B,H,nc,Q,P)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan


def ssd_scan_op(x, la, Bm, Cm, chunk: int, *, h0=None,
                interpret: Optional[bool] = None):
    """x (B,S,H,P) already dt-scaled; la (B,S,H); Bm/Cm (B,S,H,N) per-head;
    h0 (B,H,P,N) optional initial state (zeros when omitted).
    Returns (y (B,S,H,P), h_final (B,H,P,N))."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, S, H, P = x.shape
    assert S % chunk == 0
    nc = S // chunk

    def blk(a):
        # (B,S,H,...) -> (B,H,nc,Q,...)
        a = jnp.moveaxis(a, 2, 1)
        return a.reshape((B, H, nc, chunk) + a.shape[3:])

    if h0 is not None:
        h0 = h0.astype(jnp.float32)
    y, h = ssd_scan(blk(x).astype(jnp.float32), blk(la).astype(jnp.float32),
                    blk(Bm).astype(jnp.float32), blk(Cm).astype(jnp.float32),
                    h0, interpret=interpret)
    y = jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)
    return y, h
