"""Oracle for the SSD chunked scan: a *sequential* (non-chunked) state-space
recurrence — an independent algorithm from the kernel's chunked form, so the
comparison validates the chunking algebra itself.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ssd_scan_ref(x, la, Bm, Cm, h0=None):
    """x (B,S,H,P): inputs already scaled by dt; la (B,S,H): log decay
    (dt * A, negative); Bm/Cm (B,S,H,N) per-head (pre-expanded).

    h_t = exp(la_t) * h_{t-1} + B_t ⊗ x_t ;  y_t = C_t · h_t
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, xs):
        xt, lat, bt, ct = xs                       # (B,H,P),(B,H),(B,H,N),(B,H,N)
        h = jnp.exp(lat)[..., None, None] * h + xt[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhn,bhpn->bhp", ct, h)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(la, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h, ys = lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h
