from repro.sim.cost_model import (CostModel, InstanceProfile,  # noqa: F401
                                  SpeculationModel)
from repro.sim.policies import POLICIES  # noqa: F401
from repro.sim.simulator import SimResult, Simulator  # noqa: F401
