"""Deprecated location: the policy registry moved to ``repro.core.policies``
so the real JAX engine can run the same baselines as the simulator through
the shared runtime (DESIGN.md §1). This shim keeps old imports working."""
from repro.core.policies import (  # noqa: F401
    POLICIES, ArrowElasticPolicy, ArrowPolicy, BasePolicy, ColocatedPolicy,
    MinimalLoadPolicy, RoundRobinPolicy)
