"""Analytic instance cost model (TPU adaptation — DESIGN.md §3).

The paper profiles H800 GPUs; we derive iteration costs from TPU v5e
constants and the architecture config. Structure matches the paper's §4
analysis: prefill compute is quadratic in input length (attention) + linear
(MLP); decode iterations are linear in batch tokens and typically
memory-bandwidth bound (weights + KV reads).

The TTFT predictor does NOT read these constants — it fits its quadratic from
profiled samples produced by this model (sim) or wall-clock timing (engine),
exactly as the paper's profiler does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.configs.base import ModelConfig

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
HBM_BYTES = 16 * 2**30
ICI_BW = 50e9                # bytes/s/link


@dataclass(frozen=True)
class SpeculationModel:
    """Analytic model of self-speculative decoding (DESIGN.md §12) for the
    simulator's cost model: ``k`` drafts per round through the first
    ``draft_frac`` of the layer stack, verified by one full pass over the
    ``k + 1`` candidate positions, with per-draft acceptance probability
    ``accept`` (independent-trial approximation of the paper-style
    acceptance curve)."""

    k: int = 4
    draft_frac: float = 0.5       # fraction of layers used for drafting
    accept: float = 0.8           # P(draft j accepted | j-1 accepted)

    @property
    def expected_emitted(self) -> float:
        """E[tokens emitted per round] = sum_{i=0..k} accept^i — the
        geometric-series acceptance of the longest agreeing prefix, plus
        the always-emitted verify token."""
        b = min(max(self.accept, 0.0), 1.0)
        if b >= 1.0:
            return float(self.k + 1)
        return (1.0 - b ** (self.k + 1)) / (1.0 - b)

    @property
    def cost_factor(self) -> float:
        """Per-round *compute* relative to one sequential decode step: k
        truncated-layer draft steps plus a (k+1)-wide full verify pass.
        (The memory-bound round cost is lower — weights are read once per
        pass, not per position — which is where the modeled speedup comes
        from; see CostModel.spec_iteration_time.)"""
        return self.draft_frac * self.k + (self.k + 1)


@dataclass(frozen=True)
class InstanceProfile:
    """One serving instance = a TP slice of `chips` chips."""
    chips: int = 4
    flop_eff: float = 0.5    # achievable fraction of peak (MFU ceiling)
    mem_eff: float = 0.7
    overhead: float = 0.004  # fixed per-iteration dispatch/sync seconds

    @property
    def flops(self) -> float:
        return self.chips * PEAK_FLOPS * self.flop_eff

    @property
    def bw(self) -> float:
        return self.chips * HBM_BW * self.mem_eff

    @property
    def hbm(self) -> float:
        return self.chips * HBM_BYTES


class CostModel:
    def __init__(self, cfg: ModelConfig, prof: InstanceProfile = InstanceProfile()):
        self.cfg = cfg
        self.prof = prof
        self.n_active = cfg.param_count(active_only=True)
        self.param_bytes = cfg.param_count() * 2          # bf16
        c = cfg
        if c.family == "ssm":
            s = c.ssm
            self.kv_bytes_per_token = 0.0
            self.state_bytes_per_seq = c.n_layers * (
                s.n_heads(c.d_model) * s.head_dim * s.d_state * 4
                + (s.d_conv - 1) * (s.d_inner(c.d_model) + 2 * s.n_groups * s.d_state) * 2)
        elif c.family == "hybrid":
            pat = c.hybrid.pattern
            frac_attn = pat.count("attn") / len(pat)
            self.kv_bytes_per_token = c.n_layers * frac_attn * 2 * c.kv_dim * 2
            lw = c.hybrid.lru_width or c.d_model
            self.state_bytes_per_seq = c.n_layers * (1 - frac_attn) * lw * 4
            self.attn_window = c.hybrid.local_window
        else:
            self.kv_bytes_per_token = c.n_layers * 2 * c.kv_dim * 2
            self.state_bytes_per_seq = 0.0
        self.attn_window = getattr(self, "attn_window", None) or c.sliding_window

    # ------------------------------------------------------------- pieces
    def _attn_flops(self, new_tokens: float, ctx: float) -> float:
        """score+value flops for new_tokens attending to ctx positions."""
        c = self.cfg
        if c.family == "ssm":
            s = c.ssm
            # SSD: O(1) state ops per token
            return 2 * new_tokens * c.n_layers * s.n_heads(c.d_model) * \
                s.head_dim * s.d_state * 2
        if self.attn_window:
            ctx = min(ctx, self.attn_window)
        frac = 1.0
        if c.family == "hybrid":
            pat = c.hybrid.pattern
            frac = pat.count("attn") / len(pat)
        return 4 * c.n_layers * frac * c.q_dim * new_tokens * ctx

    def prefill_chunk(self, start: int, length: int) -> Tuple[float, float]:
        """(flops, bytes) for prefilling chunk [start, start+length)."""
        flops = 2 * self.n_active * length + \
            self._attn_flops(length, start + length / 2)
        bytes_ = self.kv_bytes_per_token * length
        return flops, bytes_

    def decode_tokens(self, context_lens: Sequence[int]) -> Tuple[float, float]:
        """(flops, bytes) for one decode iteration over the given requests."""
        b = len(context_lens)
        flops = 2 * self.n_active * b
        bytes_ = 0.0
        for ctx in context_lens:
            flops += self._attn_flops(1, ctx)
            eff_ctx = min(ctx, self.attn_window) if self.attn_window else ctx
            bytes_ += self.kv_bytes_per_token * eff_ctx + self.state_bytes_per_seq
        return flops, bytes_

    # ---------------------------------------------------------- iteration
    def iteration_time(self, prefill_chunks: List[Tuple[int, int]],
                       decode_ctx: Sequence[int]) -> float:
        """Mixed (chunked-prefill) batch iteration: chunks = [(start, len)]."""
        flops, bytes_ = 0.0, 0.0
        if decode_ctx:
            f, m = self.decode_tokens(decode_ctx)
            flops += f
            bytes_ += m
        for start, length in prefill_chunks:
            f, m = self.prefill_chunk(start, length)
            flops += f
            bytes_ += m
        if flops == 0 and bytes_ == 0:
            return 0.0
        bytes_ += self.param_bytes                      # weights read once/iter
        return max(flops / self.prof.flops, bytes_ / self.prof.bw) + \
            self.prof.overhead

    def prefill_time(self, input_len: int) -> float:
        """Whole-prompt prefill (used for profiling the TTFT predictor)."""
        return self.iteration_time([(0, input_len)], [])

    def spec_iteration_time(self, decode_ctx: Sequence[int],
                            spec: "SpeculationModel") -> float:
        """One self-speculative decode round (DESIGN.md §12): k truncated
        draft steps (``draft_frac`` of the layer stack → that fraction of
        the flops, KV traffic and weight bytes, weights re-read per step)
        plus one full verify pass over the k+1 candidate positions (flops
        scale with positions; KV and weights are read once). Emits
        ``spec.expected_emitted`` tokens on average, so per-token cost
        falls in the memory-bound regime — the speedup Eq.(1)/(2) and the
        autoscaler observe through shorter decode iterations."""
        if not decode_ctx:
            return 0.0
        fd, md = self.decode_tokens(decode_ctx)
        df = spec.draft_frac
        flops = spec.k * df * fd + (spec.k + 1) * fd
        bytes_ = spec.k * df * (md + self.param_bytes) \
            + md + self.param_bytes
        return max(flops / self.prof.flops, bytes_ / self.prof.bw) \
            + self.prof.overhead

    # ------------------------------------------------------------ capacity
    def kv_capacity_tokens(self) -> int:
        free = self.prof.hbm * 0.85 - self.param_bytes
        per = max(self.kv_bytes_per_token, 1.0)
        if self.cfg.family == "ssm":
            per = 64.0  # nominal bookkeeping unit; state is per-seq not per-token
        return max(int(free / per), 1024)

    def migration_bytes(self, kv_tokens: int) -> float:
        """Wire size of migrating a request holding ``kv_tokens`` of context
        (DESIGN.md §13): per-token KV for the attention layers plus the
        constant per-sequence recurrent state — O(1) in context length for
        ssm, window-bounded-plus-constant for hybrid."""
        return self.kv_bytes_per_token * kv_tokens + self.state_bytes_per_seq

    def transfer_time_bytes(self, bytes_: float, ici_links: int = 1) -> float:
        return 50e-6 + bytes_ / (ICI_BW * ici_links)

    def transfer_time(self, kv_tokens: int, ici_links: int = 1) -> float:
        return self.transfer_time_bytes(self.migration_bytes(kv_tokens),
                                        ici_links)

    def max_running_tokens(self, tpot: float, batch_hint: int = 64) -> int:
        """Profile Max Running Tokens (§5.3): largest total context such that
        a decode iteration stays within the TPOT budget."""
        lo, hi = 1024, 64 * 1024 * 1024
        while lo < hi:
            mid = (lo + hi + 1) // 2
            ctx = [mid // batch_hint] * batch_hint
            if self.iteration_time([], ctx) <= tpot:
                lo = mid
            else:
                hi = mid - 1
        return lo

    def profile_ttft_samples(self) -> List[Tuple[int, float]]:
        """Startup profiling sweep for the TTFT predictor fit."""
        return [(L, self.prefill_time(L))
                for L in (64, 256, 1024, 2048, 4096, 8192, 16384, 32768, 65536)]
