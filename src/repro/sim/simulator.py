"""Discrete-event cluster simulator: a ``ServingSystem`` backend that replays
traffic against N stateless instances driven by a scheduling policy, with the
analytic TPU cost model supplying iteration/transfer times. Reproduces the
paper's evaluation loop (Fig. 7/8/9) at cluster scale on a laptop.

Event kinds: request arrival, iteration completion, migration completion,
monitor tick. Instances run iterations back-to-back while they have work
(continuous batching); chunked prefill mixes phases inside one iteration.

All scheduling glue (prefill dispatch, decode placement, the FCFS migration
manager, monitor-tick scraping) lives in the shared ``RuntimeCore``
(core/runtime.py); this module only supplies the event loop, the virtual
clock and the cost-model timings. Tokens stream through per-request
``on_token`` callbacks as they land in virtual time — content is not
modeled, so the streamed token ids are ``None``.
"""
from __future__ import annotations

import heapq
import itertools
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.clock import VirtualClock
from repro.core.local_scheduler import LocalScheduler
from repro.core.pools import Lifecycle
from repro.core.request import Request
from repro.core.runtime import DecodePlacement, RuntimeCore
from repro.core.serving import (FinishCallback, RequestHandle, ServeReport,
                                TokenCallback)
from repro.core.slo import SLO, SchedulerConfig
from repro.core.ttft_predictor import TTFTPredictor
from repro.sim.cost_model import (CostModel, InstanceProfile,
                                  SpeculationModel)


@dataclass
class SimResult:
    requests: List[Request]
    slo: SLO
    flips: int = 0
    sim_time: float = 0.0

    @property
    def attainment(self) -> float:
        if not self.requests:
            return 1.0
        ok = sum(1 for r in self.requests if r.meets_slo(self.slo))
        return ok / len(self.requests)

    def p90(self, metric: str) -> float:
        vals = sorted(getattr(r, metric) for r in self.requests
                      if getattr(r, metric) is not None)
        if not vals:
            return float("inf")
        return vals[min(int(0.9 * len(vals)), len(vals) - 1)]


class Simulator(RuntimeCore):
    def __init__(self, cfg: ModelConfig, *, n_instances: int = 8,
                 n_prefill: int = 4, policy: str = "arrow",
                 slo: SLO = SLO(3.0, 0.1),
                 sched_cfg: Optional[SchedulerConfig] = None,
                 profile: InstanceProfile = InstanceProfile(),
                 profiles: Optional[Dict[int, InstanceProfile]] = None,
                 token_budget: int = 8192, flip_latency: float = 0.0,
                 autoscaler_cfg=None, prefix_cache: bool = False,
                 fault_plan=None, tenants=None, admission=False,
                 deflection=None, speculate: int = 0,
                 spec_accept: float = 0.8, spec_draft_frac: float = 0.5,
                 seed: int = 0, health=False):
        """``profiles`` (iid -> InstanceProfile) enables heterogeneous
        clusters (paper §8): per-instance cost models + a per-instance-fitted
        TTFT predictor; ``profile`` is the homogeneous default (elastic
        scale-ups always materialize from it). ``autoscaler_cfg`` tunes the
        AutoScaler attached when ``policy`` is elastic (DESIGN.md §6).
        ``fault_plan`` (core/faults.py) schedules crash/slowdown injection
        as exact virtual-clock events (DESIGN.md §8). ``tenants`` attaches a
        ``TenantRegistry`` (core/tenants.py); ``admission`` (bool or an
        ``AdmissionConfig``) arms the watermark admission controller
        (DESIGN.md §10). ``deflection`` (a ``DeflectionConfig``) tunes
        cross-pool prefill deflection under a deflective policy
        (``arrow_deflect``, DESIGN.md §11). ``speculate=k`` models
        self-speculative decoding (DESIGN.md §12): decode iterations cost
        ``CostModel.spec_iteration_time`` and emit multiple tokens per
        round with per-draft acceptance ``spec_accept``. ``health`` (bool or
        a ``HealthConfig``) arms the self-healing layer (DESIGN.md §14):
        straggler quarantine, the transfer retry ladder and SLO-aware
        preemption."""
        self.cfg = cfg
        self._spawn_profile = profile
        self._token_budget = token_budget
        self.spec: Optional[SpeculationModel] = (
            SpeculationModel(k=speculate, draft_frac=spec_draft_frac,
                             accept=spec_accept) if speculate else None)
        # deterministic error-diffusion residual for integer per-round
        # emission (rid -> fractional tokens owed) — the modeled stream
        # length is exact in expectation and replayable
        self._spec_residual: Dict[int, float] = {}
        ids = list(range(n_instances))
        self.costs: Dict[int, CostModel] = {
            i: CostModel(cfg, (profiles or {}).get(i, profile))
            for i in ids}
        self.cost = self.costs[0]
        if profiles:
            from repro.core.ttft_predictor import PerInstancePredictor
            predictor = PerInstancePredictor.fit_per_instance(
                {i: self.costs[i].profile_ttft_samples() for i in ids})
        else:
            predictor = TTFTPredictor.fit(self.cost.profile_ttft_samples())
        # conservative Max Running Tokens: profiled on the weakest instance
        mrt = min(
            c.max_running_tokens(
                (sched_cfg or SchedulerConfig()).tpot_threshold_frac * slo.tpot)
            for c in self.costs.values())
        base = sched_cfg or SchedulerConfig()
        overrides = {"max_running_tokens": mrt}
        if policy == "arrow_proactive":
            overrides["proactive"] = True
        sched_cfg = SchedulerConfig(**{**base.__dict__, **overrides})

        self._init_runtime(ids, n_prefill=n_prefill, policy=policy, slo=slo,
                           sched_cfg=sched_cfg, predictor=predictor,
                           clock=VirtualClock(), autoscaler_cfg=autoscaler_cfg,
                           prefix_cache=prefix_cache, fault_plan=fault_plan,
                           tenants=tenants, admission=admission,
                           deflection=deflection, run_seed=seed,
                           prefix_reuse=("block" if cfg.family == "dense"
                                         else "exact"),
                           health=health)
        self.locals: Dict[int, LocalScheduler] = {
            i: LocalScheduler(i, token_budget=token_budget,
                              kv_capacity_tokens=self.costs[i].kv_capacity_tokens())
            for i in ids}
        for i in ids:
            self._arm_deflect(i)     # §11 micro-batch knob (no-op if unarmed)

        self.requests: Dict[int, Request] = {}
        self._heap: list = []
        self._seq = itertools.count()
        self._busy: Dict[int, bool] = {i: False for i in ids}
        self._tick_armed = False
        # in-flight KV transfers carry a sequence token so a crash can
        # invalidate the pending completion event (DESIGN.md §8)
        self._xfer_seq = itertools.count(1)
        self._live_xfer: Dict[int, int] = {}      # rid -> live seq
        if self.fault_injector is not None:
            # exact virtual-time firing: one event per scripted fault
            for t in self.fault_injector.event_times():
                self._push(t, self._on_fault_due)

        # Motivation experiment (§3.2 "lagging instance scheduling"): legacy
        # systems pay a reload/drain penalty per flip. Arrow's stateless
        # instances make it 0; flip_latency>0 simulates DistServe/Splitwise-
        # style role changes to quantify what statelessness buys.
        self._flip_latency = flip_latency
        self._flip_block: Dict[int, float] = {i: 0.0 for i in ids}
        if flip_latency > 0:
            orig_move = self.pools.move

            def move(iid, to):
                if self.pools.pool_of(iid) is not to:
                    self._flip_block[iid] = self._now + flip_latency
                orig_move(iid, to)

            self.pools.move = move

    # ----------------------------------------------------- RuntimeCore hooks
    @property
    def _now(self) -> float:
        return self.clock.now()

    def local_of(self, iid: int) -> LocalScheduler:
        return self.locals[iid]

    def _begin_transfer(self, rid: int, dst: int, kv: int, rem: int) -> bool:
        # reserve memory now; data lands after the (async DMA) transfer delay
        self.locals[dst].kv_used += kv
        self._launch_transfer(rid, dst, kv, rem, delay=0.0)
        return True

    def _launch_transfer(self, rid: int, dst: int, kv: int, rem: int,
                         delay: float) -> None:
        """Schedule one transfer attempt (§14 retry ladder: ``delay`` is the
        backoff before a retry). The attempt's fate is decided now — dropped
        under an active droptransfer window, or timed out when the (possibly
        netslow-inflated) duration exceeds the per-transfer timeout — and a
        failed attempt surfaces at the moment the failure would be noticed:
        the timeout, or the would-be landing time."""
        dur = self.costs[dst].transfer_time_bytes(
            self.costs[dst].migration_bytes(kv))
        dur *= self.netslow_factor(self._now)        # degraded interconnect
        failed = self.xfer_should_drop(self._now)
        if self.health_cfg is not None and \
                dur > self.health_cfg.xfer_timeout_s:
            failed = True
            dur = self.health_cfg.xfer_timeout_s
        seq = next(self._xfer_seq)
        self._live_xfer[rid] = seq
        self._push(self._now + delay + dur, self._on_migration_done,
                   dst, rid, kv, rem, seq, failed)

    def _abort_transfer(self, rid: int, dst: int, kv: int) -> None:
        # crash abort (§8): undo the destination reservation; the pending
        # completion event no longer matches the live seq and is dropped
        loc = self.locals.get(dst)
        if loc is not None:
            loc.kv_used = max(0, loc.kv_used - kv)
        self._live_xfer.pop(rid, None)

    def _release_source_kv(self, src: int, rid: int, kv: int) -> None:
        self.locals[src].release_prefill_kv(rid, kv)
        self._kick(src)

    def _decode_started(self, iid: int) -> None:
        self._kick(iid)

    def _arrival_due(self, rid: int) -> None:
        """Deferred request released (parent finished / instance activated):
        re-enter the arrival path at the current virtual time."""
        self._push(self._now, self._on_arrival, rid)

    def _schedule_retry(self, rid: int, at: float) -> None:
        """Admission deferred ``rid`` (§10): exact virtual-time retry event.
        These events also keep the monitor tick armed, so credit accrual
        continues while requests wait."""
        self._push(max(at, self._now), self._on_arrival, rid)

    # ------------------------------------- elastic lifecycle hooks (§6)
    def _create_instance(self, iid: int) -> float:
        """Materialize a new instance from the homogeneous InstanceProfile;
        the AutoScaler's ``warmup_s`` models provision/weight-load time."""
        self.costs[iid] = CostModel(self.cfg, self._spawn_profile)
        self.locals[iid] = LocalScheduler(
            iid, token_budget=self._token_budget,
            kv_capacity_tokens=self.costs[iid].kv_capacity_tokens())
        self._busy[iid] = False
        self._flip_block[iid] = 0.0
        return self.autoscaler.cfg.warmup_s if self.autoscaler else 0.0

    def _schedule_activation(self, iid: int, delay: float) -> None:
        self._push(self._now + delay, self.activate_instance, iid)

    def _instance_ready(self, iid: int) -> None:
        self._kick(iid)

    def _instance_quiesced(self, iid: int) -> bool:
        return not self._busy.get(iid, False)

    def _destroy_instance(self, iid: int) -> None:
        del self.locals[iid]
        del self.costs[iid]
        del self._busy[iid]
        del self._flip_block[iid]

    # ---------------------------------------------- fault hooks (§8)
    def _on_instance_failed(self, iid: int) -> None:
        # a running iteration dies with the instance: its completion event
        # is stale (the handlers check lifecycle); the corpse's LocalScheduler
        # stays until finalization so stat probes see an empty instance
        self._busy[iid] = False

    def _on_fault_due(self) -> None:
        self.fault_injector.poll(self._now)

    def _is_dead(self, iid: int) -> bool:
        return iid not in self.locals or \
            self.pools.lifecycle_of(iid) is Lifecycle.FAILED

    # --------------------------------------------------------- ServingSystem
    def submit(self, req: Request, *, prompt=None, tier: str = "standard",
               tenant_id: Optional[str] = None,
               on_token: Optional[TokenCallback] = None,
               on_finish: Optional[FinishCallback] = None) -> RequestHandle:
        handle = self._register(req, tier, on_token, on_finish,
                                tenant_id=tenant_id)
        self.requests[req.rid] = req
        self._push(max(req.arrival, self._now), self._on_arrival, req.rid)
        if not self._tick_armed:
            self._tick_armed = True
            self._push(self._now + self.sched_cfg.monitor_interval,
                       self._on_monitor_tick)
        return handle

    def step(self) -> bool:
        if not self._heap:
            return False
        t, _, fn, args = heapq.heappop(self._heap)
        self.clock.advance(t)
        fn(*args)
        return bool(self._heap)

    def run_until(self, t: float) -> None:
        while self._heap and self._heap[0][0] <= t:
            self.step()
        self.clock.advance(t)

    def drain(self, *, timeout: Optional[float] = None) -> ServeReport:
        limit = float("inf") if timeout is None else self._now + timeout
        while self._heap and self._heap[0][0] <= limit:
            self.step()
            self._check_undispatchable()   # §8: raise, don't return short
        self._check_undispatchable()
        return self.report()

    # ------------------------------------------------- deprecated batch shim
    def run(self, trace: List[Request], *, max_time: float = 1e9) -> SimResult:
        """Batch entrypoint kept for compatibility; new code should use
        ``submit()`` + ``drain()`` (the unified ServingSystem API)."""
        warnings.warn("Simulator.run(trace) is deprecated; use the "
                      "ServingSystem API (submit/step/drain)",
                      DeprecationWarning, stacklevel=2)
        for r in trace:
            self.submit(r)
        while self._heap and self._heap[0][0] <= max_time:
            self.step()
        return SimResult(list(self.requests.values()), self.slo,
                         flips=self.pools.flips, sim_time=self._now)

    # ------------------------------------------------------------ events
    def _push(self, t: float, fn, *args) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn, args))

    # -------------------------------------------------------- handlers
    def _on_arrival(self, rid: int) -> None:
        iid = self.dispatch_prefill(self.handles[rid], self._now)
        if iid is not None:               # else deferred (gated/unplaced)
            self._kick(iid)

    def _kick(self, iid: int) -> None:
        """Start an iteration if the instance is idle and has work."""
        if self._is_dead(iid):            # removed/failed — stale event
            return
        if self._busy[iid]:
            return
        if self._flip_block[iid] > self._now:          # draining/reloading
            self._push(self._flip_block[iid], self._kick, iid)
            return
        loc = self.locals[iid]
        self.admit_migrations(iid)
        plan = loc.plan_iteration()
        if plan.is_empty:
            return
        chunks = [(start, ln) for _, start, ln in plan.prefill_chunks]
        ctx = [loc.decode_running[r].context_len for r in plan.decode_rids]
        spec_round = bool(self.spec is not None and ctx)
        if spec_round:
            # mirrors the engine's speculative step structure: one
            # spec_decode call for the decode batch plus a *separate*
            # chunks call (the fused mixed step doesn't speculate), so the
            # per-call overhead is paid twice exactly as on the engine
            dur = self.costs[iid].spec_iteration_time(ctx, self.spec)
            if chunks:
                dur += self.costs[iid].iteration_time(chunks, [])
        else:
            dur = self.costs[iid].iteration_time(chunks, ctx)
        dur *= self.slow_factor(iid, self._now)      # injected lag (§8)
        self._busy[iid] = True
        self._push(self._now + dur, self._on_iteration_done, iid, plan, dur,
                   spec_round)

    def _spec_round_tokens(self, rid: int) -> int:
        """Integer tokens emitted by ``rid``'s speculative round: the
        expected emission with per-rid error diffusion, so long streams hit
        the modeled rate exactly while every round emits at least the
        verify token and at most k+1."""
        spec = self.spec
        r = self._spec_residual.get(rid, 0.0) + spec.expected_emitted
        n = int(min(max(int(r), 1), spec.k + 1))
        self._spec_residual[rid] = r - n
        return n

    def _on_iteration_done(self, iid: int, plan, dur: float,
                           spec_round: bool = False) -> None:
        if self._is_dead(iid):            # crashed mid-iteration (§8)
            return
        loc = self.locals[iid]
        now = self._now
        # decode tokens out (streamed; the sim models timing, not content)
        emitted = 0
        for rid in plan.decode_rids:
            if rid not in loc.decode_running:
                continue
            handle = self.handles[rid]
            if not spec_round:
                self.emit_token(handle, now)
                emitted += 1
                if loc.complete_decode_iteration(rid):
                    self.finish(handle, now)
                continue
            n = self._spec_round_tokens(rid)
            self._spec_stats["rounds"] += 1
            self._spec_stats["drafted"] += self.spec.k
            self._spec_stats["accepted"] += n - 1
            for i in range(n):
                # space the round's tokens inside the iteration so virtual
                # token timestamps stay strictly ordered per request (the
                # stream invariant the property tests assert)
                t_i = now - dur + dur * (i + 1) / n
                self.emit_token(handle, t_i)
                emitted += 1
                self._spec_stats["emitted"] += 1
                if loc.complete_decode_iteration(rid):
                    self.finish(handle, t_i)
                    break                 # overshot accepts are discarded
        self.monitor.record_iteration(iid, now, emitted, dur)
        # prefill chunks
        for rid, start, ln in plan.prefill_chunks:
            if rid not in loc.prefill_queue:
                continue
            req = self.requests[rid]
            req.prefill_done_tokens = start + ln
            if loc.complete_prefill_chunk(rid, ln):
                self._on_prefill_complete(iid, rid)
        self._busy[iid] = False
        self._kick(iid)

    def _on_prefill_complete(self, iid: int, rid: int) -> None:
        handle = self.handles[rid]
        placement, target = self.after_prefill(handle, iid, self._now)
        if placement is DecodePlacement.FINISHED:
            self.locals[iid].release_prefill_kv(rid, handle.req.input_len)
        elif placement is DecodePlacement.LOCAL:
            self._kick(iid)
        else:
            self.admit_migrations(target)

    def _on_migration_done(self, dst: int, rid: int, kv: int, rem: int,
                           seq: int = 0, failed: bool = False) -> None:
        if self._live_xfer.get(rid) != seq:  # aborted by a crash (§8)
            return
        self._live_xfer.pop(rid, None)
        if failed:                           # dropped/timed out attempt (§14)
            attempt = self.note_xfer_drop(rid)
            if attempt <= self.xfer_retry_budget():
                # source KV is retained until acknowledged, so retry is
                # always safe; bounded exponential backoff between attempts
                self.health_stats["xfer_retries"] += 1
                self._launch_transfer(rid, dst, kv, rem,
                                      delay=self.xfer_backoff(attempt))
            else:
                self.locals[dst].kv_used -= kv   # undo the reservation
                self.fail_transfer(rid, dst, kv, self._now)
            return
        self.locals[dst].kv_used -= kv       # admit_migrated re-adds
        self._record_migration(rid, kv,
                               int(self.costs[dst].migration_bytes(kv)))
        self.complete_migration(rid, dst, kv, rem, self._now)

    def _on_monitor_tick(self) -> None:
        now = self._now
        self.collect_stats(now)
        # keep ticking while events remain — or while a quarantined
        # instance awaits its probation/escalation decision (§14), which
        # only the tick can deliver
        if self._heap or self.pools.degraded_ids():
            self._push(now + self.sched_cfg.monitor_interval,
                       self._on_monitor_tick)
        else:
            self._tick_armed = False
