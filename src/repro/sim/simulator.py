"""Discrete-event cluster simulator: replays a trace against N stateless
instances driven by a scheduling policy, with the analytic TPU cost model
supplying iteration/transfer times. Reproduces the paper's evaluation loop
(Fig. 7/8/9) at cluster scale on a laptop.

Event kinds: request arrival, iteration completion, migration completion,
monitor tick. Instances run iterations back-to-back while they have work
(continuous batching); chunked prefill mixes phases inside one iteration.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.local_scheduler import LocalScheduler
from repro.core.monitor import InstanceMonitor, InstanceStats
from repro.core.pools import InstancePools
from repro.core.request import Request, RequestState
from repro.core.slo import SLO, SchedulerConfig
from repro.core.ttft_predictor import TTFTPredictor
from repro.sim.cost_model import CostModel, InstanceProfile
from repro.sim.policies import POLICIES


@dataclass
class SimResult:
    requests: List[Request]
    slo: SLO
    flips: int = 0
    sim_time: float = 0.0

    @property
    def attainment(self) -> float:
        if not self.requests:
            return 1.0
        ok = sum(1 for r in self.requests if r.meets_slo(self.slo))
        return ok / len(self.requests)

    def p90(self, metric: str) -> float:
        vals = sorted(getattr(r, metric) for r in self.requests
                      if getattr(r, metric) is not None)
        if not vals:
            return float("inf")
        return vals[min(int(0.9 * len(vals)), len(vals) - 1)]


class Simulator:
    def __init__(self, cfg: ModelConfig, *, n_instances: int = 8,
                 n_prefill: int = 4, policy: str = "arrow",
                 slo: SLO = SLO(3.0, 0.1),
                 sched_cfg: Optional[SchedulerConfig] = None,
                 profile: InstanceProfile = InstanceProfile(),
                 profiles: Optional[Dict[int, InstanceProfile]] = None,
                 token_budget: int = 8192, flip_latency: float = 0.0):
        """``profiles`` (iid -> InstanceProfile) enables heterogeneous
        clusters (paper §8): per-instance cost models + a per-instance-fitted
        TTFT predictor; ``profile`` is the homogeneous default."""
        self.cfg = cfg
        ids_all = list(range(n_instances))
        self.costs: Dict[int, CostModel] = {
            i: CostModel(cfg, (profiles or {}).get(i, profile))
            for i in ids_all}
        self.cost = self.costs[0]
        self.slo = slo
        if profiles:
            from repro.core.ttft_predictor import PerInstancePredictor
            self.predictor = PerInstancePredictor.fit_per_instance(
                {i: self.costs[i].profile_ttft_samples() for i in ids_all})
        else:
            self.predictor = TTFTPredictor.fit(self.cost.profile_ttft_samples())
        # conservative Max Running Tokens: profiled on the weakest instance
        mrt = min(
            c.max_running_tokens(
                (sched_cfg or SchedulerConfig()).tpot_threshold_frac * slo.tpot)
            for c in self.costs.values())
        base = sched_cfg or SchedulerConfig()
        overrides = {"max_running_tokens": mrt}
        if policy == "arrow_proactive":
            overrides["proactive"] = True
        self.sched_cfg = SchedulerConfig(**{**base.__dict__, **overrides})

        ids = list(range(n_instances))
        if policy == "colocated":
            n_prefill = n_instances       # pools unused; all serve both
        self.pools = InstancePools(ids, n_prefill=n_prefill)
        self.monitor = InstanceMonitor(ids, window=self.sched_cfg.token_interval_window)
        self.locals: Dict[int, LocalScheduler] = {
            i: LocalScheduler(i, token_budget=token_budget,
                              kv_capacity_tokens=self.costs[i].kv_capacity_tokens())
            for i in ids}
        self.policy = POLICIES[policy](self.pools, self.monitor, self.predictor,
                                       slo, self.sched_cfg, self)
        self._colocated = policy == "colocated"

        self.requests: Dict[int, Request] = {}
        self._heap: list = []
        self._seq = itertools.count()
        self._busy: Dict[int, bool] = {i: False for i in ids}
        self._now = 0.0

        # Motivation experiment (§3.2 "lagging instance scheduling"): legacy
        # systems pay a reload/drain penalty per flip. Arrow's stateless
        # instances make it 0; flip_latency>0 simulates DistServe/Splitwise-
        # style role changes to quantify what statelessness buys.
        self._flip_latency = flip_latency
        self._flip_block: Dict[int, float] = {i: 0.0 for i in ids}
        if flip_latency > 0:
            orig_move = self.pools.move

            def move(iid, to):
                if self.pools.pool_of(iid) is not to:
                    self._flip_block[iid] = self._now + flip_latency
                orig_move(iid, to)

            self.pools.move = move

    # ------------------------------------------------------- ClusterView
    def has_pending_prefill(self, iid: int) -> bool:
        return self.locals[iid].has_pending_prefill()

    def has_pending_decode(self, iid: int) -> bool:
        return self.locals[iid].has_pending_decode()

    # ------------------------------------------------------------ events
    def _push(self, t: float, fn, *args) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn, args))

    def run(self, trace: List[Request], *, max_time: float = 1e9) -> SimResult:
        for r in trace:
            self.requests[r.rid] = r
            self._push(r.arrival, self._on_arrival, r.rid)
        self._push(self.sched_cfg.monitor_interval, self._on_monitor_tick)
        while self._heap:
            t, _, fn, args = heapq.heappop(self._heap)
            if t > max_time:
                break
            self._now = t
            fn(*args)
        return SimResult(list(self.requests.values()), self.slo,
                         flips=self.pools.flips, sim_time=self._now)

    # -------------------------------------------------------- handlers
    def _on_arrival(self, rid: int) -> None:
        req = self.requests[rid]
        iid = self.policy.schedule_prefill_req(req, self._now)
        req.prefill_instance = iid
        req.state = RequestState.PREFILLING
        self.locals[iid].enqueue_prefill(rid, req.input_len)
        self._kick(iid)

    def _kick(self, iid: int) -> None:
        """Start an iteration if the instance is idle and has work."""
        if self._busy[iid]:
            return
        if self._flip_block[iid] > self._now:          # draining/reloading
            self._push(self._flip_block[iid], self._kick, iid)
            return
        loc = self.locals[iid]
        self._try_admit_migrations(iid)
        plan = loc.plan_iteration()
        if plan.is_empty:
            return
        chunks = [(start, ln) for _, start, ln in plan.prefill_chunks]
        ctx = [loc.decode_running[r].context_len for r in plan.decode_rids]
        dur = self.costs[iid].iteration_time(chunks, ctx)
        self._busy[iid] = True
        self._push(self._now + dur, self._on_iteration_done, iid, plan, dur)

    def _on_iteration_done(self, iid: int, plan, dur: float) -> None:
        loc = self.locals[iid]
        now = self._now
        # decode tokens out
        emitted = 0
        for rid in plan.decode_rids:
            if rid not in loc.decode_running:
                continue
            req = self.requests[rid]
            req.token_times.append(now)
            req.decoded_tokens += 1
            emitted += 1
            if loc.complete_decode_iteration(rid):
                req.finish_time = now
                req.state = RequestState.FINISHED
        self.monitor.record_iteration(iid, now, emitted, dur)
        # prefill chunks
        for rid, start, ln in plan.prefill_chunks:
            if rid not in loc.prefill_queue:
                continue
            req = self.requests[rid]
            req.prefill_done_tokens = start + ln
            if loc.complete_prefill_chunk(rid, ln):
                self._on_prefill_complete(iid, req)
        self._busy[iid] = False
        self._kick(iid)

    def _on_prefill_complete(self, iid: int, req: Request) -> None:
        now = self._now
        req.first_token_time = now                      # o_1 returned to user
        if req.output_len <= 1:
            req.finish_time = now
            req.state = RequestState.FINISHED
            self.locals[iid].release_prefill_kv(req.rid, req.input_len)
            return
        target = self.policy.schedule_decode_req(req, now)
        req.decode_instance = target
        remaining = req.output_len - 1
        if target == iid or self._colocated:
            req.state = RequestState.DECODING
            self.locals[iid].start_local_decode(req.rid, req.input_len, remaining)
            self._kick(iid)
        else:
            req.state = RequestState.MIGRATING
            self.locals[target].enqueue_migration(req.rid, req.input_len, remaining)
            self._try_admit_migrations(target)

    def _try_admit_migrations(self, iid: int) -> None:
        """FCFS, memory-gated admission; transfer is async DMA (instance can
        keep computing)."""
        loc = self.locals[iid]
        while True:
            item = loc.next_migration()
            if item is None:
                return
            rid, kv, rem = item
            # reserve memory now; data lands after the transfer delay
            loc.kv_used += kv
            dur = self.costs[iid].transfer_time(kv)
            self._push(self._now + dur, self._on_migration_done, iid, rid, kv, rem)

    def _on_migration_done(self, iid: int, rid: int, kv: int, rem: int) -> None:
        req = self.requests[rid]
        src = req.prefill_instance
        if src is not None and src != iid:
            self.locals[src].release_prefill_kv(rid, kv)
            self._kick(src)
        loc = self.locals[iid]
        loc.kv_used -= kv                 # admit_migrated re-adds
        loc.admit_migrated(rid, kv, rem)
        req.state = RequestState.DECODING
        self._kick(iid)

    def _on_monitor_tick(self) -> None:
        now = self._now
        for iid, loc in self.locals.items():
            ready = getattr(self.policy, "prefill_ready_at", {}).get(iid, 0.0)
            s = InstanceStats(
                instance_id=iid,
                prefill_queue_len=len(loc.prefill_queue),
                prefill_backlog_tokens=loc.prefill_backlog_tokens,
                prefill_ready_at=ready,
                running_tokens=loc.running_tokens,
                n_decode_running=len(loc.decode_running),
                kv_tokens_used=loc.kv_used,
                kv_tokens_capacity=loc.kv_capacity,
            )
            self.monitor.update_stats(s)
        self.policy.on_monitor_tick(now)
        if self._heap:                     # keep ticking while events remain
            self._push(now + self.sched_cfg.monitor_interval,
                       self._on_monitor_tick)
