"""A stateless engine instance: real JAX compute (dense-family models), a
slot-granular KV cache and the Arrow local scheduler. "Stateless" in the
paper's sense — the instance carries no prefill/decode role; it executes
whatever sub-requests the global scheduler hands it.

Execution model (DESIGN.md §9): the LocalScheduler's mixed plan — the full
decode batch plus every prefill chunk — runs as ONE jitted call with
donated KV buffers (``repro.engine.fused_step``). ``dispatch_step`` launches
the call and returns immediately with the device-side token array;
``finalize_step`` performs the step's single blocking transfer and advances
the host bookkeeping, so a cluster can dispatch every instance before
fetching any — instances' steps overlap. ``step_mode="legacy"`` preserves
the pre-fusion per-rid path (per-request ``int(jnp.argmax(...))`` syncs,
functional cache copies, host pos_map round-trips) as the benchmark
baseline (benchmarks/bench_engine_step.py).
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.local_scheduler import LocalScheduler
from repro.engine import fused_step as fs
from repro.engine.state_slots import make_state_slots
from repro.models import build_model


class CorruptPayload(RuntimeError):
    """Typed transfer-integrity failure (DESIGN.md §14): the migration
    payload's checksum does not match what the exporter computed. Raised by
    ``import_state`` *before* any slot is allocated, so the importer's state
    is untouched; the cluster treats it as a failed transfer attempt and
    retries (source KV is retained until acknowledged)."""

    def __init__(self, iid: int, rid: int):
        super().__init__(f"instance {iid}: corrupt migration payload for "
                         f"rid {rid}")
        self.iid = iid
        self.rid = rid


def state_checksum(payload) -> int:
    """CRC32 over the migration payload's raw bytes, chained across arrays.
    Computed at ``export_state`` time and verified at ``import_state`` time —
    the end-to-end integrity check the §14 retry ladder keys off."""
    crc = 0
    for p in payload:
        crc = zlib.crc32(np.asarray(p).tobytes(), crc)
    return crc


class NoFreeSlots(RuntimeError):
    """Typed admission failure: the slot cache is full. Raised instead of
    the old ``assert slot is not None`` crash so callers (the cluster, the
    profiler) can keep the request queued and retry once a slot frees or a
    retained prefix is evicted."""

    def __init__(self, iid: int, rid: int):
        super().__init__(f"instance {iid}: no free KV slot for rid {rid}")
        self.iid = iid
        self.rid = rid


@dataclass
class ChunkWork:
    """One prefill chunk of a fused step."""

    rid: int
    offset: int
    length: int               # real tokens in the chunk
    tokens: np.ndarray        # the chunk's token ids, shape (length,)
    total_len: int            # the request's full prompt length


class PendingStep:
    """A dispatched step whose token array still lives on device. Groups
    are (chunks, device_tokens) pairs; when ``decode_in_group0`` the first
    group carries the decode batch's per-slot tokens stacked ahead of its
    chunk tokens. ``spec`` holds a speculative round's packed (B, k+2)
    ``[a, g_0..g_k]`` array. ``fetch`` is the step's blocking transfer;
    ``ready`` polls the device without blocking, which is what lets the
    cluster's async step collect finished instances while the rest keep
    computing."""

    def __init__(self, decode_rids: List[int],
                 groups: List[Tuple[List[ChunkWork], Any]],
                 spec: Any = None, decode_in_group0: bool = True):
        self.decode_rids = decode_rids
        self.groups = groups
        self.spec = spec
        self.decode_in_group0 = decode_in_group0

    def ready(self) -> bool:
        if self.spec is not None and not self.spec.is_ready():
            return False
        return all(arr.is_ready() for _, arr in self.groups)

    def fetch(self) -> Tuple[Optional[np.ndarray], List[np.ndarray]]:
        spec_np = None if self.spec is None else np.asarray(self.spec)
        parts = [arr for _, arr in self.groups]
        if not parts:
            return spec_np, []
        if len(parts) == 1:
            return spec_np, [np.asarray(parts[0])]
        # several padded-width groups: concatenate on device so the step
        # still pays exactly one blocking transfer
        flat = np.asarray(jnp.concatenate(parts))
        out, i = [], 0
        for p in parts:
            out.append(flat[i:i + p.shape[0]])
            i += p.shape[0]
        return spec_np, out


class _EagerStep:
    """Legacy-mode stand-in: results were computed synchronously."""

    def __init__(self, decode_out: Dict[int, int],
                 chunk_out: List[Tuple[int, Optional[int]]]):
        self.decode_out = decode_out
        self.chunk_out = chunk_out

    def ready(self) -> bool:
        return True


def _bucket32(n: int, cap: int) -> int:
    return min(-(-n // 32) * 32, cap)


class EngineInstance:
    def __init__(self, iid: int, cfg: ModelConfig, params, *,
                 n_slots: int = 8, capacity: int = 256,
                 chunk_tokens: Optional[int] = None,
                 step_mode: str = "fused", run_seed: int = 0,
                 speculate: int = 0, draft_layers: Optional[int] = None):
        assert cfg.family in ("dense", "ssm", "hybrid"), \
            f"no engine decode-state for family {cfg.family!r}"
        assert step_mode in ("fused", "legacy"), step_mode
        if cfg.family != "dense":
            assert step_mode == "fused", \
                "non-dense families have no legacy (pre-fusion) step path"
        self.run_seed = int(run_seed)
        self.speculate = int(speculate)
        self.draft_layers = (int(draft_layers) if draft_layers
                             else max(1, cfg.n_layers // 2))
        self.iid = iid
        self.cfg = cfg
        self.params = params
        self.model = build_model(cfg)
        self.capacity = capacity
        self.step_mode = step_mode
        self.kv = make_state_slots(cfg, n_slots, capacity)
        self._ops = fs.ops_for(cfg.family)
        if not self.kv.supports_speculation:
            # a rejected draft cannot roll back a recurrent state update,
            # so speculation is cleanly disabled for constant-state families
            # (DESIGN.md §13) — the stream is the plain sequential one
            self.speculate = 0
        if self.speculate:
            assert step_mode == "fused", \
                "self-speculative decoding requires the fused step path"
            assert 1 <= self.draft_layers < cfg.n_layers, \
                "draft_layers must be a strict truncation of the model"
        self.local = LocalScheduler(
            iid, token_budget=chunk_tokens or capacity,
            mixed_chunk_budget=chunk_tokens or 2048,
            kv_capacity_tokens=n_slots * capacity)
        if step_mode == "legacy":
            # pre-fusion per-instance jits (the benchmark baseline)
            self._prefill_fn = jax.jit(
                lambda p, b: self.model.prefill(p, b, cache_capacity=capacity))
            self._decode_fn = jax.jit(self.model.decode)
            from repro.models import dense as _dense
            self._chunk_fn = jax.jit(
                lambda p, cache, x, off: _dense.prefill_chunk(cfg, p, cache,
                                                              x, off))
        # request bookkeeping
        self.last_token: Dict[int, int] = {}
        self.generated: Dict[int, List[int]] = {}

    # ------------------------------------------------------------- slots
    def alloc_slot(self, rid: int) -> int:
        slot = self.kv.alloc(rid)
        if slot is None:
            raise NoFreeSlots(self.iid, rid)
        return slot

    # ---------------------------------------------------------- sampling
    def set_sampling(self, rid: int, sp) -> None:
        """Record a request's ``SamplingParams`` as slot state so it
        travels with the KV on migration/recovery. None/greedy clears to
        the default (exact argmax)."""
        if sp is None or sp.greedy:
            self.kv.samp_of.pop(rid, None)
        else:
            seed = self.run_seed if sp.seed is None else int(sp.seed)
            self.kv.samp_of[rid] = (float(sp.temperature), float(sp.top_p),
                                    seed)

    def _samp_of(self, rid: int) -> Tuple[float, float, int]:
        return self.kv.samp_of.get(rid, (0.0, 1.0, self.run_seed))

    def _slot_samp_arrays(self, decode_rids: List[int]):
        """Per-slot (temps, top_ps, seeds, rids) for a decode batch; rows
        whose slot is not decoding this step keep greedy defaults (their
        sampled token is never read)."""
        B = self.kv.n_slots
        temps = np.zeros((B,), np.float32)
        tops = np.ones((B,), np.float32)
        seeds = np.zeros((B,), np.int32)
        rids = np.zeros((B,), np.int32)
        for rid in decode_rids:
            s = self.kv.slot_of[rid]
            t, p, sd = self._samp_of(rid)
            temps[s], tops[s] = t, p
            seeds[s] = sd & 0x7FFFFFFF
            rids[s] = rid & 0x7FFFFFFF
        return temps, tops, seeds, rids

    def _sample_row(self, row, t: float, p: float, sd: int, rid: int,
                    pos: int) -> int:
        """Single-row selection for the legacy (eager) paths, via the same
        jitted sampler the fused step uses — legacy and fused streams stay
        bit-identical under sampling, not just under argmax."""
        return int(fs.sample_tokens(
            self.cfg, row[None],
            jnp.asarray([t], jnp.float32), jnp.asarray([p], jnp.float32),
            jnp.asarray([sd], jnp.int32), jnp.asarray([rid], jnp.int32),
            jnp.asarray([pos], jnp.int32))[0])

    # ----------------------------------------------------------- prefill
    def run_prefill(self, rid: int, prompt: np.ndarray) -> int:
        """Whole-prompt prefill; returns the first output token (o_1).
        Prompts are right-padded to 32-token buckets so jit traces are
        reused across lengths (causal masking keeps the live positions
        exact). Raises :class:`NoFreeSlots` when the cache is full."""
        S = len(prompt)
        if self.cfg.family != "dense":
            # constant-state families prefill via the chunk path: the slot
            # starts from zero recurrent state (the release invariant) and
            # the whole prompt scans as one fused chunk
            return self.run_prefill_chunk(rid, np.asarray(prompt, np.int32),
                                          0, S)
        S_pad = _bucket32(S, self.capacity)
        padded = np.zeros((S_pad,), np.int32)
        padded[:S] = prompt
        self.alloc_slot(rid)
        t, p, sd = self._samp_of(rid)
        sd &= 0x7FFFFFFF
        rid_m = rid & 0x7FFFFFFF
        if self.step_mode == "legacy":
            batch = {"tokens": jnp.asarray(padded)[None]}
            logits, cache = self._prefill_fn(self.params, batch)
            self.kv.place(rid, cache["k"][:, 0], cache["v"][:, 0], S)
            tok = self._sample_row(logits[0, S - 1], t, p, sd, rid_m, S - 1)
        else:
            s = self.kv.slot_of[rid]
            tok_arr, k, v, pm = fs.prefill_place(
                self.cfg, self.params, *self.kv.slabs(),
                jnp.asarray(padded), s, S, t, p, sd, rid_m)
            self.kv.swap(k, v, pm)
            self.kv.len_of[rid] = S
            tok = int(tok_arr)
        self.last_token[rid] = tok
        self.generated[rid] = [tok]
        return tok

    def begin_cached_prefill(self, rid: int, src_rid: int,
                             cached_len: int) -> None:
        """Prefix reuse (DESIGN.md §7): seed ``rid``'s slot with the first
        ``cached_len`` positions of ``src_rid``'s retained KV; subsequent
        chunks start at ``offset == cached_len``."""
        self.alloc_slot(rid)
        self.kv.copy_prefix(src_rid, rid, cached_len)

    def run_prefill_chunk(self, rid: int, chunk: np.ndarray, offset: int,
                          total_len: int) -> Optional[int]:
        """Chunked prefill (§5.4): process prompt tokens [offset, offset+len)
        against this request's slot cache. Returns o_1 on the final chunk,
        else None. (Single-chunk convenience over dispatch/finalize.)"""
        if rid not in self.kv.slot_of:
            if offset != 0:
                # a mid-prompt chunk against an unseeded slot would attend
                # garbage — fail loudly (seed via begin_cached_prefill or
                # earlier chunks), matching the pre-fusion KeyError
                raise KeyError(
                    f"rid {rid} has no KV slot at chunk offset {offset}")
            self.alloc_slot(rid)
        cw = ChunkWork(rid, offset, len(chunk),
                       np.asarray(chunk, np.int32), total_len)
        pending = self.dispatch_step([], [cw])
        _, chunk_out = self.finalize_step(pending)
        return chunk_out[0][1]

    # ------------------------------------------------------------ decode
    def run_decode_iteration(self, rids: List[int]) -> Dict[int, int]:
        """One token for each running request. Returns rid -> token.
        (Decode-only convenience over dispatch/finalize.)"""
        if not rids:
            return {}
        pending = self.dispatch_step(list(rids), [])
        decode_out, _ = self.finalize_step(pending)
        return decode_out

    # ------------------------------------------------------- fused step
    def dispatch_step(self, decode_rids: List[int],
                      chunks: Sequence[ChunkWork]):
        """Launch this instance's whole iteration — the decode batch plus
        every prefill chunk — on device and return without blocking. The
        KV slabs are donated into the call and swapped for the aliased
        outputs immediately; token ids stay on device until
        :meth:`finalize_step`."""
        if not decode_rids and not chunks:
            return None
        if self.step_mode == "legacy":
            return self._legacy_step(decode_rids, chunks)
        dec_args = None
        spec_arr = None
        # speculative round: every active row must fit its k drafts plus
        # the bonus token; otherwise fall back to plain decode this step
        use_spec = bool(self.speculate and decode_rids and
                        all(self.kv.len_of[r] + self.speculate + 1
                            <= self.capacity for r in decode_rids))
        if decode_rids:
            B = self.kv.n_slots
            tokens = np.zeros((B, 1), np.int32)
            pos = np.zeros((B,), np.int32)
            active = np.zeros((B,), bool)
            # Inactive-but-occupied slots (e.g. parked awaiting migration)
            # still get a batched dummy write; aim it at the slot's own next
            # position, which any real future decode/chunk overwrites before
            # attending to it. (The speculative path instead masks parked
            # rows out via ``active`` and writes them back untouched.)
            for rid, s in self.kv.slot_of.items():
                pos[s] = min(self.kv.len_of.get(rid, 0), self.capacity - 1)
            for rid in decode_rids:
                s = self.kv.slot_of[rid]
                tokens[s, 0] = self.last_token[rid]
                pos[s] = self.kv.len_of[rid]
                active[s] = True
            samp = tuple(jnp.asarray(a)
                         for a in self._slot_samp_arrays(decode_rids))
            if use_spec:
                spec_arr, k, v, pm = fs.spec_decode(
                    self.cfg, self.draft_layers, self.speculate,
                    self.params, *self.kv.slabs(), jnp.asarray(tokens),
                    jnp.asarray(pos), *samp, jnp.asarray(active))
                self.kv.swap(k, v, pm)
            else:
                dec_args = (jnp.asarray(tokens), jnp.asarray(pos)) + samp
                if self.kv.needs_active_mask:
                    # recurrent state has no harmless dummy-write: parked
                    # slots are masked out inside the fused step instead
                    dec_args += (jnp.asarray(active),)
        groups: List[Tuple[List[ChunkWork], Any]] = []
        for gi, (Sq, group) in enumerate(self._group_chunks(chunks)):
            n = len(group)
            ctoks = np.zeros((n, Sq), np.int32)
            slots = np.zeros((n,), np.int32)
            offsets = np.zeros((n,), np.int32)
            lens = np.zeros((n,), np.int32)
            ctemps = np.zeros((n,), np.float32)
            ctops = np.ones((n,), np.float32)
            cseeds = np.zeros((n,), np.int32)
            crids = np.zeros((n,), np.int32)
            for i, cw in enumerate(group):
                ctoks[i, :cw.length] = cw.tokens
                slots[i] = self.kv.slot_of[cw.rid]
                offsets[i] = cw.offset
                lens[i] = cw.length
                t, p, sd = self._samp_of(cw.rid)
                ctemps[i], ctops[i] = t, p
                cseeds[i] = sd & 0x7FFFFFFF
                crids[i] = cw.rid & 0x7FFFFFFF
            c_args = (jnp.asarray(ctoks), jnp.asarray(slots),
                      jnp.asarray(offsets), jnp.asarray(lens),
                      jnp.asarray(ctemps), jnp.asarray(ctops),
                      jnp.asarray(cseeds), jnp.asarray(crids))
            if gi == 0 and dec_args is not None:
                out = self._ops.mixed_step(
                    self.cfg, self.params, *self.kv.slabs(), *dec_args,
                    *c_args)
            else:
                out = self._ops.chunks_only(
                    self.cfg, self.params, *self.kv.slabs(), *c_args)
            self.kv.swap(*out[1:])
            groups.append((group, out[0]))
        if not groups and dec_args is not None:
            out = self._ops.decode_only(
                self.cfg, self.params, *self.kv.slabs(), *dec_args)
            self.kv.swap(*out[1:])
            groups.append(([], out[0]))
        return PendingStep(list(decode_rids), groups, spec=spec_arr,
                           decode_in_group0=dec_args is not None)

    def finalize_step(self, pending) -> Tuple[Dict[int, Any],
                                              List[Tuple[int, Optional[int]]]]:
        """Fetch the step's stacked token array (the one blocking transfer)
        and advance host bookkeeping. Returns (decode rid->token — or
        rid->[tokens] for a speculative round — and per-chunk (rid,
        o_1|None) in dispatch order)."""
        if pending is None:
            return {}, []
        if isinstance(pending, _EagerStep):
            return pending.decode_out, pending.chunk_out
        decode_out: Dict[int, Any] = {}
        chunk_out: List[Tuple[int, Optional[int]]] = []
        spec_np, arrays = pending.fetch()
        if spec_np is not None:
            for rid in pending.decode_rids:
                s = self.kv.slot_of[rid]
                a = int(spec_np[s, 0])
                toks = [int(x) for x in spec_np[s, 1:a + 2]]
                self.kv.advance(rid, len(toks))
                self.last_token[rid] = toks[-1]
                self.generated[rid].extend(toks)
                decode_out[rid] = toks
        for gi, ((group, _), a) in enumerate(zip(pending.groups, arrays)):
            base = 0
            if gi == 0 and pending.decode_in_group0 and pending.decode_rids:
                for rid in pending.decode_rids:
                    s = self.kv.slot_of[rid]
                    tok = int(a[s])
                    self.kv.advance(rid)
                    self.last_token[rid] = tok
                    self.generated[rid].append(tok)
                    decode_out[rid] = tok
                base = self.kv.n_slots
            for i, cw in enumerate(group):
                end = cw.offset + cw.length
                if end >= cw.total_len:
                    self.kv.len_of[cw.rid] = cw.total_len
                    tok = int(a[base + i])
                    self.last_token[cw.rid] = tok
                    self.generated[cw.rid] = [tok]
                    chunk_out.append((cw.rid, tok))
                else:
                    self.kv.len_of[cw.rid] = end
                    chunk_out.append((cw.rid, None))
        return decode_out, chunk_out

    def _group_chunks(self, chunks: Sequence[ChunkWork]
                      ) -> List[Tuple[int, List[ChunkWork]]]:
        """Group the plan's chunks by padded width so each group scans with
        one static shape. A chunk's width is its 32-bucket clipped to the
        slot tail (offset + width <= capacity), so the in-jit
        dynamic_update_slice can never clamp; in the common case every
        chunk shares one bucket and the step is a single call."""
        by_w: Dict[int, List[ChunkWork]] = {}
        for cw in chunks:
            w = min(_bucket32(cw.length, self.capacity),
                    self.capacity - cw.offset)
            by_w.setdefault(w, []).append(cw)
        return [(w, g) for w, g in by_w.items()]

    # -------------------------------------------------- legacy baseline
    def _legacy_step(self, decode_rids: List[int],
                     chunks: Sequence[ChunkWork]) -> _EagerStep:
        decode_out = self._legacy_decode(decode_rids) if decode_rids else {}
        chunk_out = [(cw.rid, self._legacy_chunk(cw)) for cw in chunks]
        return _EagerStep(decode_out, chunk_out)

    def _legacy_decode(self, rids: List[int]) -> Dict[int, int]:
        """Pre-fusion decode, kept verbatim as the bench baseline: the
        full-cache functional copy (no donation) plus an eager logits
        fetch per iteration."""
        B = self.kv.n_slots
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        for rid, s in self.kv.slot_of.items():
            pos[s] = min(self.kv.len_of.get(rid, 0), self.capacity - 1)
        for rid in rids:
            s = self.kv.slot_of[rid]
            tokens[s, 0] = self.last_token[rid]
            pos[s] = self.kv.len_of[rid]
            active[s] = True
        batch = {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos)}
        logits, cache = self._decode_fn(self.params,
                                        self.kv.as_model_cache(), batch)
        self.kv.update_from_model_cache(cache)
        out: Dict[int, int] = {}
        temps, tops, seeds, rids_arr = self._slot_samp_arrays(rids)
        arg = np.asarray(fs.sample_tokens(
            self.cfg, logits[:, 0], jnp.asarray(temps), jnp.asarray(tops),
            jnp.asarray(seeds), jnp.asarray(rids_arr), jnp.asarray(pos)))
        for rid in rids:
            s = self.kv.slot_of[rid]
            tok = int(arg[s])
            self.kv.advance(rid)
            self.last_token[rid] = tok
            self.generated[rid].append(tok)
            out[rid] = tok
        return out

    def _legacy_chunk(self, cw: ChunkWork) -> Optional[int]:
        """Pre-fusion chunked prefill: per-chunk host pos_map round-trip
        (writable np copy + three ``.at[].set`` writes back)."""
        from repro.models import dense as _dense
        rid, offset, ln = cw.rid, cw.offset, cw.length
        s = self.kv.slot_of[rid]
        ln_pad = min(-(-ln // 32) * 32, self.capacity - offset)
        padded = np.zeros((ln_pad,), np.int32)
        padded[:ln] = cw.tokens
        x = _dense.embed_tokens(self.cfg, self.params,
                                jnp.asarray(padded)[None])
        sub = {"k": self.kv.k[:, s:s + 1], "v": self.kv.v[:, s:s + 1],
               "pos_map": self.kv.pos_map[s:s + 1]}
        logits, sub = self._chunk_fn(self.params, sub, x, jnp.int32(offset))
        # write back; invalidate pad positions in the pos_map
        pm = np.array(sub["pos_map"][0])          # writable copy
        pm[offset + ln: offset + ln_pad] = -1
        self.kv.k = self.kv.k.at[:, s].set(sub["k"][:, 0])
        self.kv.v = self.kv.v.at[:, s].set(sub["v"][:, 0])
        self.kv.pos_map = self.kv.pos_map.at[s].set(jnp.asarray(pm))
        self.kv.len_of[rid] = offset + ln
        if offset + ln >= cw.total_len:
            self.kv.len_of[rid] = cw.total_len
            t, p, sd = self._samp_of(rid)
            tok = self._sample_row(logits[0, ln - 1], t, p,
                                   sd & 0x7FFFFFFF, rid & 0x7FFFFFFF,
                                   offset + ln - 1)
            self.last_token[rid] = tok
            self.generated[rid] = [tok]
            return tok
        return None

    # --------------------------------------------------------- transfer
    def export_state(self, rid: int):
        """Family-agnostic migration export: (payload host arrays, context
        length, last token, generated tokens). ``sum(p.nbytes)`` over the
        payload is the real wire size — O(L) for dense, O(1) for ssm/hybrid
        (DESIGN.md §13)."""
        payload, L = self.kv.extract_state(rid)
        return payload, L, self.last_token[rid], self.generated[rid]

    def import_state(self, rid: int, payload, L: int, last_token: int,
                     generated: List[int], sampling=None,
                     checksum: Optional[int] = None) -> bool:
        # Verify before alloc so a corrupt payload leaves the importer's
        # state untouched and the sender can simply retry (DESIGN.md §14).
        if checksum is not None and state_checksum(payload) != checksum:
            raise CorruptPayload(self.iid, rid)
        if self.kv.alloc(rid) is None:
            return False
        if sampling is not None:
            # the source slot's sampling state rides along with the state,
            # so a migrated stream keeps its key derivation (DESIGN.md §12)
            self.kv.samp_of[rid] = tuple(sampling)
        self.kv.place_state(rid, payload, L)
        self.last_token[rid] = last_token
        self.generated[rid] = list(generated)
        return True

    def export_kv(self, rid: int):
        """Dense-layout export kept for compatibility (tests, tools)."""
        k, v, L = self.kv.extract(rid)
        return k, v, L, self.last_token[rid], self.generated[rid]

    def import_kv(self, rid: int, k, v, L: int, last_token: int,
                  generated: List[int], sampling=None) -> bool:
        return self.import_state(rid, [k, v], L, last_token, generated,
                                 sampling=sampling)

    def drop(self, rid: int) -> None:
        if rid in self.kv.slot_of:
            self.kv.release(rid)
        self.last_token.pop(rid, None)

    # -------------------------------------------------------- profiling
    def profile_prefill(self, lengths=(16, 32, 64, 128)) -> List[Tuple[int, float]]:
        """Real wall-clock profiling pass for the TTFT predictor (paper §5.3:
        'profiles each instance's prefill processing capability when the
        cluster is first launched'). Raises :class:`NoFreeSlots` when asked
        to profile an instance whose slot cache is already full."""
        if not self.kv.free:
            raise NoFreeSlots(self.iid, -1)
        samples = []
        for L in lengths:
            if L > self.capacity:
                continue
            prompt = np.ones((L,), np.int32)
            self.run_prefill(-1, prompt)          # warm-up/compile
            self.drop(-1)
            t0 = time.perf_counter()
            self.run_prefill(-1, prompt)
            dt = time.perf_counter() - t0
            self.drop(-1)
            samples.append((L, dt))
        return samples
