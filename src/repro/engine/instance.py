"""A stateless engine instance: real JAX compute (dense-family models), a
slot-granular KV cache and the Arrow local scheduler. "Stateless" in the
paper's sense — the instance carries no prefill/decode role; it executes
whatever sub-requests the global scheduler hands it."""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.local_scheduler import LocalScheduler
from repro.engine.kv_slots import SlotKVCache
from repro.models import build_model


class EngineInstance:
    def __init__(self, iid: int, cfg: ModelConfig, params, *,
                 n_slots: int = 8, capacity: int = 256,
                 chunk_tokens: Optional[int] = None):
        assert cfg.family in ("dense",), \
            "real engine path supports dense-family; other families are " \
            "served via the simulator cost model (DESIGN.md §2)"
        self.iid = iid
        self.cfg = cfg
        self.params = params
        self.model = build_model(cfg)
        self.capacity = capacity
        self.kv = SlotKVCache(cfg.n_layers, n_slots, capacity,
                              cfg.n_kv_heads, cfg.head_dim_,
                              jnp.dtype(cfg.dtype))
        self.local = LocalScheduler(
            iid, token_budget=chunk_tokens or capacity,
            mixed_chunk_budget=chunk_tokens or 2048,
            kv_capacity_tokens=n_slots * capacity)
        self._prefill_fn = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_capacity=capacity))
        self._decode_fn = jax.jit(self.model.decode)
        from repro.models import dense as _dense
        self._chunk_fn = jax.jit(
            lambda p, cache, x, off: _dense.prefill_chunk(cfg, p, cache, x, off))
        # request bookkeeping
        self.last_token: Dict[int, int] = {}
        self.generated: Dict[int, List[int]] = {}

    # ----------------------------------------------------------- prefill
    def run_prefill(self, rid: int, prompt: np.ndarray) -> int:
        """Whole-prompt prefill; returns the first output token (o_1).
        Prompts are right-padded to 32-token buckets so jit traces are reused
        across lengths (causal masking keeps the live positions exact)."""
        S = len(prompt)
        S_pad = min(-(-S // 32) * 32, self.capacity)
        padded = np.zeros((S_pad,), np.int32)
        padded[:S] = prompt
        batch = {"tokens": jnp.asarray(padded)[None]}
        logits, cache = self._prefill_fn(self.params, batch)
        slot = self.kv.alloc(rid)
        assert slot is not None, "no free KV slots"
        self.kv.place(rid, cache["k"][:, 0], cache["v"][:, 0], S)
        tok = int(jnp.argmax(logits[0, S - 1, :self.cfg.vocab_size]))
        self.last_token[rid] = tok
        self.generated[rid] = [tok]
        return tok

    def begin_cached_prefill(self, rid: int, src_rid: int,
                             cached_len: int) -> None:
        """Prefix reuse (DESIGN.md §7): seed ``rid``'s slot with the first
        ``cached_len`` positions of ``src_rid``'s retained KV; subsequent
        ``run_prefill_chunk`` calls start at ``offset == cached_len``."""
        slot = self.kv.alloc(rid)
        assert slot is not None, "no free KV slots for cached prefill"
        self.kv.copy_prefix(src_rid, rid, cached_len)

    def run_prefill_chunk(self, rid: int, chunk: np.ndarray, offset: int,
                          total_len: int) -> Optional[int]:
        """Chunked prefill (§5.4): process prompt tokens [offset, offset+len)
        against this request's slot cache. Returns o_1 on the final chunk,
        else None. Chunk lengths are bucketed to 32 for jit reuse."""
        from repro.models import dense as _dense
        if offset == 0:
            slot = self.kv.alloc(rid)
            assert slot is not None, "no free KV slots"
        s = self.kv.slot_of[rid]
        ln = len(chunk)
        ln_pad = min(-(-ln // 32) * 32, self.capacity - offset)
        padded = np.zeros((ln_pad,), np.int32)
        padded[:ln] = chunk
        x = _dense.embed_tokens(self.cfg, self.params,
                                jnp.asarray(padded)[None])
        sub = {"k": self.kv.k[:, s:s + 1], "v": self.kv.v[:, s:s + 1],
               "pos_map": self.kv.pos_map[s:s + 1]}
        logits, sub = self._chunk_fn(self.params, sub, x,
                                     jnp.int32(offset))
        # write back; invalidate pad positions in the pos_map
        pm = np.array(sub["pos_map"][0])          # writable copy
        pm[offset + ln: offset + ln_pad] = -1
        self.kv.k = self.kv.k.at[:, s].set(sub["k"][:, 0])
        self.kv.v = self.kv.v.at[:, s].set(sub["v"][:, 0])
        self.kv.pos_map = self.kv.pos_map.at[s].set(jnp.asarray(pm))
        # progress marker (also keeps the batched dummy-write in
        # run_decode_iteration aimed at the next — about to be overwritten —
        # position while this request is mid-prefill)
        self.kv.len_of[rid] = offset + ln
        if offset + ln >= total_len:
            self.kv.len_of[rid] = total_len
            tok = int(jnp.argmax(logits[0, ln - 1, :self.cfg.vocab_size]))
            self.last_token[rid] = tok
            self.generated[rid] = [tok]
            return tok
        return None

    # ------------------------------------------------------------ decode
    def run_decode_iteration(self, rids: List[int]) -> Dict[int, int]:
        """One token for each running request. Returns rid -> token."""
        if not rids:
            return {}
        B = self.kv.n_slots
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        # Inactive-but-occupied slots (e.g. parked awaiting migration) still
        # get a batched dummy write; aim it at the slot's own next position,
        # which any real future decode overwrites before attending to it.
        for rid, s in self.kv.slot_of.items():
            pos[s] = min(self.kv.len_of.get(rid, 0), self.capacity - 1)
        for rid in rids:
            s = self.kv.slot_of[rid]
            tokens[s, 0] = self.last_token[rid]
            pos[s] = self.kv.len_of[rid]
            active[s] = True
        batch = {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos)}
        logits, cache = self._decode_fn(self.params,
                                        self.kv.as_model_cache(), batch)
        self.kv.update_from_model_cache(cache)
        out: Dict[int, int] = {}
        arg = np.asarray(jnp.argmax(logits[:, 0, :self.cfg.vocab_size], axis=-1))
        for rid in rids:
            s = self.kv.slot_of[rid]
            tok = int(arg[s])
            self.kv.advance(rid)
            self.last_token[rid] = tok
            self.generated[rid].append(tok)
            out[rid] = tok
        return out

    # --------------------------------------------------------- transfer
    def export_kv(self, rid: int):
        k, v, L = self.kv.extract(rid)
        return np.asarray(k), np.asarray(v), L, self.last_token[rid], \
            self.generated[rid]

    def import_kv(self, rid: int, k, v, L: int, last_token: int,
                  generated: List[int]) -> bool:
        slot = self.kv.alloc(rid)
        if slot is None:
            return False
        self.kv.place(rid, jnp.asarray(k), jnp.asarray(v), L)
        self.last_token[rid] = last_token
        self.generated[rid] = list(generated)
        return True

    def drop(self, rid: int) -> None:
        if rid in self.kv.slot_of:
            self.kv.release(rid)
        self.last_token.pop(rid, None)

    # -------------------------------------------------------- profiling
    def profile_prefill(self, lengths=(16, 32, 64, 128)) -> List[Tuple[int, float]]:
        """Real wall-clock profiling pass for the TTFT predictor (paper §5.3:
        'profiles each instance's prefill processing capability when the
        cluster is first launched')."""
        samples = []
        for L in lengths:
            if L > self.capacity:
                continue
            prompt = np.ones((L,), np.int32)
            self.run_prefill(-1, prompt)          # warm-up/compile
            self.drop(-1)
            t0 = time.perf_counter()
            self.run_prefill(-1, prompt)
            dt = time.perf_counter() - t0
            self.drop(-1)
            samples.append((L, dt))
        return samples
