from repro.engine.cluster import ArrowEngineCluster, ServeRequest  # noqa: F401
from repro.engine.instance import (ChunkWork, EngineInstance,  # noqa: F401
                                   NoFreeSlots)
from repro.engine.kv_slots import SlotKVCache  # noqa: F401
