from repro.engine.cluster import ArrowEngineCluster, ServeRequest  # noqa: F401
from repro.engine.instance import EngineInstance  # noqa: F401
from repro.engine.kv_slots import SlotKVCache  # noqa: F401
