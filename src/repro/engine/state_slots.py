"""Per-architecture decode-state slots: the engine's cache contract.

The engine's unit of admission is a *slot*; what a slot holds depends on the
model family (DESIGN.md §13):

  dense   — per-token KV rings (``SlotKVCache``): state grows O(L) with
            context, prefix reuse works at block granularity, migration
            moves tokens × per-token-KV bytes.
  ssm     — Mamba-2 ``{"conv", "ssm"}`` slabs (``SSMStateSlots``): state is
            O(1) in context, so migration is constant-cost and "prefix"
            reuse only makes sense for an exact full-length match (the
            recurrent state is a lossy summary — there is no per-position
            KV to truncate).
  hybrid  — RecurrentGemma conv/h recurrences plus fixed local-attention
            rings (``RecurrentStateSlots``): same O(1) economics as ssm.

Every implementation keeps the host bookkeeping (free list, rid -> slot /
context length / sampling params) and the device invariants the fused step
relies on: mutating slot ops are jitted with **donated** slabs, a released
slot's recurrent state is zeroed (so the next occupant chunks from a zero
state), and ``extract_state``/``place_state`` give the cluster a
family-agnostic migration transfer (the payload's ``nbytes`` is the real
wire cost that ``RuntimeCore`` records).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig

# ------------------------------------------------- per-leaf slot ops (jitted)


@partial(jax.jit, static_argnums=(2,), donate_argnums=(0,))
def _zero_slot(a, slot, axis):
    shape = list(a.shape)
    shape[axis] = 1
    return lax.dynamic_update_slice_in_dim(a, jnp.zeros(shape, a.dtype),
                                           slot, axis)


@partial(jax.jit, static_argnums=(2,))
def _take_slot(a, slot, axis):
    return lax.dynamic_index_in_dim(a, slot, axis, keepdims=False)


@partial(jax.jit, static_argnums=(3,), donate_argnums=(0,))
def _put_slot(a, row, slot, axis):
    return lax.dynamic_update_slice_in_dim(a, jnp.expand_dims(row, axis),
                                           slot, axis)


@partial(jax.jit, static_argnums=(3,), donate_argnums=(0,))
def _copy_slot(a, src, dst, axis):
    return lax.dynamic_update_slice_in_dim(
        a, lax.dynamic_slice_in_dim(a, src, 1, axis), dst, axis)


# ---------------------------------------------------------------------- base


class StateSlotsBase:
    """Host bookkeeping shared by every decode-state implementation, plus
    the per-architecture capability flags the scheduling layer reads."""

    #: "block" — per-token KV, any block-aligned prefix is reusable;
    #: "exact" — constant-size recurrent state, only a full-length match.
    prefix_reuse: str = "exact"
    #: recurrent updates are irreversible, so parked slots must be masked
    #: out of the fused decode instead of receiving dummy writes, and
    #: rejected speculation cannot roll the state back.
    needs_active_mask: bool = True
    supports_speculation: bool = False

    def __init__(self, n_slots: int, capacity: int):
        self.n_slots = n_slots
        self.capacity = capacity
        self.free = list(range(n_slots))
        self.slot_of: Dict[int, int] = {}       # rid -> slot
        self.len_of: Dict[int, int] = {}        # rid -> context length
        # rid -> (temperature, top_p, seed): sampling state is part of the
        # slot's serving state so it travels with the state on migration and
        # crash recovery (DESIGN.md §12); absent rid ≡ greedy
        self.samp_of: Dict[int, Tuple[float, float, int]] = {}

    # ------------------------------------------------------------- alloc
    def alloc(self, rid: int) -> Optional[int]:
        if not self.free:
            return None
        s = self.free.pop()
        self.slot_of[rid] = s
        return s

    def release(self, rid: int) -> None:
        s = self.slot_of.pop(rid)
        self.len_of.pop(rid, None)
        self.samp_of.pop(rid, None)
        self._clear_slot(s)
        self.free.append(s)

    def advance(self, rid: int, n: int = 1) -> None:
        self.len_of[rid] += n

    # ----------------------------------------------------- device contract
    def slabs(self) -> tuple:
        """The donated arguments of a fused step, in the order the family's
        fused-step entry points expect them. The caller owns putting the
        returned slabs back via :meth:`swap` — after a donating call the
        previous buffers are dead."""
        raise NotImplementedError

    def swap(self, *slabs) -> None:
        raise NotImplementedError

    def _clear_slot(self, slot: int) -> None:
        """Restore the released slot to the freshly-initialized state (zero
        recurrent state / invalid positions) so the next occupant's chunked
        prefill starts clean."""
        raise NotImplementedError

    # --------------------------------------------------------- migration
    def extract_state(self, rid: int) -> Tuple[List[np.ndarray], int]:
        """(payload host arrays, context length) — the family-agnostic
        migration export; ``sum(p.nbytes for p in payload)`` is the real
        transfer size."""
        raise NotImplementedError

    def place_state(self, rid: int, payload: List[np.ndarray],
                    length: int) -> None:
        """Inverse of :meth:`extract_state` into ``rid``'s allocated slot."""
        raise NotImplementedError

    def state_bytes(self, rid: int) -> int:
        """Bytes a migration of ``rid`` moves right now."""
        raise NotImplementedError

    def copy_prefix(self, src_rid: int, dst_rid: int, length: int) -> None:
        raise NotImplementedError


# ------------------------------------------------------------ ssm (Mamba-2)


class SSMStateSlots(StateSlotsBase):
    """Fixed-size ``{"conv": (L, B, W-1, Ch), "ssm": (L, B, H, P, N)}``
    slabs — O(1) bytes per slot regardless of context length."""

    def __init__(self, cfg: ModelConfig, n_slots: int, capacity: int):
        super().__init__(n_slots, capacity)
        from repro.models import ssm as ssm_mod
        cache = ssm_mod.init_cache(cfg, n_slots)
        self.conv = cache["conv"]
        self.ssm = cache["ssm"]

    def slabs(self):
        return self.conv, self.ssm

    def swap(self, conv, ssm) -> None:
        self.conv, self.ssm = conv, ssm

    def _clear_slot(self, slot: int) -> None:
        self.conv = _zero_slot(self.conv, slot, 1)
        self.ssm = _zero_slot(self.ssm, slot, 1)

    def extract_state(self, rid: int):
        s = self.slot_of[rid]
        payload = [np.asarray(_take_slot(self.conv, s, 1)),
                   np.asarray(_take_slot(self.ssm, s, 1))]
        return payload, self.len_of[rid]

    def place_state(self, rid: int, payload, length: int) -> None:
        s = self.slot_of[rid]
        conv_row, ssm_row = payload
        self.conv = _put_slot(self.conv, jnp.asarray(conv_row, self.conv.dtype),
                              s, 1)
        self.ssm = _put_slot(self.ssm, jnp.asarray(ssm_row, self.ssm.dtype),
                             s, 1)
        self.len_of[rid] = length

    def state_bytes(self, rid: int) -> int:
        return (self.conv.nbytes + self.ssm.nbytes) // self.n_slots

    def copy_prefix(self, src_rid: int, dst_rid: int, length: int) -> None:
        # exact-prefix only: the recurrent state *is* the whole context
        assert length == self.len_of[src_rid], (length, self.len_of[src_rid])
        s, d = self.slot_of[src_rid], self.slot_of[dst_rid]
        self.conv = _copy_slot(self.conv, s, d, 1)
        self.ssm = _copy_slot(self.ssm, s, d, 1)
        self.len_of[dst_rid] = length


# ------------------------------------------- hybrid (RecurrentGemma/Griffin)


class RecurrentStateSlots(StateSlotsBase):
    """The whole hybrid decode cache with batch == ``n_slots``: conv/h
    recurrences plus the fixed-size local-attention k/v rings and their
    ``pos_map``. Rings are bounded by the local window, so state is O(1) in
    context length, same as ssm."""

    def __init__(self, cfg: ModelConfig, n_slots: int, capacity: int):
        super().__init__(n_slots, capacity)
        from repro.models import hybrid as hyb_mod
        self.cache = hyb_mod.init_cache(cfg, n_slots, capacity)

    def slabs(self):
        return (self.cache,)

    def swap(self, cache) -> None:
        self.cache = cache

    def _leaves(self):
        """Deterministic (section, key, axis-of-slot) walk of the cache."""
        for k in sorted(self.cache["groups"]):
            yield "groups", k, 1
        yield None, "pos_map", 0
        if "tail" in self.cache:
            for k in sorted(self.cache["tail"]):
                yield "tail", k, 1

    def _get(self, sec, key):
        return self.cache[key] if sec is None else self.cache[sec][key]

    def _set(self, sec, key, value) -> None:
        if sec is None:
            self.cache[key] = value
        else:
            self.cache[sec][key] = value

    def _clear_slot(self, slot: int) -> None:
        for sec, key, axis in self._leaves():
            a = self._get(sec, key)
            if key == "pos_map":
                row = jnp.full((a.shape[1],), -1, jnp.int32)
                a = _put_slot(a, row, slot, axis)
            else:
                a = _zero_slot(a, slot, axis)
            self._set(sec, key, a)

    def extract_state(self, rid: int):
        s = self.slot_of[rid]
        payload = [np.asarray(_take_slot(self._get(sec, key), s, axis))
                   for sec, key, axis in self._leaves()]
        return payload, self.len_of[rid]

    def place_state(self, rid: int, payload, length: int) -> None:
        s = self.slot_of[rid]
        for (sec, key, axis), row in zip(self._leaves(), payload):
            a = self._get(sec, key)
            self._set(sec, key, _put_slot(a, jnp.asarray(row, a.dtype), s,
                                          axis))
        self.len_of[rid] = length

    def state_bytes(self, rid: int) -> int:
        return sum(self._get(sec, key).nbytes
                   for sec, key, _ in self._leaves()) // self.n_slots

    def copy_prefix(self, src_rid: int, dst_rid: int, length: int) -> None:
        assert length == self.len_of[src_rid], (length, self.len_of[src_rid])
        s, d = self.slot_of[src_rid], self.slot_of[dst_rid]
        for sec, key, axis in self._leaves():
            self._set(sec, key, _copy_slot(self._get(sec, key), s, d, axis))
        self.len_of[dst_rid] = length


# ------------------------------------------------------------------ factory


def make_state_slots(cfg: ModelConfig, n_slots: int, capacity: int
                     ) -> StateSlotsBase:
    """Decode-state slots for ``cfg.family`` (the engine's cache seam)."""
    if cfg.family == "dense":
        from repro.engine.kv_slots import SlotKVCache
        return SlotKVCache(cfg.n_layers, n_slots, capacity, cfg.n_kv_heads,
                           cfg.head_dim_, jnp.dtype(cfg.dtype))
    if cfg.family == "ssm":
        return SSMStateSlots(cfg, n_slots, capacity)
    if cfg.family == "hybrid":
        return RecurrentStateSlots(cfg, n_slots, capacity)
    raise NotImplementedError(f"no decode-state slots for family "
                              f"{cfg.family!r}")
