"""Fused per-instance engine step: one jitted call with donated KV buffers
(DESIGN.md §9).

Every function here takes the ``ModelConfig`` as a *static* jit argument
(it is a frozen, hashable dataclass), so traces are shared across all
``EngineInstance``s of a cluster — and across clusters — instead of each
instance re-jitting its own closures. An elastic spawn (§6) therefore
starts with a warm jit cache.

The SlotKVCache slabs (``k``, ``v``, ``pos_map``) are **donated**: XLA
aliases them with the corresponding outputs, so the multi-MB cache updates
in place every step instead of being functionally copied. Callers must
immediately replace their references with the returned slabs
(``SlotKVCache.swap``) — the donated inputs are dead after the call.

Token selection (greedy argmax) stays on device; each entry point returns a
single stacked int32 token array per step, which the instance fetches with
one blocking transfer at finalize time so concurrent instances' steps
overlap.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import dense


def _decode_core(cfg, params, k, v, pos_map, tokens, pos):
    """Batched decode over every slot (active rows carry real tokens,
    parked slots get the dummy write at their own next position — see
    EngineInstance.dispatch_step). Returns per-slot argmax tokens."""
    x = dense.embed_tokens(cfg, params, tokens)
    logits, cache = dense.decode_step(
        cfg, params, {"k": k, "v": v, "pos_map": pos_map}, x, pos)
    toks = jnp.argmax(logits[:, 0, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    return toks, cache["k"], cache["v"], cache["pos_map"]


def _chunk_scan(cfg, params, k, v, pos_map, toks, slots, offsets, lens):
    """Run every prefill chunk of the plan against its own slot, scanned
    sequentially inside the jit (chunks target distinct slots, so the order
    only matters vs the decode dummy-writes, which ran first). ``toks`` is
    (N, Sq) bucket-padded chunk tokens; ``slots``/``offsets``/``lens`` are
    (N,) i32. Pad-position invalidation is folded in here — no host copy of
    the pos_map remains (ISSUE 5 satellite). Returns the per-chunk argmax
    at each chunk's last real token (meaningful only for final chunks; the
    host decides which)."""
    C = pos_map.shape[1]
    Sq = toks.shape[1]
    idx = jnp.arange(C, dtype=jnp.int32)

    def body(carry, xs):
        k, v, pos_map = carry
        t, s, off, ln = xs
        x = dense.embed_tokens(cfg, params, t[None])
        sub = {"k": lax.dynamic_slice_in_dim(k, s, 1, 1),
               "v": lax.dynamic_slice_in_dim(v, s, 1, 1),
               "pos_map": lax.dynamic_slice_in_dim(pos_map, s, 1, 0)}
        logits, sub = dense.prefill_chunk(cfg, params, sub, x, off)
        # bucket padding [off+ln, off+Sq) never becomes valid KV
        row = jnp.where((idx >= off + ln) & (idx < off + Sq), -1,
                        sub["pos_map"][0])
        k = lax.dynamic_update_slice_in_dim(k, sub["k"], s, 1)
        v = lax.dynamic_update_slice_in_dim(v, sub["v"], s, 1)
        pos_map = lax.dynamic_update_slice_in_dim(pos_map, row[None], s, 0)
        tok = jnp.argmax(lax.dynamic_index_in_dim(
            logits[0, :, :cfg.vocab_size], jnp.maximum(ln - 1, 0), 0,
            keepdims=False)).astype(jnp.int32)
        return (k, v, pos_map), tok

    (k, v, pos_map), ctoks = lax.scan(body, (k, v, pos_map),
                                      (toks, slots, offsets, lens))
    return ctoks, k, v, pos_map


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3, 4))
def decode_only(cfg, params, k, v, pos_map, tokens, pos):
    """Decode batch, no prefill chunks. -> ((B,) tokens, k, v, pos_map)."""
    return _decode_core(cfg, params, k, v, pos_map, tokens, pos)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3, 4))
def chunks_only(cfg, params, k, v, pos_map, toks, slots, offsets, lens):
    """Prefill chunks, no decode. -> ((N,) tokens, k, v, pos_map)."""
    return _chunk_scan(cfg, params, k, v, pos_map, toks, slots, offsets, lens)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3, 4))
def mixed_step(cfg, params, k, v, pos_map, tokens, pos, toks, slots, offsets,
               lens):
    """The LocalScheduler's full mixed plan — decode batch first (matching
    the pre-fusion execution order, so parked-slot dummy writes land before
    chunks overwrite them), then every prefill chunk — as ONE jitted call.
    -> ((B+N,) stacked tokens, k, v, pos_map)."""
    dtoks, k, v, pos_map = _decode_core(cfg, params, k, v, pos_map, tokens,
                                        pos)
    ctoks, k, v, pos_map = _chunk_scan(cfg, params, k, v, pos_map, toks,
                                       slots, offsets, lens)
    return jnp.concatenate([dtoks, ctoks]), k, v, pos_map


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3, 4))
def prefill_place(cfg, params, k, v, pos_map, tokens, slot, length):
    """Whole-prompt prefill fused with the slot placement that previously
    ran as host-level ``.at[].set`` copies: forward the padded prompt,
    write its KV into ``slot``, select o_1 — one call, donated buffers.
    -> (o_1 token scalar, k, v, pos_map)."""
    C = k.shape[2]
    S = tokens.shape[0]
    x = dense.embed_tokens(cfg, params, tokens[None])
    logits, cache = dense.forward_seq(cfg, params, x, jnp.arange(S),
                                      cache_capacity=C)
    k = lax.dynamic_update_slice(k, cache["k"][:, :1], (0, slot, 0, 0, 0))
    v = lax.dynamic_update_slice(v, cache["v"][:, :1], (0, slot, 0, 0, 0))
    idx = jnp.arange(C, dtype=jnp.int32)
    row = jnp.where(idx < length, idx, -1)
    pos_map = lax.dynamic_update_slice_in_dim(pos_map, row[None], slot, 0)
    tok = jnp.argmax(lax.dynamic_index_in_dim(
        logits[0, :, :cfg.vocab_size], jnp.maximum(length - 1, 0), 0,
        keepdims=False)).astype(jnp.int32)
    return tok, k, v, pos_map
