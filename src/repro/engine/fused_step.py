"""Fused per-instance engine step: one jitted call with donated KV buffers
(DESIGN.md §9), with on-device replayable sampling and self-speculative
decoding (DESIGN.md §12).

Every function here takes the ``ModelConfig`` as a *static* jit argument
(it is a frozen, hashable dataclass), so traces are shared across all
``EngineInstance``s of a cluster — and across clusters — instead of each
instance re-jitting its own closures. An elastic spawn (§6) therefore
starts with a warm jit cache.

The SlotKVCache slabs (``k``, ``v``, ``pos_map``) are **donated**: XLA
aliases them with the corresponding outputs, so the multi-MB cache updates
in place every step instead of being functionally copied. Callers must
immediately replace their references with the returned slabs
(``SlotKVCache.swap``) — the donated inputs are dead after the call.

Token selection stays on device. Each slot samples with a key derived
*statelessly* as ``fold_in(fold_in(PRNGKey(seed), rid), position)`` —
no PRNG counter state exists anywhere, so a stream replays bit-for-bit
across runs, across step modes, and across KV migration / crash-recovery
re-prefill (the position is absolute in the request's token stream).
``temperature <= 0`` selects the exact argmax the pre-sampling engine
computed, on the un-cast logits, so greedy serving is provably unchanged.
Each entry point returns a single stacked int32 token array per step,
which the instance fetches with one blocking transfer at finalize time so
concurrent instances' steps overlap.
"""
from __future__ import annotations

from functools import partial
from types import SimpleNamespace

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import dense, hybrid
from repro.models import ssm as ssm_models


# ------------------------------------------------------------- sampling

def _sample_one(cfg, logits, temp, top_p, seed, rid, pos):
    """Select one token from a single logits row (padded vocab).

    Gumbel-max over the temperature-scaled, top-p-masked logits: the
    sample is an *argmax* of perturbed scores, so it inherits the same
    ulp-robustness the greedy path relies on for fused-vs-legacy and
    cross-instance (migration) bit-identity — fusion-level float noise
    only matters on exact score ties, which the Gumbel noise breaks.
    ``temp <= 0`` short-circuits to the pre-sampling argmax on the
    original-dtype logits."""
    V = cfg.vocab_size
    row = logits[:V]
    greedy = jnp.argmax(row).astype(jnp.int32)
    rowf = row.astype(jnp.float32)
    t = jnp.maximum(temp, 1e-6).astype(jnp.float32)
    scaled = rowf / t
    probs = jax.nn.softmax(scaled)
    order = jnp.argsort(-probs)
    sp = probs[order]
    # nucleus rule: keep tokens whose *exclusive* prefix mass is < top_p —
    # the top-1 token always survives (its exclusive mass is 0)
    keep_sorted = (jnp.cumsum(sp) - sp) < jnp.maximum(top_p, 1e-6)
    keep = jnp.zeros((row.shape[0],), bool).at[order].set(keep_sorted)
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), rid), pos)
    g = jax.random.gumbel(key, (row.shape[0],), jnp.float32)
    sampled = jnp.argmax(jnp.where(keep, scaled + g,
                                   -jnp.inf)).astype(jnp.int32)
    return jnp.where(temp <= 0.0, greedy, sampled)


def _sample_rows(cfg, logits, temps, top_ps, seeds, rids, pos):
    """Vectorized :func:`_sample_one` over (B, V_padded) logits rows."""
    return jax.vmap(
        lambda lg, t, p, sd, rid, ps: _sample_one(cfg, lg, t, p, sd, rid, ps)
    )(logits, temps, top_ps, seeds, rids, pos)


@partial(jax.jit, static_argnums=(0,))
def sample_tokens(cfg, logits, temps, top_ps, seeds, rids, pos):
    """Standalone batched sampler for the legacy (eager) step path: the
    same selection ops as the fused step, applied to already-materialized
    logits rows — fused-vs-legacy streams stay bit-identical because the
    logits are (PR 5 parity) and the selection is argmax-shaped."""
    return _sample_rows(cfg, logits, temps, top_ps, seeds, rids, pos)


# ------------------------------------------------------------ core steps

def _decode_core(cfg, params, k, v, pos_map, tokens, pos, temps, top_ps,
                 seeds, rids):
    """Batched decode over every slot (active rows carry real tokens,
    parked slots get the dummy write at their own next position — see
    EngineInstance.dispatch_step). Returns per-slot sampled tokens, keyed
    by each row's absolute position ``pos``."""
    x = dense.embed_tokens(cfg, params, tokens)
    logits, cache = dense.decode_step(
        cfg, params, {"k": k, "v": v, "pos_map": pos_map}, x, pos)
    toks = _sample_rows(cfg, logits[:, 0], temps, top_ps, seeds, rids, pos)
    return toks, cache["k"], cache["v"], cache["pos_map"]


def _chunk_scan(cfg, params, k, v, pos_map, toks, slots, offsets, lens,
                temps, top_ps, seeds, rids):
    """Run every prefill chunk of the plan against its own slot, scanned
    sequentially inside the jit (chunks target distinct slots, so the order
    only matters vs the decode dummy-writes, which ran first). ``toks`` is
    (N, Sq) bucket-padded chunk tokens; ``slots``/``offsets``/``lens`` are
    (N,) i32. Pad-position invalidation is folded in here — no host copy of
    the pos_map remains (ISSUE 5 satellite). Returns the per-chunk sampled
    token at each chunk's last real token — keyed by its absolute position
    ``offset + len - 1`` (meaningful only for final chunks; the host
    decides which)."""
    C = pos_map.shape[1]
    Sq = toks.shape[1]
    idx = jnp.arange(C, dtype=jnp.int32)

    def body(carry, xs):
        k, v, pos_map = carry
        t, s, off, ln, tp, pp, sd, rid = xs
        x = dense.embed_tokens(cfg, params, t[None])
        sub = {"k": lax.dynamic_slice_in_dim(k, s, 1, 1),
               "v": lax.dynamic_slice_in_dim(v, s, 1, 1),
               "pos_map": lax.dynamic_slice_in_dim(pos_map, s, 1, 0)}
        logits, sub = dense.prefill_chunk(cfg, params, sub, x, off)
        # bucket padding [off+ln, off+Sq) never becomes valid KV
        row = jnp.where((idx >= off + ln) & (idx < off + Sq), -1,
                        sub["pos_map"][0])
        k = lax.dynamic_update_slice_in_dim(k, sub["k"], s, 1)
        v = lax.dynamic_update_slice_in_dim(v, sub["v"], s, 1)
        pos_map = lax.dynamic_update_slice_in_dim(pos_map, row[None], s, 0)
        last = jnp.maximum(ln - 1, 0)
        tok = _sample_one(cfg, lax.dynamic_index_in_dim(
            logits[0], last, 0, keepdims=False), tp, pp, sd, rid, off + last)
        return (k, v, pos_map), tok

    (k, v, pos_map), ctoks = lax.scan(
        body, (k, v, pos_map),
        (toks, slots, offsets, lens, temps, top_ps, seeds, rids))
    return ctoks, k, v, pos_map


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3, 4))
def decode_only(cfg, params, k, v, pos_map, tokens, pos, temps, top_ps,
                seeds, rids):
    """Decode batch, no prefill chunks. -> ((B,) tokens, k, v, pos_map)."""
    return _decode_core(cfg, params, k, v, pos_map, tokens, pos, temps,
                        top_ps, seeds, rids)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3, 4))
def chunks_only(cfg, params, k, v, pos_map, toks, slots, offsets, lens,
                temps, top_ps, seeds, rids):
    """Prefill chunks, no decode. -> ((N,) tokens, k, v, pos_map)."""
    return _chunk_scan(cfg, params, k, v, pos_map, toks, slots, offsets,
                       lens, temps, top_ps, seeds, rids)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3, 4))
def mixed_step(cfg, params, k, v, pos_map, tokens, pos, dtemps, dtop_ps,
               dseeds, drids, toks, slots, offsets, lens, ctemps, ctop_ps,
               cseeds, crids):
    """The LocalScheduler's full mixed plan — decode batch first (matching
    the pre-fusion execution order, so parked-slot dummy writes land before
    chunks overwrite them), then every prefill chunk — as ONE jitted call.
    -> ((B+N,) stacked tokens, k, v, pos_map)."""
    dtoks, k, v, pos_map = _decode_core(cfg, params, k, v, pos_map, tokens,
                                        pos, dtemps, dtop_ps, dseeds, drids)
    ctoks, k, v, pos_map = _chunk_scan(cfg, params, k, v, pos_map, toks,
                                       slots, offsets, lens, ctemps,
                                       ctop_ps, cseeds, crids)
    return jnp.concatenate([dtoks, ctoks]), k, v, pos_map


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3, 4))
def prefill_place(cfg, params, k, v, pos_map, tokens, slot, length, temp,
                  top_p, seed, rid):
    """Whole-prompt prefill fused with the slot placement that previously
    ran as host-level ``.at[].set`` copies: forward the padded prompt,
    write its KV into ``slot``, select o_1 (keyed at absolute position
    ``length - 1``) — one call, donated buffers.
    -> (o_1 token scalar, k, v, pos_map)."""
    C = k.shape[2]
    S = tokens.shape[0]
    x = dense.embed_tokens(cfg, params, tokens[None])
    logits, cache = dense.forward_seq(cfg, params, x, jnp.arange(S),
                                      cache_capacity=C)
    k = lax.dynamic_update_slice(k, cache["k"][:, :1], (0, slot, 0, 0, 0))
    v = lax.dynamic_update_slice(v, cache["v"][:, :1], (0, slot, 0, 0, 0))
    idx = jnp.arange(C, dtype=jnp.int32)
    row = jnp.where(idx < length, idx, -1)
    pos_map = lax.dynamic_update_slice_in_dim(pos_map, row[None], slot, 0)
    last = jnp.maximum(length - 1, 0)
    tok = _sample_one(cfg, lax.dynamic_index_in_dim(
        logits[0], last, 0, keepdims=False), temp, top_p, seed, rid, last)
    return tok, k, v, pos_map


# ------------------------------------------------ ssm (Mamba-2) steps
#
# Same calling convention as the dense entries but over the {"conv","ssm"}
# decode-state slabs (DESIGN.md §13). Two differences forced by recurrence:
# the decode batch carries an explicit ``active`` mask (a recurrent update
# is irreversible, so parked slots keep their old state instead of the
# dense dummy-write trick), and chunked prefill passes each chunk's
# ``valid_len`` into the model so pad positions leave the state untouched.


def _ssm_decode_core(cfg, params, conv, ssm, tokens, pos, temps, top_ps,
                     seeds, rids, active):
    x = ssm_models.embed_tokens(cfg, params, tokens)
    logits, cache = ssm_models.decode_step(
        cfg, params, {"conv": conv, "ssm": ssm}, x, pos)
    conv = jnp.where(active[None, :, None, None], cache["conv"], conv)
    ssm = jnp.where(active[None, :, None, None, None], cache["ssm"], ssm)
    toks = _sample_rows(cfg, logits[:, 0], temps, top_ps, seeds, rids, pos)
    return toks, conv, ssm


def _ssm_chunk_scan(cfg, params, conv, ssm, toks, slots, offsets, lens,
                    temps, top_ps, seeds, rids):
    def body(carry, xs):
        conv, ssm = carry
        t, s, off, ln, tp, pp, sd, rid = xs
        x = ssm_models.embed_tokens(cfg, params, t[None])
        sub = {"conv": lax.dynamic_slice_in_dim(conv, s, 1, 1),
               "ssm": lax.dynamic_slice_in_dim(ssm, s, 1, 1)}
        logits, sub = ssm_models.prefill_chunk(cfg, params, sub, x, off,
                                               valid_len=ln)
        conv = lax.dynamic_update_slice_in_dim(conv, sub["conv"], s, 1)
        ssm = lax.dynamic_update_slice_in_dim(ssm, sub["ssm"], s, 1)
        last = jnp.maximum(ln - 1, 0)
        tok = _sample_one(cfg, lax.dynamic_index_in_dim(
            logits[0], last, 0, keepdims=False), tp, pp, sd, rid, off + last)
        return (conv, ssm), tok

    (conv, ssm), ctoks = lax.scan(
        body, (conv, ssm),
        (toks, slots, offsets, lens, temps, top_ps, seeds, rids))
    return ctoks, conv, ssm


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3))
def ssm_decode_only(cfg, params, conv, ssm, tokens, pos, temps, top_ps,
                    seeds, rids, active):
    return _ssm_decode_core(cfg, params, conv, ssm, tokens, pos, temps,
                            top_ps, seeds, rids, active)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3))
def ssm_chunks_only(cfg, params, conv, ssm, toks, slots, offsets, lens,
                    temps, top_ps, seeds, rids):
    return _ssm_chunk_scan(cfg, params, conv, ssm, toks, slots, offsets,
                           lens, temps, top_ps, seeds, rids)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2, 3))
def ssm_mixed_step(cfg, params, conv, ssm, tokens, pos, dtemps, dtop_ps,
                   dseeds, drids, active, toks, slots, offsets, lens, ctemps,
                   ctop_ps, cseeds, crids):
    dtoks, conv, ssm = _ssm_decode_core(cfg, params, conv, ssm, tokens, pos,
                                        dtemps, dtop_ps, dseeds, drids,
                                        active)
    ctoks, conv, ssm = _ssm_chunk_scan(cfg, params, conv, ssm, toks, slots,
                                       offsets, lens, ctemps, ctop_ps,
                                       cseeds, crids)
    return jnp.concatenate([dtoks, ctoks]), conv, ssm


# --------------------------------------- hybrid (RecurrentGemma) steps
#
# The decode state is the whole hybrid cache pytree with batch == n_slots,
# passed (and donated) as ONE argument. Per-slot slice/update walk the
# structure; the ``active`` mask reverts every leaf of inactive rows (ring
# writes included — cheaper than special-casing which leaves are safe).


def _hyb_rows(act, new, old, axis):
    shape = [1] * new.ndim
    shape[axis] = act.shape[0]
    return jnp.where(act.reshape(shape), new, old)


def _hyb_mask(old, new, act):
    out = {"groups": {k: _hyb_rows(act, new["groups"][k], old["groups"][k], 1)
                      for k in old["groups"]},
           "pos_map": _hyb_rows(act, new["pos_map"], old["pos_map"], 0)}
    if "tail" in old:
        out["tail"] = {k: _hyb_rows(act, new["tail"][k], old["tail"][k], 1)
                       for k in old["tail"]}
    return out


def _hyb_slice(cache, s):
    sub = {"groups": {k: lax.dynamic_slice_in_dim(a, s, 1, 1)
                      for k, a in cache["groups"].items()},
           "pos_map": lax.dynamic_slice_in_dim(cache["pos_map"], s, 1, 0)}
    if "tail" in cache:
        sub["tail"] = {k: lax.dynamic_slice_in_dim(a, s, 1, 1)
                       for k, a in cache["tail"].items()}
    return sub


def _hyb_update(cache, sub, s):
    out = {"groups": {k: lax.dynamic_update_slice_in_dim(
               cache["groups"][k], sub["groups"][k], s, 1)
               for k in cache["groups"]},
           "pos_map": lax.dynamic_update_slice_in_dim(
               cache["pos_map"], sub["pos_map"], s, 0)}
    if "tail" in cache:
        out["tail"] = {k: lax.dynamic_update_slice_in_dim(
            cache["tail"][k], sub["tail"][k], s, 1) for k in cache["tail"]}
    return out


def _hyb_decode_core(cfg, params, cache, tokens, pos, temps, top_ps, seeds,
                     rids, active):
    x = hybrid.embed_tokens(cfg, params, tokens)
    logits, new_cache = hybrid.decode_step(cfg, params, cache, x, pos)
    new_cache = _hyb_mask(cache, new_cache, active)
    toks = _sample_rows(cfg, logits[:, 0], temps, top_ps, seeds, rids, pos)
    return toks, new_cache


def _hyb_chunk_scan(cfg, params, cache, toks, slots, offsets, lens, temps,
                    top_ps, seeds, rids):
    def body(cache, xs):
        t, s, off, ln, tp, pp, sd, rid = xs
        x = hybrid.embed_tokens(cfg, params, t[None])
        sub = _hyb_slice(cache, s)
        logits, sub = hybrid.prefill_chunk(cfg, params, sub, x, off,
                                           valid_len=ln)
        cache = _hyb_update(cache, sub, s)
        last = jnp.maximum(ln - 1, 0)
        tok = _sample_one(cfg, lax.dynamic_index_in_dim(
            logits[0], last, 0, keepdims=False), tp, pp, sd, rid, off + last)
        return cache, tok

    cache, ctoks = lax.scan(
        body, cache, (toks, slots, offsets, lens, temps, top_ps, seeds, rids))
    return ctoks, cache


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def hybrid_decode_only(cfg, params, cache, tokens, pos, temps, top_ps,
                       seeds, rids, active):
    return _hyb_decode_core(cfg, params, cache, tokens, pos, temps, top_ps,
                            seeds, rids, active)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def hybrid_chunks_only(cfg, params, cache, toks, slots, offsets, lens,
                       temps, top_ps, seeds, rids):
    return _hyb_chunk_scan(cfg, params, cache, toks, slots, offsets, lens,
                           temps, top_ps, seeds, rids)


@partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def hybrid_mixed_step(cfg, params, cache, tokens, pos, dtemps, dtop_ps,
                      dseeds, drids, active, toks, slots, offsets, lens,
                      ctemps, ctop_ps, cseeds, crids):
    dtoks, cache = _hyb_decode_core(cfg, params, cache, tokens, pos, dtemps,
                                    dtop_ps, dseeds, drids, active)
    ctoks, cache = _hyb_chunk_scan(cfg, params, cache, toks, slots, offsets,
                                   lens, ctemps, ctop_ps, cseeds, crids)
    return jnp.concatenate([dtoks, ctoks]), cache


# ---------------------------------------------------- family dispatch

_OPS = {
    "dense": SimpleNamespace(decode_only=decode_only, chunks_only=chunks_only,
                             mixed_step=mixed_step),
    "ssm": SimpleNamespace(decode_only=ssm_decode_only,
                           chunks_only=ssm_chunks_only,
                           mixed_step=ssm_mixed_step),
    "hybrid": SimpleNamespace(decode_only=hybrid_decode_only,
                              chunks_only=hybrid_chunks_only,
                              mixed_step=hybrid_mixed_step),
}


def ops_for(family: str):
    """The family's fused-step entry points. All share the calling
    convention ``op(cfg, params, *slots.slabs(), *step_args)`` and return
    ``(tokens, *new_slabs)`` — the caller swaps the slabs back via
    ``StateSlots.swap`` (they were donated)."""
    return _OPS[family]


# -------------------------------------------- self-speculative decoding

@partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(4, 5, 6))
def spec_decode(cfg, draft_layers, k_draft, params, k, v, pos_map, tokens,
                pos, temps, top_ps, seeds, rids, active):
    """One self-speculative decode round for the whole slot batch, inside
    a single jitted call (DESIGN.md §12).

    Draft: ``k_draft`` sequential batched decode steps through only the
    first ``draft_layers`` layers — the params pytree stacks every layer on
    the leading ``lax.scan`` axis, so the truncated model is a tree-slice.
    Each draft token at absolute position ``p`` samples with the *same*
    key the full model would use at ``p`` (Gumbel-max coupling), so when
    the truncated logits agree with the full logits the draft is accepted
    with certainty. Draft KV lives only in the scan carry and is
    discarded.

    Verify: one full-layer pass per slot over ``[t0, d1..dk]`` at per-row
    offsets (a chunked prefill with a per-slot offset — the shared-offset
    ``dense.prefill_chunk`` runs on a single-row sub-cache inside the
    scan), sampling the target token at every position with its own
    positional key. The longest prefix of drafts agreeing with the
    targets is accepted; the emitted tokens are exactly the targets
    ``g_0..g_a`` — i.e. **bit-identical to the non-speculative stream**,
    because every target was sampled from the same context with the same
    key as sequential decode would. KV positions past the accepted prefix
    are invalidated; rows with ``active == False`` (parked slots) are
    written back untouched.

    Callers must ensure every active row satisfies
    ``pos + k_draft + 1 <= capacity`` (the instance falls back to plain
    decode otherwise). -> ((B, k_draft+2) packed [a, g_0..g_k], k, v,
    pos_map)."""
    B, C = pos_map.shape
    dparams = dict(params)
    dparams["layers"] = jax.tree.map(lambda a: a[:draft_layers],
                                     params["layers"])

    def draft_body(carry, _):
        dk, dv, dpm, tok, p = carry
        x = dense.embed_tokens(cfg, dparams, tok)
        logits, cache = dense.decode_step(
            cfg, dparams, {"k": dk, "v": dv, "pos_map": dpm}, x, p)
        nxt = _sample_rows(cfg, logits[:, 0], temps, top_ps, seeds, rids, p)
        return (cache["k"], cache["v"], cache["pos_map"], nxt[:, None],
                p + 1), nxt

    _, drafts = lax.scan(
        draft_body,
        (k[:draft_layers], v[:draft_layers], pos_map, tokens, pos),
        None, length=k_draft)
    ver_tokens = jnp.concatenate([tokens, drafts.T], axis=1)     # (B, k+1)
    idx = jnp.arange(C, dtype=jnp.int32)
    rel = jnp.arange(k_draft + 1, dtype=jnp.int32)

    def ver_body(carry, xs):
        k_, v_, pm = carry
        vt, s, off, t_, tp, sd, rid, act = xs
        # inactive (parked) rows still flow through for static shapes, but
        # clamp their offset into bounds and write back their original
        # slice — a strict no-op on their KV
        off_c = jnp.minimum(off, C - (k_draft + 1))
        sub0 = {"k": lax.dynamic_slice_in_dim(k_, s, 1, 1),
                "v": lax.dynamic_slice_in_dim(v_, s, 1, 1),
                "pos_map": lax.dynamic_slice_in_dim(pm, s, 1, 0)}
        x = dense.embed_tokens(cfg, params, vt[None])
        logits, sub1 = dense.prefill_chunk(cfg, params, sub0, x, off_c)
        g = jax.vmap(lambda lg, pp: _sample_one(cfg, lg, t_, tp, sd, rid,
                                                pp))(logits[0], off_c + rel)
        agree = jnp.cumprod((vt[1:] == g[:-1]).astype(jnp.int32))
        a = jnp.sum(agree)                       # accepted drafts, 0..k
        # valid context after the round: [0, off + a]; rejected draft
        # positions (off+a+1 .. off+k) revert to invalid
        row = jnp.where((idx > off_c + a) & (idx <= off_c + k_draft), -1,
                        sub1["pos_map"][0])
        kw = jnp.where(act, sub1["k"], sub0["k"])
        vw = jnp.where(act, sub1["v"], sub0["v"])
        roww = jnp.where(act, row, sub0["pos_map"][0])
        k_ = lax.dynamic_update_slice_in_dim(k_, kw, s, 1)
        v_ = lax.dynamic_update_slice_in_dim(v_, vw, s, 1)
        pm = lax.dynamic_update_slice_in_dim(pm, roww[None], s, 0)
        return (k_, v_, pm), jnp.concatenate([a[None].astype(jnp.int32), g])

    slots = jnp.arange(B, dtype=jnp.int32)
    (k, v, pos_map), packed = lax.scan(
        ver_body, (k, v, pos_map),
        (ver_tokens, slots, pos, temps, top_ps, seeds, rids, active))
    return packed, k, v, pos_map
