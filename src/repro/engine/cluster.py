"""Real-compute Arrow cluster: N EngineInstances (one JAX process, cooperative
round-robin execution standing in for N accelerators), the Arrow global
scheduler, instance monitor and KV transfers with actual array movement.

Wall-clock time drives everything: the TTFT predictor is fitted from a real
profiling pass at launch, token intervals are measured, and the scheduler
makes the same decisions it would on a hardware cluster. Use small models/CPU.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (SLO, GlobalScheduler, InstanceMonitor, InstancePools,
                        InstanceStats, Request, RequestState, SchedulerConfig,
                        TTFTPredictor)
from repro.engine.instance import EngineInstance
from repro.models import build_model


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_offset: float = 0.0        # seconds after serve() start
    # outcomes
    req: Request = None
    output_tokens: List[int] = field(default_factory=list)


class ArrowEngineCluster:
    def __init__(self, cfg: ModelConfig, *, n_instances: int = 2,
                 n_prefill: int = 1, n_slots: int = 8, capacity: int = 256,
                 slo: SLO = SLO(ttft=2.0, tpot=0.5),
                 sched_cfg: Optional[SchedulerConfig] = None, seed: int = 0,
                 params=None, chunk_tokens: Optional[int] = None):
        import jax
        self.cfg = cfg
        if params is None:
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(seed))
        self.instances: Dict[int, EngineInstance] = {
            i: EngineInstance(i, cfg, params, n_slots=n_slots,
                              capacity=capacity, chunk_tokens=chunk_tokens)
            for i in range(n_instances)}
        ids = list(self.instances)
        self.pools = InstancePools(ids, n_prefill=n_prefill)
        self.monitor = InstanceMonitor(ids)
        # real profiling pass on instance 0 (instances are homogeneous here)
        samples = self.instances[0].profile_prefill()
        self.predictor = TTFTPredictor.fit(samples)
        self.sched_cfg = sched_cfg or SchedulerConfig(
            max_running_tokens=n_slots * capacity, monitor_interval=0.05)
        self.gs = GlobalScheduler(self.pools, self.monitor, self.predictor,
                                  slo, self.sched_cfg, self)
        self._pending_migrations: List[tuple] = []   # (rid, src, dst)

    # ------------------------------------------------------- ClusterView
    def has_pending_prefill(self, iid: int) -> bool:
        return self.instances[iid].local.has_pending_prefill()

    def has_pending_decode(self, iid: int) -> bool:
        return self.instances[iid].local.has_pending_decode()

    # ------------------------------------------------------------- serve
    def serve(self, reqs: List[ServeRequest], *, timeout: float = 300.0
              ) -> List[ServeRequest]:
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0  # noqa: E731
        pending = sorted(reqs, key=lambda r: r.arrival_offset)
        live: Dict[int, ServeRequest] = {}
        last_tick = 0.0
        while (pending or live) and now() < timeout:
            t = now()
            # arrivals
            while pending and pending[0].arrival_offset <= t:
                sr = pending.pop(0)
                sr.req = Request(sr.rid, arrival=t, input_len=len(sr.prompt),
                                 output_len=sr.max_new_tokens)
                out = self.gs.schedule_prefill(sr.req, t)
                sr.req.prefill_instance = out.instance
                sr.req.state = RequestState.PREFILLING
                inst = self.instances[out.instance]
                inst.local.enqueue_prefill(sr.rid, len(sr.prompt))
                live[sr.rid] = sr
            # migrations (instant data move + admission gate)
            self._run_migrations(live, now)
            # one iteration per instance (cooperative round-robin)
            for iid, inst in self.instances.items():
                self._step_instance(iid, inst, live, now)
            # monitor tick
            if now() - last_tick >= self.sched_cfg.monitor_interval:
                last_tick = now()
                self._monitor_tick(last_tick)
            if not live and pending:
                time.sleep(max(pending[0].arrival_offset - now(), 0.0))
        return reqs

    # ---------------------------------------------------------- internals
    def _step_instance(self, iid, inst, live, now) -> None:
        plan = inst.local.plan_iteration()
        if plan.is_empty:
            return
        t_start = now()
        # decode batch first
        done_tokens = inst.run_decode_iteration(plan.decode_rids)
        t_after = now()
        for rid, tok in done_tokens.items():
            sr = live.get(rid)
            if sr is None:
                continue
            sr.output_tokens.append(tok)
            sr.req.token_times.append(t_after)
            sr.req.decoded_tokens += 1
            if inst.local.complete_decode_iteration(rid):
                sr.req.finish_time = t_after
                sr.req.state = RequestState.FINISHED
                inst.drop(rid)
                live.pop(rid, None)
        if done_tokens:
            self.monitor.record_iteration(iid, t_after, len(done_tokens),
                                          t_after - t_start)
        # chunked prefill (§5.4): one chunk per iteration, decode-first batch
        for rid, start, ln in plan.prefill_chunks[:1]:
            sr = live.get(rid)
            if sr is None:
                continue
            if start == 0 and not inst.kv.free:    # no slot: retry next round
                continue
            tok = inst.run_prefill_chunk(rid, sr.prompt[start:start + ln],
                                         start, sr.req.input_len)
            t_fin = now()
            inst.local.complete_prefill_chunk(rid, ln)
            if tok is None:                        # more chunks to go
                continue
            sr.output_tokens.append(tok)
            sr.req.first_token_time = t_fin
            # resync Eq.(2) bookkeeping against reality: predicted drain time
            # of the instance = now + predicted time of the remaining queue
            backlog = sum(self.predictor.predict(w.input_len)
                          for w in inst.local.prefill_queue.values())
            self.gs.prefill_ready_at[iid] = t_fin + backlog
            if sr.max_new_tokens <= 1:
                sr.req.finish_time = t_fin
                sr.req.state = RequestState.FINISHED
                inst.drop(rid)
                live.pop(rid, None)
                continue
            target = self.gs.schedule_decode(sr.req, t_fin).instance
            sr.req.decode_instance = target
            rem = sr.max_new_tokens - 1
            if target == iid:
                sr.req.state = RequestState.DECODING
                inst.local.start_local_decode(rid, sr.req.input_len, rem)
            else:
                sr.req.state = RequestState.MIGRATING
                self.instances[target].local.enqueue_migration(
                    rid, sr.req.input_len, rem)
                self._pending_migrations.append((rid, iid, target))

    def _run_migrations(self, live, now) -> None:
        src_of = {r: (s, d) for (r, s, d) in self._pending_migrations}
        for dst in self.instances:
            dloc = self.instances[dst].local
            while True:
                item = dloc.next_migration()       # FCFS + memory gate (§5.4)
                if item is None:
                    break
                mrid, kv_tokens, rem = item
                src = src_of.get(mrid, (None, None))[0]
                sr = live.get(mrid)
                if sr is None or src is None:
                    self._pending_migrations = [
                        t for t in self._pending_migrations if t[0] != mrid]
                    continue
                # real KV movement between instances
                k, v, L, last, gen = self.instances[src].export_kv(mrid)
                ok = self.instances[dst].import_kv(mrid, k, v, L, last, gen)
                if not ok:                          # no free slot: retry later
                    dloc.migration_queue.appendleft((mrid, kv_tokens, rem))
                    break
                self.instances[src].drop(mrid)
                dloc.admit_migrated(mrid, kv_tokens, rem)
                sr.req.state = RequestState.DECODING
                self._pending_migrations = [
                    t for t in self._pending_migrations if t[0] != mrid]

    def _monitor_tick(self, t: float) -> None:
        for iid, inst in self.instances.items():
            loc = inst.local
            self.monitor.update_stats(InstanceStats(
                instance_id=iid,
                prefill_queue_len=len(loc.prefill_queue),
                prefill_backlog_tokens=loc.prefill_backlog_tokens,
                prefill_ready_at=self.gs.prefill_ready_at.get(iid, 0.0),
                running_tokens=loc.running_tokens,
                n_decode_running=len(loc.decode_running),
                kv_tokens_used=loc.kv_used,
                kv_tokens_capacity=loc.kv_capacity,
            ))
        self.gs.on_monitor_tick(t)
