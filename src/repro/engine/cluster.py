"""Real-compute Arrow cluster: a ``ServingSystem`` backend over N
EngineInstances (one JAX process, cooperative round-robin execution standing
in for N accelerators) with real array movement for KV transfers.

Wall-clock time drives everything: the TTFT predictor is fitted from a real
profiling pass at launch, token intervals are measured, and the scheduler
makes the same decisions it would on a hardware cluster. Use small models/CPU.

All scheduling glue (prefill dispatch, decode placement, the FCFS migration
manager, monitor-tick scraping, the ``POLICIES`` registry) comes from the
shared ``RuntimeCore`` (core/runtime.py) — so the engine runs the same
baseline policies (``colocated``, ``minimal_load``, ...) and replays the same
traces as the simulator, and streams real token ids through per-request
``on_token`` callbacks as they land.

Each cooperative pass is two-phase (DESIGN.md §9): every instance's fused
step — its full decode batch plus all planned prefill chunks, one jitted
call with donated KV buffers — is dispatched before any token array is
fetched, so the instances' device steps overlap and each pays a single
blocking transfer per pass.
"""
from __future__ import annotations

import heapq
import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (Request, RequestState, SLO, SchedulerConfig,
                        TTFTPredictor)
from repro.core.clock import WallClock
from repro.core.local_scheduler import LocalScheduler
from repro.core.prefix_index import content_keys, lineage_keys
from repro.core.runtime import DecodePlacement, RuntimeCore
from repro.core.serving import (FinishCallback, RequestHandle, ServeReport,
                                TokenCallback)
from repro.engine.instance import (ChunkWork, CorruptPayload, EngineInstance,
                                   NoFreeSlots, state_checksum)
from repro.models import build_model


@dataclass
class ServeRequest:
    """Legacy batch-mode request (kept for the ``serve()`` shim)."""

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_offset: float = 0.0        # seconds after serve() start
    # outcomes
    req: Request = None
    output_tokens: List[int] = field(default_factory=list)


class ArrowEngineCluster(RuntimeCore):
    def __init__(self, cfg: ModelConfig, *, n_instances: int = 2,
                 n_prefill: int = 1, n_slots: int = 8, capacity: int = 256,
                 slo: SLO = SLO(ttft=2.0, tpot=0.5),
                 sched_cfg: Optional[SchedulerConfig] = None, seed: int = 0,
                 params=None, chunk_tokens: Optional[int] = None,
                 policy: str = "arrow", autoscaler_cfg=None,
                 prefix_cache: bool = False, fault_plan=None,
                 step_mode: str = "fused", tenants=None, admission=False,
                 deflection=None, speculate: int = 0,
                 draft_layers: Optional[int] = None, health=False):
        import jax
        self.cfg = cfg
        self.capacity = capacity
        self.n_slots = n_slots
        self.chunk_tokens = chunk_tokens
        self.step_mode = step_mode
        self.run_seed = int(seed)
        self.speculate = int(speculate)
        self.draft_layers = draft_layers
        if params is None:
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(seed))
        self.params = params           # shared by reference across instances
        self.instances: Dict[int, EngineInstance] = {
            i: self._new_instance(i) for i in range(n_instances)}
        # real profiling pass on instance 0 (instances are homogeneous here)
        samples = self.instances[0].profile_prefill()
        predictor = TTFTPredictor.fit(samples)
        sched_cfg = sched_cfg or SchedulerConfig(
            max_running_tokens=n_slots * capacity, monitor_interval=0.05)
        self._init_runtime(list(self.instances), n_prefill=n_prefill,
                           policy=policy, slo=slo, sched_cfg=sched_cfg,
                           predictor=predictor, clock=WallClock(),
                           autoscaler_cfg=autoscaler_cfg,
                           prefix_cache=prefix_cache, fault_plan=fault_plan,
                           tenants=tenants, admission=admission,
                           deflection=deflection, run_seed=seed,
                           health=health,
                           prefix_reuse=next(iter(
                               self.instances.values())).kv.prefix_reuse)
        for i in self.instances:
            self._arm_deflect(i)     # §11 micro-batch knob (no-op if unarmed)
        self._pending: list = []                # heap: (arrival, rid)
        self._live: Dict[int, RequestHandle] = {}
        # async step state (DESIGN.md §12): iid -> dispatched-step context
        # whose token arrays are still computing on device; populated by the
        # dispatch-all phase of step() and drained by collect-ready
        self._inflight: Dict[int, tuple] = {}
        self._prompts: Dict[int, np.ndarray] = {}
        self._last_tick = 0.0
        # multi-turn sessions (DESIGN.md §7): the evolving token stream per
        # session (prompt ‖ generated of the last finished turn) — follow-up
        # prompts literally extend it, which is what makes lineage keys
        # *true in compute* on the engine. ``_session_epoch`` bumps when a
        # turn truncates the stream (capacity clamp): stale lineage keys
        # must not collide with the forked content.
        self._session_tail: Dict[int, np.ndarray] = {}
        self._session_epoch: Dict[int, int] = {}
        self._rid_epoch: Dict[int, tuple] = {}   # rid -> (lookup, retain)

    def _new_instance(self, iid: int) -> EngineInstance:
        return EngineInstance(
            iid, self.cfg, self.params, n_slots=self.n_slots,
            capacity=self.capacity, chunk_tokens=self.chunk_tokens,
            step_mode=self.step_mode, run_seed=self.run_seed,
            speculate=self.speculate, draft_layers=self.draft_layers)

    @property
    def gs(self):
        """Back-compat alias from when the engine hard-wired GlobalScheduler;
        with ``policy='arrow'`` this is the GlobalScheduler subclass."""
        return self.policy

    # ----------------------------------------------------- RuntimeCore hooks
    def local_of(self, iid: int) -> LocalScheduler:
        return self.instances[iid].local

    def _begin_transfer(self, rid: int, dst: int, kv: int, rem: int) -> bool:
        # real decode-state movement between instances (synchronous array
        # export/import); both endpoints must first land any inflight async
        # step — the source so the exported state includes every token
        # already emitted, the destination so its donated slabs aren't
        # mid-flight
        src = self._kv_source(rid)
        self._finalize_now(src)
        self._finalize_now(dst)
        samp = self.instances[src].kv.samp_of.get(rid)
        payload, L, last, gen = self.instances[src].export_state(rid)
        # end-to-end integrity (§14): checksum at export, verify at import.
        # The source slot is retained until complete_migration acknowledges,
        # so every retry re-sends pristine state.
        checksum = state_checksum(payload)
        while True:
            nf = self.netslow_factor(self.clock.now())
            if nf > 1.0:                  # degraded interconnect window (§14)
                time.sleep(min((nf - 1.0) * 1e-3, 0.05))
            wire = payload
            if self.xfer_should_drop(self.clock.now()):
                # a dropped attempt materializes as a corrupt wire image;
                # corrupt a *copy* so the source arrays stay pristine
                wire = [np.array(p, copy=True) for p in payload]
                for p in wire:
                    if p.size:
                        p.view(np.uint8).reshape(-1)[0] ^= 0xFF
                        break
                self.health_stats["xfer_corrupt"] += 1
            try:
                ok = self.instances[dst].import_state(
                    rid, wire, L, last, gen, sampling=samp, checksum=checksum)
            except CorruptPayload:
                attempt = self.note_xfer_drop(rid)
                if attempt <= self.xfer_retry_budget():
                    self.health_stats["xfer_retries"] += 1
                    time.sleep(min(self.xfer_backoff(attempt), 0.05))
                    continue
                # retries exhausted: fall through to re-prefill recovery
                # (§8); the transfer item is consumed, not requeued
                self.fail_transfer(rid, dst, kv, self.clock.now())
                return True
            break
        if not ok:
            # no free slot: cached prefixes are reclaimable capacity (§7)
            if not (self.prefix_mgr is not None
                    and self.prefix_mgr.evict_one(dst) is not None
                    and self.instances[dst].import_state(rid, payload, L, last,
                                                         gen, sampling=samp)):
                return False                    # genuinely full: retry later
        # the wire cost is the payload's actual bytes — O(1) in context for
        # constant-state families, tokens × per-token KV for dense (§13)
        self._record_migration(rid, L, sum(int(p.nbytes) for p in payload))
        self.complete_migration(rid, dst, kv, rem, self.clock.now())
        return True

    def _release_source_kv(self, src: int, rid: int, kv: int) -> None:
        # free the slot *and* the LocalScheduler token accounting (the gate
        # for migration admission) — mirror of the sim's release
        self.instances[src].local.release_prefill_kv(rid, kv)
        self.instances[src].drop(rid)

    def _arrival_due(self, rid: int) -> None:
        heapq.heappush(self._pending, (self.handles[rid].req.arrival, rid))

    def _schedule_retry(self, rid: int, at: float) -> None:
        """Admission deferred ``rid`` (§10): re-enter the arrival heap at a
        strictly future wall-clock time (NOT the original arrival — that is
        already due and would spin inside the current step's pop loop)."""
        heapq.heappush(self._pending, (max(at, self.clock.now() + 1e-6), rid))

    def _request_rejected(self, rid: int) -> None:
        """Admission rejected ``rid`` (§10): free its synthesized prompt —
        it never entered scheduling, so there is nothing else to unwind."""
        self._prompts.pop(rid, None)

    # ------------------------------------------------ fault hooks (§8)
    def _on_instance_failed(self, iid: int) -> None:
        # the EngineInstance — and with it the slot KV cache — dies here;
        # the LocalScheduler bookkeeping was already inventoried. An inflight
        # async step dies with it: its tokens were never emitted, so the
        # stream consistently resumes from the last *emitted* token (§8)
        self._inflight.pop(iid, None)
        self.instances.pop(iid, None)

    def _request_lost(self, rid: int) -> None:
        # strawman: stranded for good — drop it from the serving loop and
        # free its prompt (kept until finish for recovery, which never comes)
        self._live.pop(rid, None)
        self._prompts.pop(rid, None)

    def _prepare_recovery(self, handle: RequestHandle) -> None:
        """Crash recovery (§8): extend the stored prompt with the streamed
        tokens not yet folded in, minus the last (its logits are what the
        recovery prefill recomputes to seed the next decode step) — so the
        re-prefill rebuilds the exact pre-crash KV and greedy decode
        continues token-identically."""
        req = handle.req
        prompt = self._prompts.get(req.rid)
        emitted = [t for t in handle.tokens if t is not None]
        if prompt is None or not emitted:
            return
        folded = max(req.resumed_tokens - 1, 0)   # already in the prompt
        tail = np.asarray(emitted[folded:len(emitted) - 1], np.int32)
        self._prompts[req.rid] = np.concatenate([prompt, tail])

    # ------------------------------------- prefix cache / sessions (§7)
    def _release_retained(self, iid: int, rid: int) -> None:
        super()._release_retained(iid, rid)
        inst = self.instances.get(iid)
        if inst is not None:
            inst.drop(rid)                      # free the real slot

    def _retain_kv(self, iid: int, rid: int, kv_tokens: int) -> bool:
        inst = self.instances.get(iid)
        if inst is None or rid not in inst.kv.slot_of:
            return False
        # a full slot's tail position keeps receiving the batched dummy
        # write (instance.run_decode_iteration) — don't retain it
        if inst.kv.len_of.get(rid, 0) >= inst.capacity:
            return False
        return super()._retain_kv(iid, rid, kv_tokens)

    def _prepare_dispatch(self, handle: RequestHandle, now: float) -> None:
        """Materialize the session prompt once parent gating has cleared:
        the prompt extends the session transcript (real tokens), padded
        with deterministic fresh tokens up to the trace's input_len."""
        req = handle.req
        if req.session_id is None or req.rid in self._prompts:
            return
        sid = req.session_id
        ctx = self._session_tail.get(sid, np.zeros((0,), np.int32))
        n = max(1, min(req.input_len, self.capacity - req.output_len))
        epoch = self._session_epoch.get(sid, 0)
        if n < len(ctx):
            # capacity clamp truncated the stream: this turn forks the
            # session — future retentions use a fresh lineage namespace
            self._session_epoch[sid] = epoch + 1
            self._rid_epoch[req.rid] = (epoch, epoch + 1)
            prompt = ctx[:n].copy()
        else:
            self._rid_epoch[req.rid] = (epoch, epoch)
            rng = np.random.default_rng(0xA44 + req.rid)
            fresh = rng.integers(1, self.cfg.vocab_size,
                                 size=n - len(ctx)).astype(np.int32)
            prompt = np.concatenate([ctx, fresh]).astype(np.int32)
        req.input_len = n
        self._prompts[req.rid] = prompt

    def _lookup_keys(self, req: Request):
        if req.session_id is not None:
            epoch = self._rid_epoch.get(req.rid, (0, 0))[0]
            return lineage_keys((req.session_id, epoch), req.input_len - 1,
                                self.prefix_mgr.block)
        prompt = self._prompts.get(req.rid)
        if prompt is None:
            return None
        return content_keys(prompt[:req.input_len - 1], self.prefix_mgr.block)

    def _retention_keys(self, handle: RequestHandle):
        req = handle.req
        if req.session_id is not None:
            epoch = self._rid_epoch.get(req.rid, (0, 0))[1]
            return lineage_keys((req.session_id, epoch),
                                req.input_len + req.decoded_tokens,
                                self.prefix_mgr.block)
        prompt = self._prompts.get(req.rid)
        if prompt is None:
            return None
        # resident KV = prompt + every generated token except the last
        # (o_m is returned but never fed back into the cache); a crash
        # recovery (§8) already folded tokens[:resumed-1] into the prompt
        folded = max(req.resumed_tokens - 1, 0)
        gen = np.asarray([t for t in handle.tokens[folded:-1]
                          if t is not None], np.int32)
        return content_keys(np.concatenate([prompt, gen]),
                            self.prefix_mgr.block)

    def _session_note_finish(self, handle: RequestHandle) -> None:
        req = handle.req
        if req.session_id is None:
            if self.prefix_mgr is None:
                # prompts are kept through decode for crash recovery (§8);
                # with the cache off nothing else will free this one
                self._prompts.pop(req.rid, None)
            return
        prompt = self._prompts.get(req.rid)
        if prompt is None:
            return
        folded = max(req.resumed_tokens - 1, 0)   # recovery extended prompt
        gen = np.asarray([t for t in handle.tokens[folded:]
                          if t is not None], np.int32)
        self._session_tail[req.session_id] = np.concatenate([prompt, gen])
        self._prompts.pop(req.rid, None)   # folded into the tail; free it

    def _maybe_retain(self, handle: RequestHandle) -> None:
        super()._maybe_retain(handle)
        self._prompts.pop(handle.req.rid, None)   # keys computed; free it

    # ------------------------------------- elastic lifecycle hooks (§6)
    def _quiesce_for_evacuation(self, iid: int) -> None:
        # land any inflight async step first: its decode tokens belong to
        # requests that evacuation (retirement or quarantine, §14) is about
        # to flip to MIGRATING (and pop from the local scheduler) — emit
        # them before the state moves
        self._finalize_now(iid)

    def _preempt_release(self, iid: int, rid: int) -> None:
        # SLO-aware preemption (§14): the victim's real slot is freed; its
        # stream resumes through the re-prefill recovery path
        inst = self.instances.get(iid)
        if inst is not None:
            inst.drop(rid)

    def _create_instance(self, iid: int) -> float:
        """Spawn a real EngineInstance; params are shared by reference and
        the fused-step jits are module-level keyed on the (hashable) config
        (DESIGN.md §9), so a spawn starts with a warm jit cache — the cost
        is the KV-cache allocation, which happens right here, i.e. the
        warm-up is real elapsed wall-clock, and the instance is ACTIVE the
        moment construction returns."""
        self.instances[iid] = self._new_instance(iid)
        return 0.0

    def _destroy_instance(self, iid: int) -> None:
        # retirement is gated on _instance_quiesced, so there is no inflight
        # step by now; the pop is a belt-and-braces invariant
        self._inflight.pop(iid, None)
        self.instances.pop(iid, None)

    # --------------------------------------------------------- ServingSystem
    def submit(self, req: Request, *, prompt: Optional[np.ndarray] = None,
               tier: str = "standard", tenant_id: Optional[str] = None,
               on_token: Optional[TokenCallback] = None,
               on_finish: Optional[FinishCallback] = None) -> RequestHandle:
        """``req.arrival`` is wall-clock seconds after the serving loop
        starts. When ``prompt`` is omitted a deterministic synthetic prompt is
        generated (clamped so prompt + decode tokens fit a KV slot), which is
        what lets ``repro.traces`` traces replay directly on the engine."""
        if prompt is None and req.session_id is None:
            n = max(1, min(req.input_len, self.capacity - req.output_len))
            rng = np.random.default_rng(0xA44 + req.rid)
            prompt = rng.integers(1, self.cfg.vocab_size,
                                  size=n).astype(np.int32)
        handle = self._register(req, tier, on_token, on_finish,
                                tenant_id=tenant_id)
        if prompt is not None:
            req.input_len = len(prompt)
            self._prompts[req.rid] = np.asarray(prompt, np.int32)
        # else: a session request — its prompt extends the session
        # transcript and is materialized at dispatch time, once the parent
        # turn has finished (_prepare_dispatch)
        heapq.heappush(self._pending, (req.arrival, req.rid))
        return handle

    def _finalize_now(self, iid: int) -> None:
        """Land ``iid``'s inflight async step immediately (blocking fetch).
        Used where host state must be consistent with the device — KV
        export/import endpoints — and as the no-progress fallback."""
        ctx = self._inflight.pop(iid, None)
        if ctx is None:
            return
        inst = self.instances.get(iid)
        if inst is not None:
            self._finalize_instance_step(iid, inst, ctx)

    def _instance_quiesced(self, iid: int) -> bool:
        # elastic retirement / recycling must not reap an instance whose
        # async step is still computing on device
        return iid not in self._inflight

    def step(self) -> bool:
        """One fully-async cooperative pass (DESIGN.md §12): collect the
        instances whose dispatched step has finished on device (non-blocking
        ``ready()`` poll), then dispatch a new fused step on every idle
        instance. An instance's step may stay inflight across many step()
        calls — fast instances are never barriered on slow ones (the PR 5/7
        two-phase step still joined all instances every pass). When nothing
        is ready and nothing can be dispatched, the oldest inflight step is
        force-finalized so the pass always makes progress instead of
        spinning the host."""
        t = self.clock.now()
        if self.fault_injector is not None:    # polled firing (§8)
            self.fault_injector.poll(t)
        # arrivals due
        while self._pending and self._pending[0][0] <= t:
            _, rid = heapq.heappop(self._pending)
            handle = self.handles[rid]
            if self.dispatch_prefill(handle, t) is None:
                continue       # deferred: re-enters _pending via _arrival_due
            self._live[rid] = handle
        # migrations (instant data move + admission gate); snapshot the id
        # lists — elastic retirement may remove instances mid-pass
        for dst in list(self.instances):
            self.admit_migrations(dst)
        # collect-ready: finalize any inflight step whose token arrays have
        # landed; the rest keep computing
        progressed = 0
        for iid in list(self._inflight):
            inst = self.instances.get(iid)
            if inst is None:                  # died while inflight
                self._inflight.pop(iid, None)
                continue
            if self._inflight[iid][0].ready():
                ctx = self._inflight.pop(iid)
                self._finalize_instance_step(iid, inst, ctx)
                progressed += 1
        # dispatch-all: every instance without an inflight step launches its
        # next fused step and returns immediately
        for iid, inst in list(self.instances.items()):
            if iid in self._inflight or iid not in self.instances:
                continue
            ctx = self._dispatch_instance(iid, inst)
            if ctx is not None:
                self._inflight[iid] = ctx
                progressed += 1
        if not progressed and self._inflight:
            # nothing landed, nothing to launch: block on the oldest
            # inflight step rather than busy-spinning the host
            self._finalize_now(next(iter(self._inflight)))
        # monitor tick
        now = self.clock.now()
        if now - self._last_tick >= self.sched_cfg.monitor_interval:
            self._last_tick = now
            self.collect_stats(now)
        return bool(self._live or self._pending or self._inflight)

    def run_until(self, t: float) -> None:
        while self.clock.now() < t:
            if not self.step():
                time.sleep(min(1e-3, max(t - self.clock.now(), 0.0)))

    def drain(self, *, timeout: Optional[float] = 300.0) -> ServeReport:
        limit = (float("inf") if timeout is None
                 else self.clock.now() + timeout)
        while (self._pending or self._live) and self.clock.now() < limit:
            self.step()
            self._check_undispatchable()   # §8: raise, don't spin to timeout
            if not self._live and not self._inflight and self._pending:
                time.sleep(max(self._pending[0][0] - self.clock.now(), 0.0))
        return self.report()

    # ------------------------------------------------- deprecated batch shim
    def serve(self, reqs: List[ServeRequest], *, timeout: float = 300.0
              ) -> List[ServeRequest]:
        """Batch entrypoint kept for compatibility; new code should use
        ``submit()`` + ``drain()`` (the unified ServingSystem API)."""
        warnings.warn("ArrowEngineCluster.serve(reqs) is deprecated; use the "
                      "ServingSystem API (submit/step/drain)",
                      DeprecationWarning, stacklevel=2)
        handles = []
        for sr in reqs:
            sr.req = Request(sr.rid, arrival=sr.arrival_offset,
                             input_len=len(sr.prompt),
                             output_len=sr.max_new_tokens)
            handles.append(self.submit(sr.req, prompt=sr.prompt))
        self.drain(timeout=timeout)
        for sr, h in zip(reqs, handles):
            sr.output_tokens = [t for t in h.tokens if t is not None]
        return reqs

    # ---------------------------------------------------------- internals
    def _dispatch_instance(self, iid: int, inst: EngineInstance):
        """Phase 1: admit the plan's chunks (slot allocation / cached-prefix
        seeding) and launch the instance's fused step without blocking."""
        plan = inst.local.plan_iteration()
        if plan.is_empty:
            return None
        t_start = self.clock.now()
        chunks = []
        # the legacy baseline is the *pre-fusion* path faithfully: it
        # processed at most one prefill chunk per cooperative pass
        plan_chunks = (plan.prefill_chunks[:1] if self.step_mode == "legacy"
                       else plan.prefill_chunks)
        for rid, start, ln in plan_chunks:
            handle = self._live.get(rid)
            if handle is None:
                continue
            if rid not in inst.kv.slot_of:         # first chunk: need a slot
                if not inst.kv.free and not (
                        self.prefix_mgr is not None
                        and self.prefix_mgr.evict_one(iid) is not None):
                    continue                       # no slot: retry next round
                try:
                    if start > 0:
                        # prefix reuse (§7): seed the fresh slot with the
                        # cached prefix, then compute only the suffix chunks
                        src = self._prefix_src[rid]
                        inst.begin_cached_prefill(rid, src[1], start)
                    else:
                        inst.alloc_slot(rid)
                except NoFreeSlots:
                    continue                       # stays queued; retry later
                # sampling params become slot state alongside the fresh KV
                # (recovery re-runs this path, so a recovered stream keeps
                # its keys — DESIGN.md §12)
                inst.set_sampling(rid, handle.req.sampling)
            prompt = self._prompts[rid]
            chunks.append(ChunkWork(rid, start, ln,
                                    prompt[start:start + ln],
                                    handle.req.input_len))
        pending = inst.dispatch_step(plan.decode_rids, chunks)
        if pending is None:
            return None
        # t_disp closes this instance's own dispatch span; the finalize span
        # is measured separately so an instance's iteration duration (the
        # TPOT signal) and any injected slowdown never absorb the *other*
        # instances' dispatch/finalize work done in between
        return pending, chunks, t_start, self.clock.now()

    def _finalize_instance_step(self, iid: int, inst: EngineInstance,
                                ctx) -> None:
        """Phase 2: the step's one blocking token fetch + host bookkeeping
        (stream emission, decode/prefill completion, Eq.(2) resync)."""
        pending, chunks, t_start, t_disp = ctx
        slow = self.slow_factor(iid, t_start)    # injected lag (§8)
        t_fin0 = self.clock.now()
        done_tokens, chunk_tokens = inst.finalize_step(pending)
        t_after = self.clock.now()
        # this instance's own work: its dispatch span + its blocking fetch
        # (the device compute overlapped the other instances' phases)
        span = (t_disp - t_start) + (t_after - t_fin0)
        emitted = 0
        for rid, tok in done_tokens.items():
            handle = self._live.get(rid)
            if handle is None:
                continue
            spec_round = isinstance(tok, list)
            toks = tok if spec_round else [tok]
            if spec_round:
                self._spec_stats["rounds"] += 1
                self._spec_stats["drafted"] += inst.speculate
                self._spec_stats["accepted"] += len(toks) - 1
            for tk in toks:
                self.emit_token(handle, t_after, tk)
                emitted += 1
                if spec_round:
                    self._spec_stats["emitted"] += 1
                if inst.local.complete_decode_iteration(rid):
                    self.finish(handle, t_after)
                    if rid not in inst.local.retained:  # kept as prefix (§7)
                        inst.drop(rid)
                    self._live.pop(rid, None)
                    break                 # overshot accepts are discarded
        if done_tokens:
            self.monitor.record_iteration(iid, t_after, emitted, span)
        # chunked prefill (§5.4): the fused step ran *every* chunk of the
        # plan; finalize_step reports them in dispatch order
        by_rid = dict(chunk_tokens)
        for cw in chunks:
            handle = self._live.get(cw.rid)
            if handle is None:
                continue
            tok = by_rid.get(cw.rid)
            t_fin = self.clock.now()
            inst.local.complete_prefill_chunk(cw.rid, cw.length)
            if tok is None:                        # more chunks to go
                continue
            # (the prompt stays resident until finish — crash recovery §8
            # may need to re-prefill it)
            # resync Eq.(2) bookkeeping against reality: predicted drain time
            # of the instance = now + predicted time of the remaining queue
            # (a cached prefix shrinks a queued request to its suffix)
            backlog = sum(self.predictor.predict_chunk(w.done, w.remaining)
                          for w in inst.local.prefill_queue.values())
            self.policy.prefill_ready_at[iid] = t_fin + backlog
            placement, _ = self.after_prefill(handle, iid, t_fin, token=tok)
            if placement is DecodePlacement.FINISHED:
                # release the prefill's kv_used accounting (mirror of the
                # sim path); a retained prefix re-added its own tokens
                inst.local.release_prefill_kv(cw.rid, handle.req.input_len)
                if cw.rid not in inst.local.retained:
                    inst.drop(cw.rid)
                self._live.pop(cw.rid, None)
        if slow > 1.0:                           # lagging instance (§8)
            time.sleep(min((slow - 1.0)
                           * max(self.clock.now() - t_fin0 + (t_disp - t_start),
                                 0.0), 0.25))
