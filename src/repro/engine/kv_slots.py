"""Slot-granular batched KV cache for the real engine.

Layout mirrors the model cache ({"k","v": (L, B_slots, C, Hk, D), "pos_map":
(B_slots, C)}), so ``model.decode`` runs directly on it. Slots are the
engine's unit of admission (the Pallas paged_attention kernel gives the
page-granular variant; at engine scale on CPU, slot granularity keeps the
JAX arrays static-shaped while remaining a faithful continuous-batching
memory manager)."""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np


class SlotKVCache:
    def __init__(self, n_layers: int, n_slots: int, capacity: int,
                 n_kv_heads: int, head_dim: int, dtype=jnp.float32):
        self.n_slots = n_slots
        self.capacity = capacity
        self.k = jnp.zeros((n_layers, n_slots, capacity, n_kv_heads, head_dim), dtype)
        self.v = jnp.zeros_like(self.k)
        self.pos_map = jnp.full((n_slots, capacity), -1, jnp.int32)
        self.free = list(range(n_slots))
        self.slot_of: Dict[int, int] = {}       # rid -> slot
        self.len_of: Dict[int, int] = {}        # rid -> context length

    # ------------------------------------------------------------- alloc
    def alloc(self, rid: int) -> Optional[int]:
        if not self.free:
            return None
        s = self.free.pop()
        self.slot_of[rid] = s
        return s

    def release(self, rid: int) -> None:
        s = self.slot_of.pop(rid)
        self.len_of.pop(rid, None)
        self.pos_map = self.pos_map.at[s].set(-1)
        self.free.append(s)

    # ------------------------------------------------------------- write
    def place(self, rid: int, k_seq, v_seq, length: int) -> None:
        """k_seq/v_seq (L, S, Hk, D) from a prefill cache (len S >= length)."""
        s = self.slot_of[rid]
        S = min(length, self.capacity)
        self.k = self.k.at[:, s, :S].set(k_seq[:, :S])
        self.v = self.v.at[:, s, :S].set(v_seq[:, :S])
        pm = np.full(self.capacity, -1, np.int32)
        pm[:S] = np.arange(S)
        self.pos_map = self.pos_map.at[s].set(jnp.asarray(pm))
        self.len_of[rid] = length

    def copy_prefix(self, src_rid: int, dst_rid: int, length: int) -> None:
        """Copy-on-extend (DESIGN.md §7): duplicate the first ``length``
        cached positions of ``src_rid``'s slot into ``dst_rid``'s freshly
        allocated slot, so the new request prefills only its suffix. The
        copy is the new request's own KV — the source stays untouched."""
        s = self.slot_of[src_rid]
        d = self.slot_of[dst_rid]
        L = min(length, self.len_of[src_rid], self.capacity)
        self.k = self.k.at[:, d, :L].set(self.k[:, s, :L])
        self.v = self.v.at[:, d, :L].set(self.v[:, s, :L])
        pm = np.full(self.capacity, -1, np.int32)
        pm[:L] = np.arange(L)
        self.pos_map = self.pos_map.at[d].set(jnp.asarray(pm))
        self.len_of[dst_rid] = L

    def extract(self, rid: int):
        """For KV transfer to another instance: (k (L,S,Hk,D), v, length)."""
        s = self.slot_of[rid]
        L = self.len_of[rid]
        return self.k[:, s, :L], self.v[:, s, :L], L

    def as_model_cache(self):
        return {"k": self.k, "v": self.v, "pos_map": self.pos_map}

    def update_from_model_cache(self, cache) -> None:
        self.k, self.v, self.pos_map = cache["k"], cache["v"], cache["pos_map"]
        for rid in self.len_of:
            self.len_of[rid] += 0  # lengths advance via advance()

    def advance(self, rid: int) -> None:
        self.len_of[rid] += 1
