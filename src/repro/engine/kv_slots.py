"""Slot-granular batched KV cache for the real engine.

Layout mirrors the model cache ({"k","v": (L, B_slots, C, Hk, D), "pos_map":
(B_slots, C)}), so ``model.decode`` runs directly on it. Slots are the
engine's unit of admission (the Pallas paged_attention kernel gives the
page-granular variant; at engine scale on CPU, slot granularity keeps the
JAX arrays static-shaped while remaining a faithful continuous-batching
memory manager).

All mutating slot operations (place / copy_prefix / release) are jitted
module-level functions with **donated** slab arguments, so they update the
cache buffers in place instead of the host-level ``.at[].set`` full-array
copies they replaced (DESIGN.md §9). ``extract`` stacks k and v into one
device array so a KV export costs a single blocking transfer.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.engine.state_slots import StateSlotsBase


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _kv_place(k, v, pos_map, k_seq, v_seq, slot, length):
    """Write k_seq/v_seq (L, S, Hk, D), S <= C, into ``slot``; positions
    [length, C) are marked invalid (S may exceed ``length`` by padding)."""
    k = lax.dynamic_update_slice(k, k_seq[:, None], (0, slot, 0, 0, 0))
    v = lax.dynamic_update_slice(v, v_seq[:, None], (0, slot, 0, 0, 0))
    idx = jnp.arange(pos_map.shape[1], dtype=jnp.int32)
    row = jnp.where(idx < length, idx, -1)
    pos_map = lax.dynamic_update_slice_in_dim(pos_map, row[None], slot, 0)
    return k, v, pos_map


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _kv_copy_prefix(k, v, pos_map, src, dst, length):
    """Duplicate ``src``'s slot into ``dst``; only [0, length) becomes
    valid (the copied tail beyond ``length`` is masked garbage that the
    suffix chunks overwrite)."""
    k = lax.dynamic_update_slice_in_dim(
        k, lax.dynamic_slice_in_dim(k, src, 1, 1), dst, 1)
    v = lax.dynamic_update_slice_in_dim(
        v, lax.dynamic_slice_in_dim(v, src, 1, 1), dst, 1)
    idx = jnp.arange(pos_map.shape[1], dtype=jnp.int32)
    row = jnp.where(idx < length, idx, -1)
    pos_map = lax.dynamic_update_slice_in_dim(pos_map, row[None], dst, 0)
    return k, v, pos_map


@partial(jax.jit, donate_argnums=(0,))
def _kv_clear_row(pos_map, slot):
    row = jnp.full((1, pos_map.shape[1]), -1, jnp.int32)
    return lax.dynamic_update_slice_in_dim(pos_map, row, slot, 0)


@jax.jit
def _kv_extract_stack(k, v, slot):
    """Stack a slot's k and v into one (2, L, C, Hk, D) array — a KV
    export is then a single device transfer."""
    return jnp.stack([lax.dynamic_index_in_dim(k, slot, 1, keepdims=False),
                      lax.dynamic_index_in_dim(v, slot, 1, keepdims=False)])


class SlotKVCache(StateSlotsBase):
    """Dense-family decode state: per-token KV rings. State grows O(L) with
    context, so block-granular prefix reuse and token-proportional migration
    sizing both apply (capability flags below)."""

    prefix_reuse = "block"
    needs_active_mask = False
    supports_speculation = True

    def __init__(self, n_layers: int, n_slots: int, capacity: int,
                 n_kv_heads: int, head_dim: int, dtype=jnp.float32):
        super().__init__(n_slots, capacity)
        self.k = jnp.zeros((n_layers, n_slots, capacity, n_kv_heads, head_dim), dtype)
        self.v = jnp.zeros_like(self.k)
        self.pos_map = jnp.full((n_slots, capacity), -1, jnp.int32)

    def _clear_slot(self, slot: int) -> None:
        # invalidating the pos_map row is enough — the k/v bytes are never
        # attended without a valid position, and the next occupant's
        # prefill overwrites them wholesale
        self.pos_map = _kv_clear_row(self.pos_map, slot)

    # -------------------------------------------------------------- slabs
    def slabs(self):
        """The donated arguments of a fused step. The caller owns putting
        the returned slabs back via :meth:`swap` — after a donating call
        the previous buffers are dead."""
        return self.k, self.v, self.pos_map

    def swap(self, k, v, pos_map) -> None:
        self.k, self.v, self.pos_map = k, v, pos_map

    # ------------------------------------------------------------- write
    def place(self, rid: int, k_seq, v_seq, length: int) -> None:
        """k_seq/v_seq (L, S, Hk, D) from a prefill cache (len S >= length)."""
        s = self.slot_of[rid]
        S = min(length, self.capacity)
        self.swap(*_kv_place(self.k, self.v, self.pos_map,
                             k_seq[:, :self.capacity], v_seq[:, :self.capacity],
                             s, S))
        self.len_of[rid] = length

    def copy_prefix(self, src_rid: int, dst_rid: int, length: int) -> None:
        """Copy-on-extend (DESIGN.md §7): duplicate the first ``length``
        cached positions of ``src_rid``'s slot into ``dst_rid``'s freshly
        allocated slot, so the new request prefills only its suffix. The
        copy is the new request's own KV — the source stays untouched."""
        s = self.slot_of[src_rid]
        d = self.slot_of[dst_rid]
        L = min(length, self.len_of[src_rid], self.capacity)
        self.swap(*_kv_copy_prefix(self.k, self.v, self.pos_map, s, d, L))
        self.len_of[dst_rid] = L

    def extract(self, rid: int):
        """For KV transfer to another instance: (k (L,S,Hk,D), v, length)
        as host arrays — one stacked device transfer."""
        s = self.slot_of[rid]
        L = self.len_of[rid]
        kv = np.asarray(_kv_extract_stack(self.k, self.v, s))
        return kv[0, :, :L], kv[1, :, :L], L

    # ---------------------------------------- family-agnostic migration
    def extract_state(self, rid: int):
        k, v, L = self.extract(rid)
        return [k, v], L

    def place_state(self, rid: int, payload, length: int) -> None:
        k, v = np.asarray(payload[0]), np.asarray(payload[1])
        # bucket-pad the context so the jitted place sees few shapes
        S_pad = min(-(-k.shape[1] // 32) * 32, self.capacity)
        if k.shape[1] < S_pad:
            pad = [(0, 0), (0, S_pad - k.shape[1]), (0, 0), (0, 0)]
            k, v = np.pad(k, pad), np.pad(v, pad)
        self.place(rid, jnp.asarray(k), jnp.asarray(v), length)

    def state_bytes(self, rid: int) -> int:
        # O(L) in context: tokens × per-token KV bytes (k and v rows)
        n_layers, _, _, hk, d = self.k.shape
        per_token = 2 * n_layers * hk * d * self.k.dtype.itemsize
        return per_token * self.len_of[rid]

    def as_model_cache(self):
        return {"k": self.k, "v": self.v, "pos_map": self.pos_map}

    def update_from_model_cache(self, cache) -> None:
        self.k, self.v, self.pos_map = cache["k"], cache["v"], cache["pos_map"]
        for rid in self.len_of:
            self.len_of[rid] += 0  # lengths advance via advance()
