"""qwen2-vl-2b [arXiv:2409.12191] — VLM; M-RoPE; vision encoder is a stub
(input_specs supplies pre-projected patch+text embeddings and M-RoPE positions)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    activation="swiglu",
    norm="rmsnorm",
    rope="mrope",
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),   # halves of head_dim/2 split across (t, h, w)
    tie_embeddings=True,
    max_seq_len=32768,
    source="arXiv:2409.12191",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=512, mrope_sections=(4, 6, 6), max_seq_len=128,
    )
