"""stablelm-12b [hf:stabilityai/stablelm-2-1_6b] — dense, partial RoPE, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    activation="swiglu",
    norm="layernorm",
    rope="partial",
    rope_fraction=0.25,
    tie_embeddings=False,
    max_seq_len=4096,
    source="hf:stabilityai/stablelm-2-1_6b",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=512,
    )
