"""gemma-2b [arXiv:2403.08295] — dense, GeGLU, head_dim=256, MQA (kv=1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    norm="rmsnorm",
    rope="standard",
    rope_theta=10000.0,
    embed_scale=True,
    tie_embeddings=True,
    max_seq_len=8192,
    source="arXiv:2403.08295",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32, d_ff=512,
        vocab_size=512, max_seq_len=128,
    )
