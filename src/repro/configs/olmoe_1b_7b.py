"""olmoe-1b-7b [arXiv:2409.02060] — MoE, 64 experts top-8, small experts."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    activation="swiglu",
    norm="rmsnorm",
    rope="standard",
    qk_norm=True,
    tie_embeddings=False,
    max_seq_len=4096,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    source="arXiv:2409.02060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32, d_ff=64,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=4.0),
    )
