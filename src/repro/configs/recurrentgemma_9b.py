"""recurrentgemma-9b [arXiv:2402.19427] — hybrid: RG-LRU + local attention, 1:2."""
from repro.configs.base import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,              # 38 blocks; pattern (rglru, rglru, attn) — final partial group
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    activation="geglu",
    norm="rmsnorm",
    rope="standard",
    embed_scale=True,
    tie_embeddings=True,
    sliding_window=2048,      # local attention window (always on)
    long_context_window=2048,
    hybrid=HybridConfig(pattern=("rglru", "rglru", "attn"), lru_width=4096,
                        local_window=2048, conv_width=4),
    source="arXiv:2402.19427",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32, d_ff=256,
        vocab_size=512, sliding_window=32,
        hybrid=HybridConfig(pattern=("rglru", "rglru", "attn"), lru_width=128,
                            local_window=32, conv_width=4),
    )
