"""qwen3-1.7b [hf:Qwen/Qwen3-8B family] — dense, qk_norm, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    activation="swiglu",
    norm="rmsnorm",
    rope="standard",
    rope_theta=1000000.0,
    qk_norm=True,
    tie_embeddings=True,
    max_seq_len=32768,
    source="hf:Qwen/Qwen3-8B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=512,
    )
