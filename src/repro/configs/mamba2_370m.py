"""mamba2-370m [arXiv:2405.21060] — attention-free SSM, SSD (state-space duality)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,                # attention-free
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    norm="rmsnorm",
    rope="none",
    tie_embeddings=True,
    long_context_window=None,   # not needed: state is O(1) in sequence length
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128, n_groups=1),
    source="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16, n_groups=1),
    )
