"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401

_MODULES = {
    "whisper-medium": "repro.configs.whisper_medium",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    # float32 on CPU for numerically-stable smoke tests
    return importlib.import_module(_MODULES[arch_id]).smoke_config().replace(dtype="float32")
