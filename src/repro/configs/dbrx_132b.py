"""dbrx-132b [hf:databricks/dbrx-base] — fine-grained MoE, 16 experts top-4."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    activation="swiglu",
    norm="rmsnorm",
    rope="standard",
    rope_theta=500000.0,
    tie_embeddings=False,
    max_seq_len=32768,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
    source="hf:databricks/dbrx-base",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=128,
        vocab_size=512,
        # capacity_factor >= E/K makes dispatch drop-free so decode==prefill exactly
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, capacity_factor=4.0),
    )
