"""Model/architecture configuration dataclasses.

Every assigned architecture gets one ``<arch>.py`` in this package exporting
``CONFIG`` (the full published config) and ``smoke_config()`` (a reduced
variant of the same family for CPU tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int          # per-expert FFN hidden size
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD parameters."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64        # SSD head dim P
    chunk: int = 128          # SSD chunk length
    n_groups: int = 1         # B/C groups (G)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma / Griffin: repeating block pattern."""
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")
    lru_width: Optional[int] = None      # defaults to d_model
    local_window: int = 2048             # local attention window
    conv_width: int = 4                  # temporal conv in recurrent block


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder for enc-dec archs (whisper). Frontend is a stub: input_specs
    provides precomputed frame embeddings of shape (B, n_frames, d_model)."""
    n_layers: int = 24
    n_frames: int = 1500


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # default d_model // n_heads
    activation: str = "swiglu"           # swiglu | geglu | gelu
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    rope: str = "standard"               # standard | partial | mrope | none | learned
    rope_fraction: float = 1.0           # fraction of head_dim rotated (chatglm=0.5)
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # qwen2-vl t/h/w halves
    qk_norm: bool = False
    logit_softcap: Optional[float] = None
    embed_scale: bool = False            # gemma: scale embeddings by sqrt(d_model)
    tie_embeddings: bool = True
    max_seq_len: int = 1 << 20
    sliding_window: Optional[int] = None          # always-on local window (hybrid attn)
    long_context_window: Optional[int] = 4096     # window used for the long_500k variant;
                                                  # None => arch cannot run long_500k
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encoder: Optional[EncoderConfig] = None
    dtype: str = "bfloat16"
    # Attention implementation on the dense serving path (DESIGN.md §9):
    # "reference" = pure-jnp sdpa with explicit masks; "pallas" = the
    # flash_prefill / paged_attention kernels (interpret-mode on CPU,
    # Mosaic on TPU). The pallas path assumes the serving engine's
    # contiguously-valid KV prefix contract and no logit_softcap.
    attn_impl: str = "reference"
    source: str = ""                     # citation
    # Dry-run only: fully unroll the layer scan so compiled.cost_analysis()
    # and the collective-bytes sum count every layer (XLA reports while-loop
    # bodies once). Training/serving keep the scan (small HLO, fast compile).
    scan_unroll: bool = False
    # Distribution: when set (by the step builders), models pin activations'
    # batch dim to these mesh axes via with_sharding_constraint — without it
    # GSPMD can de-shard the batch after the (vocab-sharded) embedding gather
    # and all-reduce FULL activations every layer (§Perf hillclimb A).
    act_batch_axes: Optional[Tuple[str, ...]] = None
    # §Perf hillclimb: sequence-parallel full-seq attention — shard q's seq
    # dim over this axis and replicate K/V (cheap for MQA/GQA), so scores are
    # computed block-locally with no partial-contraction all-reduce.
    attn_seq_axis: Optional[str] = None
    # §Perf hillclimb C: GShard-style grouped MoE dispatch. moe_groups splits
    # tokens into batch-aligned groups that sort/pack locally; the (G,E,C,d)
    # dispatch buffer is then resharded group-sharded -> expert-sharded on
    # moe_ep_axis, which GSPMD lowers to all-to-all instead of the one-hot
    # gather + 256GiB all-reduce the global-sort dispatch provokes.
    moe_ep_axis: Optional[str] = None
    moe_groups: int = 1

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (for 6ND model-flops accounting).
    def param_count(self, active_only: bool = False) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.head_dim_
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.activation in ("swiglu", "geglu"):
            mlp_per_ff = 3 * d
        else:
            mlp_per_ff = 2 * d
        n = 0
        if self.family == "moe":
            e = self.moe.top_k if active_only else self.moe.n_experts
            mlp = e * mlp_per_ff * self.moe.d_ff_expert + d * self.moe.n_experts
            n += self.n_layers * (attn + mlp + 2 * d)
        elif self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            # in_proj (z,x,B,C,dt), conv, out_proj
            per = d * (2 * di + 2 * s.n_groups * s.d_state + nh) + s.d_conv * (
                di + 2 * s.n_groups * s.d_state) + di * d + 2 * nh + di
            n += self.n_layers * (per + 2 * d)
        elif self.family == "hybrid":
            h = self.hybrid
            lw = h.lru_width or d
            rec = d * lw * 2 + h.conv_width * lw + lw * d + 2 * lw  # gates are lw*lw? see models
            rec += 2 * lw * lw  # input/recurrent gates
            mlp = mlp_per_ff * ff
            pat = list(h.pattern)
            per_group = sum(rec if p == "rglru" else attn for p in pat) + len(pat) * (mlp + 2 * d)
            n += (self.n_layers // len(pat)) * per_group
        else:
            mlp = mlp_per_ff * ff
            n += self.n_layers * (attn + mlp + 2 * d)
            if self.family == "encdec" and self.encoder is not None:
                # encoder layers + decoder cross-attn
                n += self.encoder.n_layers * (attn + mlp + 2 * d)
                n += self.n_layers * (attn + d)  # cross attention
        n += self.padded_vocab * d  # embedding (tied head)
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        return n


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
