"""whisper-medium [arXiv:2212.04356] — enc-dec audio; conv/mel frontend is a stub
(input_specs supplies precomputed frame embeddings)."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    rope="learned",           # whisper uses learned positional embeddings in the decoder
    tie_embeddings=True,
    max_seq_len=448,
    long_context_window=None,  # enc-dec full attention: long_500k skipped (DESIGN.md §4)
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
        vocab_size=512, encoder=EncoderConfig(n_layers=2, n_frames=16), max_seq_len=64,
    )
