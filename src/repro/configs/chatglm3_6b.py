"""chatglm3-6b [arXiv:2406.12793] — dense, 2d (partial) RoPE, GQA kv=2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    activation="swiglu",
    norm="rmsnorm",
    rope="partial",
    rope_fraction=0.5,        # 2d rope: rotate half of head_dim
    tie_embeddings=False,
    max_seq_len=32768,
    source="arXiv:2406.12793",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=512,
    )
