"""AdamW in pure JAX (pytree-generic); optimizer state shards like params."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(f32, params),
                      nu=jax.tree.map(f32, params))


def adamw_update(params, grads, state: AdamWState, *, lr=3e-4, b1=0.9,
                 b2=0.95, eps=1e-8, weight_decay=0.01):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        new_p = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda x: x[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda x: x[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda x: x[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)
