"""Dense decoder-only transformer (also serves the VLM backbone: the vision
frontend is a stub, so prefill/train consume precomputed embeddings + M-RoPE
positions; decode embeds new text tokens via the embedding table).

Cache layout (per model):
  {"k","v": (L, B, C, Hk, D), "pos_map": (B, C) int32 abs position per slot (-1 empty)}

``C`` (capacity) may be >= seq (full cache) or a sliding window (ring buffer,
slot = pos % C) — the pos_map-driven mask makes both behave identically.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm


def _use_pallas(cfg: ModelConfig) -> bool:
    """The serving engine's kernel switch (DESIGN.md §9). Softcapped logits
    (gemma) have no kernel variant yet — fail loudly rather than silently
    diverging from the reference numerics."""
    if cfg.attn_impl == "reference":
        return False
    if cfg.attn_impl != "pallas":
        raise ValueError(f"unknown attn_impl {cfg.attn_impl!r}")
    if cfg.logit_softcap:
        raise NotImplementedError(
            "attn_impl='pallas' does not support logit_softcap")
    return True


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    kg = cm.KeyGen(key)
    L = (cfg.n_layers,)
    layers = {
        "ln1": cm.init_norm(cfg, L, cfg.d_model, dtype),
        "attn": cm.init_attention(cfg, kg, L, dtype),
        "ln2": cm.init_norm(cfg, L, cfg.d_model, dtype),
        "mlp": cm.init_mlp(cfg, kg, L, dtype),
    }
    return {
        "tok": cm.init_embedding(cfg, kg, dtype),
        "layers": layers,
        "final_norm": cm.init_norm(cfg, (), cfg.d_model, dtype),
    }


def _block(cfg: ModelConfig, p, x, cos, sin, rope_dim, mask, kv_cache=None,
           slot=None, attn=None):
    """One transformer block. Returns (x, (k, v)) where k/v are either the
    full-seq kv (prefill/train) or the updated cache slabs (decode).
    ``attn`` overrides the reference sdpa (the Pallas kernel closures built
    by forward_seq/decode_step when cfg.attn_impl == "pallas")."""
    h = cm.apply_norm(cfg, p["ln1"], x)
    q, k, v = cm.attention_qkv(cfg, p["attn"], h, cos, sin, rope_dim)
    if kv_cache is None:
        q, k, v = cm.constrain_seq_attention(cfg, q, k, v)
        if attn is not None:
            o = attn(q, k, v)
        else:
            o = cm.sdpa(q, k, v, mask, cfg.logit_softcap)
        out_kv = (k, v)
    else:
        ck, cv = kv_cache
        B = x.shape[0]
        bidx = jnp.arange(B)
        ck = ck.at[bidx, slot].set(k[:, 0])
        cv = cv.at[bidx, slot].set(v[:, 0])
        if attn is not None:
            o = attn(q, ck, cv)
        else:
            o = cm.sdpa(q, ck, cv, mask, cfg.logit_softcap)
        out_kv = (ck, cv)
    x = x + o @ p["attn"]["wo"]
    h = cm.apply_norm(cfg, p["ln2"], x)
    x = x + cm.mlp(cfg, p["mlp"], h)
    return x, out_kv


def forward_seq(cfg: ModelConfig, params, x, positions, *, mrope_positions=None,
                window: Optional[int] = None, cache_capacity: Optional[int] = None,
                remat: bool = False):
    """Full-sequence forward. x (B,S,d) embeddings. Returns (logits, cache|None)."""
    B, S, _ = x.shape
    x = cm.constrain_batch(cfg, x)
    cos, sin, rope_dim = cm.rope_for(cfg, positions, mrope_positions)
    mask = cm.causal_mask(S, S, window=window)
    attn = None
    if _use_pallas(cfg):
        from repro.kernels.flash_prefill import flash_seq_op

        def attn(q, k, v):
            o = flash_seq_op(q, k, v, window=window)
            return o.reshape(B, S, -1)

    def body(x, lp):
        x, kv = _block(cfg, lp, x, cos, sin, rope_dim, mask, attn=attn)
        return cm.constrain_batch(cfg, x), kv

    if remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)

    cache = None
    if cache_capacity is not None:
        C = cache_capacity
        if C >= S:
            pad = [(0, 0), (0, 0), (0, C - S), (0, 0), (0, 0)]
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
            pos_map = jnp.where(jnp.arange(C)[None] < S,
                                jnp.arange(C)[None], -1)
            pos_map = jnp.broadcast_to(pos_map, (B, C)).astype(jnp.int32)
        else:
            # keep the last C positions, placed at their ring slots
            keep_pos = jnp.arange(S - C, S)                       # absolute
            slots = keep_pos % C
            ks_l, vs_l = ks[:, :, S - C:], vs[:, :, S - C:]
            ks = jnp.zeros_like(ks_l).at[:, :, slots].set(ks_l)
            vs = jnp.zeros_like(vs_l).at[:, :, slots].set(vs_l)
            pos_map = jnp.zeros((C,), jnp.int32).at[slots].set(keep_pos)
            pos_map = jnp.broadcast_to(pos_map[None], (B, C)).astype(jnp.int32)
        cache = {"k": ks, "v": vs, "pos_map": pos_map}
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, x, pos, *, mrope_positions=None,
                window: Optional[int] = None):
    """x (B,1,d) new-token embeddings; pos (B,) absolute positions.
    Returns (logits (B,1,V), new_cache).

    With ``cfg.attn_impl == "pallas"`` the per-layer attention runs the
    paged_attention kernel over the slot cache viewed as contiguous pages;
    that path assumes a non-ring cache whose positions [0, pos] are valid
    (the serving engine's contract) and masks by context length instead of
    the pos_map."""
    B = x.shape[0]
    x = cm.constrain_batch(cfg, x)
    C = cache["k"].shape[2]
    slot = (pos % C).astype(jnp.int32)
    pos_map = cache["pos_map"].at[jnp.arange(B), slot].set(pos.astype(jnp.int32))
    mask = cm.decode_mask(pos_map, pos, window=window)
    cos, sin, rope_dim = cm.rope_for(cfg, pos[:, None], mrope_positions)
    attn = None
    if _use_pallas(cfg):
        if window is not None:
            raise NotImplementedError(
                "attn_impl='pallas' decode has no sliding-window variant")
        from repro.kernels.flash_prefill.ops import _block_size
        from repro.kernels.paged_attention import paged_attention_op
        page = _block_size(C)             # pages tile the slot's capacity
        MP = C // page
        page_table = (jnp.arange(B)[:, None] * MP
                      + jnp.arange(MP)[None, :]).astype(jnp.int32)
        lengths = (pos + 1).astype(jnp.int32)

        def attn(q, ck, cv):
            Hk, D = ck.shape[2], ck.shape[3]
            kp = ck.reshape(B * MP, page, Hk, D)
            vp = cv.reshape(B * MP, page, Hk, D)
            o = paged_attention_op(q[:, 0], kp, vp, page_table, lengths)
            return o.reshape(B, 1, -1)

    def body(x, xs):
        lp, ck, cv = xs
        x, (ck, cv) = _block(cfg, lp, x, cos, sin, rope_dim, mask,
                             kv_cache=(ck, cv), slot=slot, attn=attn)
        return x, (ck, cv)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                           unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)
    return logits, {"k": ks, "v": vs, "pos_map": pos_map}


def prefill_chunk(cfg: ModelConfig, params, cache, x, offset, *,
                  mrope_positions=None, window=None):
    """Chunked prefill (paper §5.4): run a chunk x (B,Sq,d) whose tokens sit
    at absolute positions [offset, offset+Sq) against an existing cache
    (same layout as decode). Assumes a non-ring cache (capacity >= prompt
    length — the serving engine's slot caches satisfy this) and a shared
    integer ``offset`` across the batch rows being filled.

    Returns (logits (B,Sq,V), new_cache).

    With ``cfg.attn_impl == "pallas"`` attention runs the flash_prefill
    kernel (dynamic-offset variant, so ``offset`` stays traced) against the
    whole cache with positional causal masking; positions [0, offset) must
    be contiguously valid (the engine's KV prefix contract, DESIGN.md §9).
    """
    B, Sq, _ = x.shape
    x = cm.constrain_batch(cfg, x)
    positions = offset + jnp.arange(Sq)
    pos_map = lax.dynamic_update_slice(
        cache["pos_map"],
        jnp.broadcast_to(positions[None], (B, Sq)).astype(jnp.int32),
        (0, offset))
    mask = cm.chunk_mask(pos_map, positions, window=window)
    cos, sin, rope_dim = cm.rope_for(cfg, positions, mrope_positions)
    attn = None
    if _use_pallas(cfg):
        from repro.kernels.flash_prefill import flash_chunk_op

        def attn(q, ck, cv):
            o = flash_chunk_op(q, ck, cv, offset, window=window)
            return o.reshape(B, Sq, -1)

    def body(x, xs):
        lp, ck, cv = xs
        h = cm.apply_norm(cfg, lp["ln1"], x)
        q, k, v = cm.attention_qkv(cfg, lp["attn"], h, cos, sin, rope_dim)
        ck = lax.dynamic_update_slice(ck, k, (0, offset, 0, 0))
        cv = lax.dynamic_update_slice(cv, v, (0, offset, 0, 0))
        if attn is not None:
            o = attn(q, ck, cv)
        else:
            o = cm.sdpa(q, ck, cv, mask, cfg.logit_softcap)
        x = x + o @ lp["attn"]["wo"]
        x = x + cm.mlp(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x))
        return x, (ck, cv)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                           unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)
    return logits, {"k": ks, "v": vs, "pos_map": pos_map}


# ------------------------------------------------------------------ wrappers


def embed_tokens(cfg: ModelConfig, params, tokens):
    return cm.embed(cfg, params["tok"], tokens)
