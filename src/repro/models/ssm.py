"""Mamba2 (SSD — state-space duality, arXiv:2405.21060). Attention-free.

Block: in_proj -> [z | xBC | dt]; causal conv1d + silu over xBC; SSD scan;
gated RMSNorm; out_proj. The chunked SSD here is the pure-jnp reference — the
Pallas TPU kernel (repro/kernels/ssd_scan) implements the same chunk recurrence
with VMEM-resident state.

State cache (per model):
  {"conv": (L, B, W-1, d_conv_ch), "ssm": (L, B, H, P, N)}  — O(1) in seq len.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm


def _use_pallas(cfg: ModelConfig) -> bool:
    """Gate the Pallas SSD kernel onto the serving path (mirrors dense)."""
    if cfg.attn_impl == "reference":
        return False
    if cfg.attn_impl != "pallas":
        raise NotImplementedError(f"attn_impl={cfg.attn_impl!r}")
    return True


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    return s, di, H, s.head_dim, s.n_groups, s.d_state


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    kg = cm.KeyGen(key)
    s, di, H, P, G, N = _dims(cfg)
    conv_ch = di + 2 * G * N
    L = (cfg.n_layers,)
    layers = {
        "ln": cm.init_norm(cfg, L, cfg.d_model, dtype),
        "w_in": cm.ninit(kg(), L + (cfg.d_model, 2 * di + 2 * G * N + H), dtype),
        "conv_w": cm.ninit(kg(), L + (s.d_conv, conv_ch), dtype, scale=0.2),
        "conv_b": cm.zinit(L + (conv_ch,), dtype),
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)), L + (H,)
        ).astype(jnp.float32),
        "D": cm.oinit(L + (H,), jnp.float32),
        "dt_bias": cm.zinit(L + (H,), jnp.float32),
        "out_norm": cm.init_norm(cfg, L, di, dtype),
        "w_out": cm.ninit(kg(), L + (di, cfg.d_model), dtype),
    }
    return {
        "tok": cm.init_embedding(cfg, kg, dtype),
        "layers": layers,
        "final_norm": cm.init_norm(cfg, (), cfg.d_model, dtype),
    }


def _split_in(cfg, zxbcdt):
    _, di, H, P, G, N = _dims(cfg)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b, state=None):
    """xBC (B,S,Ch); w (W,Ch) depthwise. state (B,W-1,Ch) prepended if given.
    Returns (out (B,S,Ch), new_state (B,W-1,Ch))."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[2]), xBC.dtype)
    full = jnp.concatenate([state, xBC], axis=1)              # (B, S+W-1, Ch)
    out = sum(full[:, i:i + xBC.shape[1]] * w[i] for i in range(W)) + b
    new_state = full[:, full.shape[1] - (W - 1):]
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk, h0=None):
    """Reference chunked SSD.

    x (B,S,H,P) f32; dt (B,S,H) f32 (already softplus'ed); A (H,) negative;
    Bm, Cm (B,S,G,N); D (H,). h0 optional (B,H,P,N).
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q
    # expand groups to heads
    Bh = jnp.repeat(Bm, rep, axis=2)                          # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    la = dt * A                                               # (B,S,H) log decay
    la = la.reshape(Bsz, nc, Q, H)
    cum = jnp.cumsum(la, axis=2)                              # within-chunk cumsum
    xq = (x * dt[..., None]).reshape(Bsz, nc, Q, H, P)        # input with dt
    Bq = Bh.reshape(Bsz, nc, Q, H, N)
    Cq = Ch.reshape(Bsz, nc, Q, H, N)

    # --- intra-chunk (quadratic within chunk) ---
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask *inside* the exp: exp of masked-out (positive) entries would be inf
    # and poison gradients through the where.
    decay = jnp.exp(jnp.where(mask, seg, -jnp.inf))
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Cq, Bq)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", cb * decay, xq)

    # --- chunk states ---
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                   # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", tail, Bq, xq)
    chunk_decay = jnp.exp(jnp.sum(la, axis=2))                # (B,nc,H)

    # --- inter-chunk scan ---
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h, xs):
        st, dec = xs                                          # (B,H,P,N), (B,H)
        h_prev = h
        h = dec[:, :, None, None] * h + st
        return h, h_prev

    states_t = jnp.moveaxis(states, 1, 0)                     # (nc,B,H,P,N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)                 # (nc,B,H)
    h_final, h_prevs = lax.scan(step, h0, (states_t, decay_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                     # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                         jnp.exp(cum), Cq, h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P) + D[None, None, :, None] * x
    return y, h_final


def _block_seq(cfg, lp, u, conv_state=None, h0=None):
    """Full-seq Mamba2 block. u (B,S,d). Returns (out, conv_state, h_final)."""
    s, di, H, P, G, N = _dims(cfg)
    B, S, _ = u.shape
    x_in = cm.apply_norm(cfg, lp["ln"], u)
    zxbcdt = x_in @ lp["w_in"]
    z, xBC, dt = _split_in(cfg, zxbcdt)
    xBC, conv_state = _causal_conv(xBC, lp["conv_w"], lp["conv_b"], conv_state)
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P).astype(jnp.float32)
    Bm = Bm.reshape(B, S, G, N).astype(jnp.float32)
    Cm = Cm.reshape(B, S, G, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"])
    y, h_final = ssd_chunked(xs, dt, A, Bm, Cm, lp["D"], cfg.ssm.chunk, h0)
    y = y.reshape(B, S, di).astype(u.dtype)
    y = cm.apply_norm(cfg, lp["out_norm"], y * jax.nn.silu(z))
    return u + y @ lp["w_out"], conv_state, h_final


def _block_step(cfg, lp, u, conv_state, h):
    """Single-token step. u (B,1,d); conv_state (B,W-1,Ch); h (B,H,P,N)."""
    s, di, H, P, G, N = _dims(cfg)
    B = u.shape[0]
    x_in = cm.apply_norm(cfg, lp["ln"], u)
    zxbcdt = x_in @ lp["w_in"]
    z, xBC, dt = _split_in(cfg, zxbcdt)
    # conv over state + current input
    full = jnp.concatenate([conv_state, xBC], axis=1)          # (B,W,Ch)
    w = lp["conv_w"]
    out = jnp.einsum("bwc,wc->bc", full, w) + lp["conv_b"]
    xBC = jax.nn.silu(out)[:, None]                            # (B,1,Ch)
    new_conv = full[:, 1:]
    xs, Bm, Cm = jnp.split(xBC[:, 0], [di, di + G * N], axis=-1)
    xs = xs.reshape(B, H, P).astype(jnp.float32)
    Bm = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + lp["dt_bias"])  # (B,H)
    A = -jnp.exp(lp["A_log"])
    a = jnp.exp(dt * A)                                        # (B,H)
    h = a[:, :, None, None] * h + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bm, xs)
    y = jnp.einsum("bhn,bhpn->bhp", Cm, h) + lp["D"][None, :, None] * xs
    y = y.reshape(B, 1, di).astype(u.dtype)
    y = cm.apply_norm(cfg, lp["out_norm"], y * jax.nn.silu(z))
    return u + y @ lp["w_out"], new_conv, h


def _block_chunk(cfg, lp, u, conv_state, h0, valid_len):
    """Mamba2 block over one serving chunk with carried state.

    u (B,S,d) where positions >= ``valid_len`` are padding; conv_state
    (B,W-1,Ch); h0 (B,H,P,N). dt is zeroed at pad positions, so their decay
    is exp(0)=1 and their input contribution dt*x is 0 — the recurrent state
    passes through padding exactly, making the returned state the state
    after ``valid_len`` real tokens. Pad outputs are garbage and must be
    ignored by the caller. Returns (out, new_conv, h_final)."""
    s, di, H, P, G, N = _dims(cfg)
    B, S, _ = u.shape
    W = s.d_conv
    x_in = cm.apply_norm(cfg, lp["ln"], u)
    zxbcdt = x_in @ lp["w_in"]
    z, xBC, dt = _split_in(cfg, zxbcdt)
    full = jnp.concatenate([conv_state, xBC], axis=1)          # (B,S+W-1,Ch)
    out = sum(full[:, i:i + S] * lp["conv_w"][i] for i in range(W)) + lp["conv_b"]
    xBC = jax.nn.silu(out)
    # last W-1 *valid* inputs (reaching into the old state when valid_len<W-1)
    new_conv = lax.dynamic_slice_in_dim(full, valid_len, W - 1, axis=1)
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P).astype(jnp.float32)
    Bm = Bm.reshape(B, S, G, N).astype(jnp.float32)
    Cm = Cm.reshape(B, S, G, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    valid = (jnp.arange(S) < valid_len).astype(jnp.float32)
    dt = dt * valid[None, :, None]
    A = -jnp.exp(lp["A_log"])
    Q = cfg.ssm.chunk
    pad = (-S) % Q                    # static: chunk widths need not align
    if pad:
        zeros = lambda a: jnp.pad(a, [(0, pad if ax == 1 else 0)
                                      for ax in range(a.ndim)])
        xs, Bm, Cm, dt = zeros(xs), zeros(Bm), zeros(Cm), zeros(dt)
    if _use_pallas(cfg):
        from repro.kernels.ssd_scan import ssd_scan_op
        rep = H // G
        la = dt * A                                            # (B,Sp,H)
        y, h_final = ssd_scan_op(
            xs * dt[..., None], la, jnp.repeat(Bm, rep, axis=2),
            jnp.repeat(Cm, rep, axis=2), Q, h0=h0)
        y = y + lp["D"][None, None, :, None] * xs              # skip term
    else:
        y, h_final = ssd_chunked(xs, dt, A, Bm, Cm, lp["D"], Q, h0)
    y = y[:, :S].reshape(B, S, di).astype(u.dtype)
    y = cm.apply_norm(cfg, lp["out_norm"], y * jax.nn.silu(z))
    return u + y @ lp["w_out"], new_conv, h_final


def init_cache(cfg: ModelConfig, batch: int, capacity=None):
    """Zero decode state for ``batch`` fresh streams — O(1) in context length.
    ``capacity`` is accepted for interface parity with attention caches."""
    del capacity
    s, di, H, P, G, N = _dims(cfg)
    conv_ch = di + 2 * G * N
    L = cfg.n_layers
    return {"conv": jnp.zeros((L, batch, s.d_conv - 1, conv_ch),
                              jnp.dtype(cfg.dtype)),
            "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32)}


def prefill_chunk(cfg: ModelConfig, params, cache, x, offset=None, *,
                  valid_len, window=None):
    """Serving chunked prefill: advance the decode state by one chunk.

    x (B,S,d) with positions >= ``valid_len`` padding; cache is the decode
    state {"conv": (L,B,W-1,Ch), "ssm": (L,B,H,P,N)} and is returned
    advanced past the chunk's ``valid_len`` real tokens. ``offset`` is
    accepted for interface parity with the attention families but unused —
    the recurrent state carries all positional context."""
    del offset, window
    x = cm.constrain_batch(cfg, x)

    def body(xc, xs):
        lp, conv, h = xs
        out, conv, h = _block_chunk(cfg, lp, xc, conv, h, valid_len)
        return cm.constrain_batch(cfg, out), (conv, h)

    x, (convs, hs) = lax.scan(body, x,
                              (params["layers"], cache["conv"], cache["ssm"]),
                              unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)
    return logits, {"conv": convs, "ssm": hs}


def forward_seq(cfg: ModelConfig, params, x, positions=None, *, window=None,
                cache_capacity=None, remat: bool = False):
    """x (B,S,d). Returns (logits, cache|None)."""
    del positions, window
    want_cache = cache_capacity is not None
    x = cm.constrain_batch(cfg, x)

    def body(xc, lp):
        x = xc
        x, conv_state, h = _block_seq(cfg, lp, x)
        return cm.constrain_batch(cfg, x), (conv_state, h)

    if remat:
        body = jax.checkpoint(body)
    x, (convs, hs) = lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)
    cache = {"conv": convs, "ssm": hs} if want_cache else None
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, x, pos=None, *, window=None):
    del pos, window
    x = cm.constrain_batch(cfg, x)

    def body(xc, xs):
        lp, conv, h = xs
        x = xc
        x, conv, h = _block_step(cfg, lp, x, conv, h)
        return x, (conv, h)

    x, (convs, hs) = lax.scan(body, x, (params["layers"], cache["conv"], cache["ssm"]),
                            unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)
    return logits, {"conv": convs, "ssm": hs}


def embed_tokens(cfg: ModelConfig, params, tokens):
    return cm.embed(cfg, params["tok"], tokens)
