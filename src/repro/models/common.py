"""Shared building blocks for the model zoo: norms, RoPE variants, GQA attention
(pure-jnp reference path — Pallas kernels live in repro.kernels and are used by
the serving engine), MLPs and initialisation helpers.

All models are functional: params are nested dicts of jnp arrays, stacked with a
leading layer dimension and consumed via ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------- init utils


def ninit(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def zinit(shape, dtype):
    return jnp.zeros(shape, dtype)


def oinit(shape, dtype):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Deterministic per-leaf key generator."""

    def __init__(self, key):
        self._key = key
        self._i = 0

    def __call__(self):
        self._i += 1
        return jax.random.fold_in(self._key, self._i)


# ---------------------------------------------------------------------- norm


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(cfg: ModelConfig, shape_prefix, d, dtype):
    if cfg.norm == "rmsnorm":
        return {"scale": zinit(shape_prefix + (d,), dtype)}
    return {"scale": oinit(shape_prefix + (d,), dtype),
            "bias": zinit(shape_prefix + (d,), dtype)}


# ---------------------------------------------------------------------- rope


def rope_angles(positions, rope_dim: int, theta: float):
    """positions (..., S) -> cos,sin (..., S, rope_dim//2)."""
    half = rope_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions, rope_dim: int, theta: float, sections):
    """qwen2-vl M-RoPE. positions (B, 3, S) (t/h/w); sections sum to rope_dim//2.

    Frequency channel j takes its position from the section it belongs to.
    """
    half = rope_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    pos = positions.astype(jnp.float32)[:, sec_ids, :]             # (B, half, S)
    ang = jnp.moveaxis(pos, 1, -1) * inv           # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rope_dim: int):
    """x (B, S, H, D); cos/sin broadcastable to (B, S, 1, rope_dim//2)."""
    half = rope_dim // 2
    xr, xp = x[..., :rope_dim], x[..., rope_dim:]
    x1, x2 = xr[..., :half], xr[..., half:]
    cos = cos.astype(jnp.float32)
    sin = sin.astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = x1f * cos - x2f * sin
    r2 = x2f * cos + x1f * sin
    return jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype), xp], axis=-1)


def rope_for(cfg: ModelConfig, positions, mrope_positions=None):
    """Returns (cos, sin, rope_dim) ready for apply_rope, or (None, None, 0)."""
    hd = cfg.head_dim_
    if cfg.rope in ("none", "learned"):
        return None, None, 0
    if cfg.rope == "mrope":
        rope_dim = hd
        cos, sin = mrope_angles(mrope_positions, rope_dim, cfg.rope_theta,
                                cfg.mrope_sections)
        return cos[:, :, None, :], sin[:, :, None, :], rope_dim
    rope_dim = hd if cfg.rope == "standard" else int(hd * cfg.rope_fraction)
    rope_dim -= rope_dim % 2
    cos, sin = rope_angles(positions, rope_dim, cfg.rope_theta)
    # positions (S,) -> (1,S,1,half); (B,S) -> (B,S,1,half)
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    return cos[:, :, None, :], sin[:, :, None, :], rope_dim


# ----------------------------------------------------------------- attention


def sdpa(q, k, v, mask, logit_softcap: Optional[float] = None):
    """Reference GQA attention. q (B,S,H,D); k,v (B,T,Hk,D); mask additive,
    broadcastable to (B,Hk,G,S,T). Returns (B,S,H*D) (heads flattened, ready
    for the output projection)."""
    B, S, H, D = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qg = q.reshape(B, S, Hk, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, kf) * (1.0 / math.sqrt(D))
    if logit_softcap:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    scores = scores + mask
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H * D).astype(q.dtype)


NEG_INF = -1e30


def causal_mask(S: int, T: int, q_offset=0, window: Optional[int] = None):
    """(1,1,1,S,T) additive mask; query i has absolute position q_offset+i,
    kv j has absolute position j."""
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(T)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF)[None, None, None].astype(jnp.float32)


def decode_mask(kv_positions, pos, window: Optional[int] = None):
    """kv_positions (B,T) absolute position per cache slot (-1 empty);
    pos (B,) current query position. -> (B,1,1,1,T)."""
    ok = (kv_positions >= 0) & (kv_positions <= pos[:, None])
    if window is not None:
        ok &= kv_positions > (pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)[:, None, None, None].astype(jnp.float32)


def chunk_mask(kv_positions, q_positions, window: Optional[int] = None):
    """Chunked-prefill mask: queries at absolute positions q_positions (Sq,)
    attend to cache slots whose pos_map (B,T) entry is valid and causal.
    -> (B,1,1,Sq,T)."""
    kv = kv_positions[:, None, :]                  # (B,1,T)
    q = q_positions[None, :, None]                 # (1,Sq,1)
    ok = (kv >= 0) & (kv <= q)
    if window is not None:
        ok &= kv > (q - window)
    return jnp.where(ok, 0.0, NEG_INF)[:, None, None].astype(jnp.float32)


# ----------------------------------------------------------- attention block


def init_attention(cfg: ModelConfig, kg: KeyGen, prefix, dtype, d_model=None):
    d = d_model or cfg.d_model
    hd = cfg.head_dim_
    p = {
        "wq": ninit(kg(), prefix + (d, cfg.n_heads * hd), dtype),
        "wk": ninit(kg(), prefix + (d, cfg.n_kv_heads * hd), dtype),
        "wv": ninit(kg(), prefix + (d, cfg.n_kv_heads * hd), dtype),
        "wo": ninit(kg(), prefix + (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = zinit(prefix + (hd,), dtype)
        p["k_norm"] = zinit(prefix + (hd,), dtype)
    return p


def attention_qkv(cfg: ModelConfig, p, x, cos, sin, rope_dim):
    """Project + rope. x (B,S,d) -> q (B,S,H,D), k,v (B,S,Hk,D)."""
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if rope_dim:
        q = apply_rope(q, cos, sin, rope_dim)
        k = apply_rope(k, cos, sin, rope_dim)
    return q, k, v


# ----------------------------------------------------------------------- mlp


def init_mlp(cfg: ModelConfig, kg: KeyGen, prefix, dtype, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": ninit(kg(), prefix + (d, ff), dtype),
            "w_up": ninit(kg(), prefix + (d, ff), dtype),
            "w_down": ninit(kg(), prefix + (ff, d), dtype),
        }
    return {
        "w_in": ninit(kg(), prefix + (d, ff), dtype),
        "w_out": ninit(kg(), prefix + (ff, d), dtype),
    }


def mlp(cfg: ModelConfig, p, x):
    if cfg.activation == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if cfg.activation == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]


# ----------------------------------------------------------------- embedding


def init_embedding(cfg: ModelConfig, kg: KeyGen, dtype):
    p = {"embed": ninit(kg(), (cfg.padded_vocab, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = ninit(kg(), (cfg.d_model, cfg.padded_vocab), dtype)
    return p


def embed(cfg: ModelConfig, p, tokens):
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        return x @ p["embed"].T
    return x @ p["unembed"]


def lm_loss(cfg: ModelConfig, logits, labels, ignore=-1):
    """Cross-entropy over padded vocab; labels (B,S) int32; logits (B,S,V)."""
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    ok = labels != ignore
    return jnp.sum(nll * ok) / jnp.maximum(jnp.sum(ok), 1)


def constrain_seq_attention(cfg: ModelConfig, q, k, v):
    """Sequence-parallel attention constraints (full-seq prefill/train only):
    q blocks shard the seq dim over cfg.attn_seq_axis; K/V replicate along it
    (MQA/GQA K/V are small). Scores then stay block-local."""
    if not cfg.attn_seq_axis:
        return q, k, v
    from jax.sharding import PartitionSpec as P
    ax = cfg.act_batch_axes
    b = (ax if ax and len(ax) > 1 else (ax[0] if ax else None))
    s = cfg.attn_seq_axis
    q = jax.lax.with_sharding_constraint(q, P(b, s, None, None))
    k = jax.lax.with_sharding_constraint(k, P(b, None, None, None))
    v = jax.lax.with_sharding_constraint(v, P(b, None, None, None))
    return q, k, v


def constrain_batch(cfg: ModelConfig, x):
    """Pin the leading (batch) dim of an activation to the configured mesh
    axes (no-op when cfg.act_batch_axes is unset — CPU/engine paths)."""
    if not cfg.act_batch_axes:
        return x
    ax = tuple(cfg.act_batch_axes)
    spec = (ax if len(ax) > 1 else ax[0],) + (None,) * (x.ndim - 1)
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
