"""Whisper-style encoder–decoder (arXiv:2212.04356). The mel-spectrogram +
conv frontend is a STUB per the assignment: inputs are precomputed frame
embeddings (B, n_frames, d_model). We implement the transformer backbone:
bidirectional encoder (sinusoidal positions) + causal decoder (learned
positions, cross-attention).

Cache:
  {"k","v": (L,B,C,H,D) decoder self-attn (ring-capable),
   "ck","cv": (L,B,F,H,D) cross-attn (computed once at prefill),
   "pos_map": (B,C)}
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    kg = cm.KeyGen(key)
    Le = (cfg.encoder.n_layers,)
    Ld = (cfg.n_layers,)
    enc_layers = {
        "ln1": cm.init_norm(cfg, Le, cfg.d_model, dtype),
        "attn": cm.init_attention(cfg, kg, Le, dtype),
        "ln2": cm.init_norm(cfg, Le, cfg.d_model, dtype),
        "mlp": cm.init_mlp(cfg, kg, Le, dtype),
    }
    dec_layers = {
        "ln1": cm.init_norm(cfg, Ld, cfg.d_model, dtype),
        "self_attn": cm.init_attention(cfg, kg, Ld, dtype),
        "ln_x": cm.init_norm(cfg, Ld, cfg.d_model, dtype),
        "cross_attn": cm.init_attention(cfg, kg, Ld, dtype),
        "ln2": cm.init_norm(cfg, Ld, cfg.d_model, dtype),
        "mlp": cm.init_mlp(cfg, kg, Ld, dtype),
    }
    return {
        "tok": cm.init_embedding(cfg, kg, dtype),
        "pos": cm.ninit(kg(), (cfg.max_seq_len, cfg.d_model), dtype),
        "enc_layers": enc_layers,
        "enc_norm": cm.init_norm(cfg, (), cfg.d_model, dtype),
        "dec_layers": dec_layers,
        "final_norm": cm.init_norm(cfg, (), cfg.d_model, dtype),
    }


def encode(cfg: ModelConfig, params, audio_embeds, remat: bool = False):
    """audio_embeds (B, F, d) — stub frontend output. Returns (B, F, d)."""
    x = audio_embeds + cm.sinusoidal_positions(
        audio_embeds.shape[1], cfg.d_model).astype(audio_embeds.dtype)[None]
    x = cm.constrain_batch(cfg, x)
    zero_mask = jnp.zeros((1, 1, 1, 1, 1), jnp.float32)

    def body(x, lp):
        h = cm.apply_norm(cfg, lp["ln1"], x)
        q, k, v = cm.attention_qkv(cfg, lp["attn"], h, None, None, 0)
        x = x + cm.sdpa(q, k, v, zero_mask) @ lp["attn"]["wo"]
        x = x + cm.mlp(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x))
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["enc_layers"], unroll=cfg.scan_unroll)
    return cm.apply_norm(cfg, params["enc_norm"], x)


def _dec_block(cfg, lp, x, mask, cross_kv, self_kv=None, slot=None):
    h = cm.apply_norm(cfg, lp["ln1"], x)
    q, k, v = cm.attention_qkv(cfg, lp["self_attn"], h, None, None, 0)
    if self_kv is None:
        o = cm.sdpa(q, k, v, mask)
        out_kv = (k, v)
    else:
        ck_, cv_ = self_kv
        bidx = jnp.arange(x.shape[0])
        ck_ = ck_.at[bidx, slot].set(k[:, 0])
        cv_ = cv_.at[bidx, slot].set(v[:, 0])
        o = cm.sdpa(q, ck_, cv_, mask)
        out_kv = (ck_, cv_)
    x = x + o @ lp["self_attn"]["wo"]
    # cross attention (kv precomputed from encoder output)
    h = cm.apply_norm(cfg, lp["ln_x"], x)
    B, S, _ = h.shape
    hd = cfg.head_dim_
    qx = (h @ lp["cross_attn"]["wq"]).reshape(B, S, cfg.n_heads, hd)
    ckv, cvv = cross_kv
    ox = cm.sdpa(qx, ckv, cvv, jnp.zeros((1, 1, 1, 1, 1), jnp.float32))
    x = x + ox @ lp["cross_attn"]["wo"]
    x = x + cm.mlp(cfg, lp["mlp"], cm.apply_norm(cfg, lp["ln2"], x))
    return x, out_kv


def cross_kv_all(cfg: ModelConfig, params, enc_out):
    """Precompute cross-attention K/V for every decoder layer: (L,B,F,H,D)."""
    B, F, _ = enc_out.shape
    hd = cfg.head_dim_

    def f(_, lp):
        k = (enc_out @ lp["cross_attn"]["wk"]).reshape(B, F, cfg.n_kv_heads, hd)
        v = (enc_out @ lp["cross_attn"]["wv"]).reshape(B, F, cfg.n_kv_heads, hd)
        return None, (k, v)

    _, (ck, cv) = lax.scan(f, None, params["dec_layers"], unroll=cfg.scan_unroll)
    return ck, cv


def forward_seq(cfg: ModelConfig, params, tokens, audio_embeds, *,
                cache_capacity: Optional[int] = None, remat: bool = False,
                enc_out=None):
    """Teacher-forced decoder pass (train/prefill). Returns (logits, cache)."""
    if enc_out is None:
        enc_out = encode(cfg, params, audio_embeds, remat=remat)
    B, S = tokens.shape
    x = cm.embed(cfg, params["tok"], tokens)
    x = x + params["pos"][:S][None]
    x = cm.constrain_batch(cfg, x)
    mask = cm.causal_mask(S, S)
    ck, cv = cross_kv_all(cfg, params, enc_out)

    def body(x, xs):
        lp, ckl, cvl = xs
        x, kv = _dec_block(cfg, lp, x, mask, (ckl, cvl))
        return x, kv

    if remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = lax.scan(body, x, (params["dec_layers"], ck, cv),
                           unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)

    cache = None
    if cache_capacity is not None:
        C = cache_capacity
        assert C >= S, "whisper decoder cache must hold the full prefix"
        pad = [(0, 0), (0, 0), (0, C - S), (0, 0), (0, 0)]
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        pos_map = jnp.where(jnp.arange(C)[None] < S, jnp.arange(C)[None], -1)
        pos_map = jnp.broadcast_to(pos_map, (B, C)).astype(jnp.int32)
        cache = {"k": ks, "v": vs, "ck": ck, "cv": cv, "pos_map": pos_map}
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    """token (B,1) int32; pos (B,)."""
    B = token.shape[0]
    C = cache["k"].shape[2]
    slot = (pos % C).astype(jnp.int32)
    pos_map = cache["pos_map"].at[jnp.arange(B), slot].set(pos.astype(jnp.int32))
    mask = cm.decode_mask(pos_map, pos)
    x = cm.embed(cfg, params["tok"], token)
    x = x + jnp.take(params["pos"], jnp.minimum(pos, cfg.max_seq_len - 1),
                     axis=0)[:, None]
    x = cm.constrain_batch(cfg, x)

    def body(x, xs):
        lp, ck_, cv_, ckl, cvl = xs
        x, (ck_, cv_) = _dec_block(cfg, lp, x, mask, (ckl, cvl),
                                   self_kv=(ck_, cv_), slot=slot)
        return x, (ck_, cv_)

    x, (ks, vs) = lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]), unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"],
                    "pos_map": pos_map}
