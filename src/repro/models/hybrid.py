"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): repeating
(RG-LRU, RG-LRU, local-attention) blocks, GeGLU MLPs, MQA local attention.

Layer pattern: ``len(pattern)`` layers per scanned group; a trailing partial
group (n_layers % len(pattern) leading entries of the pattern) is handled as a
separately-scanned "tail" stack (38 = 12×3 + 2 for recurrentgemma-9b).

Cache:
  groups: {"conv{i}": (G,B,W-1,lw), "h{i}": (G,B,lw) per rglru slot,
           "k","v": (G,B,C,Hk,D)}  with C = local attention window (ring)
  tail:   {"conv{i}", "h{i}"}
  pos_map: (B, C)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels.rglru_scan import rglru_scan_op
from repro.models import common as cm

_RGLRU_C = 8.0


def _use_pallas(cfg: ModelConfig) -> bool:
    """Gate the Pallas RG-LRU scan onto the serving path (mirrors dense)."""
    if cfg.attn_impl == "reference":
        return False
    if cfg.attn_impl != "pallas":
        raise NotImplementedError(f"attn_impl={cfg.attn_impl!r}")
    return True


def _pattern(cfg: ModelConfig):
    pat = cfg.hybrid.pattern
    n_groups = cfg.n_layers // len(pat)
    tail = cfg.n_layers % len(pat)
    return pat, n_groups, tail


def _lru_width(cfg):
    return cfg.hybrid.lru_width or cfg.d_model


# ------------------------------------------------------------------- params


def _init_rglru(cfg, kg, prefix, dtype):
    d, lw = cfg.d_model, _lru_width(cfg)
    cw = cfg.hybrid.conv_width
    return {
        "w_y": cm.ninit(kg(), prefix + (d, lw), dtype),
        "w_x": cm.ninit(kg(), prefix + (d, lw), dtype),
        "conv_w": cm.ninit(kg(), prefix + (cw, lw), dtype, scale=0.2),
        "conv_b": cm.zinit(prefix + (lw,), dtype),
        "w_a": cm.ninit(kg(), prefix + (lw, lw), dtype),
        "b_a": cm.zinit(prefix + (lw,), jnp.float32),
        "w_i": cm.ninit(kg(), prefix + (lw, lw), dtype),
        "b_i": cm.zinit(prefix + (lw,), jnp.float32),
        "lam": jnp.broadcast_to(jnp.linspace(0.5, 4.0, lw, dtype=jnp.float32),
                                prefix + (lw,)),
        "w_o": cm.ninit(kg(), prefix + (lw, d), dtype),
    }


def _init_sub(cfg, kg, kind, prefix, dtype):
    p = {"ln1": cm.init_norm(cfg, prefix, cfg.d_model, dtype),
         "ln2": cm.init_norm(cfg, prefix, cfg.d_model, dtype),
         "mlp": cm.init_mlp(cfg, kg, prefix, dtype)}
    if kind == "attn":
        p["attn"] = cm.init_attention(cfg, kg, prefix, dtype)
    else:
        p["rglru"] = _init_rglru(cfg, kg, prefix, dtype)
    return p


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    kg = cm.KeyGen(key)
    pat, n_groups, tail = _pattern(cfg)
    groups = {f"sub{i}_{kind}": _init_sub(cfg, kg, kind, (n_groups,), dtype)
              for i, kind in enumerate(pat)}
    params = {
        "tok": cm.init_embedding(cfg, kg, dtype),
        "groups": groups,
        "final_norm": cm.init_norm(cfg, (), cfg.d_model, dtype),
    }
    # Tail = n_layers % len(pattern) extra layers; they take the leading kinds
    # of the pattern, which must be homogeneous to scan as one stack.
    if tail and any(k != pat[0] for k in pat[:tail]):
        raise NotImplementedError("heterogeneous tail not supported")
    if tail:
        params["tail"] = {f"sub0_{pat[0]}": _init_sub(cfg, kg, pat[0], (tail,), dtype)}
    return params


# -------------------------------------------------------------------- rglru


def _rglru_gates(p, u, x_in):
    """u: conv output (B,S,lw); x_in: pre-conv branch input for gates (B,S,lw).
    Returns log_a (f32), gated input (f32)."""
    r = jax.nn.sigmoid((x_in @ p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid((x_in @ p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i * u.astype(jnp.float32)
    return log_a, gated


def _rglru_scan(log_a, gated, h0=None):
    """Associative scan of h_t = a_t h_{t-1} + b_t along axis 1."""
    a = jnp.exp(log_a)

    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    acc_a, h = lax.associative_scan(comb, (a, gated), axis=1)
    if h0 is not None:
        h = h + acc_a * h0[:, None, :]
    return h


def _rglru_seq(cfg, p, x, conv_state=None, h0=None):
    """Full recurrent mixer block. x (B,S,d) normed input."""
    y = jax.nn.gelu(x @ p["w_y"])
    u = x @ p["w_x"]
    cw = cfg.hybrid.conv_width
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], cw - 1, u.shape[-1]), u.dtype)
    full = jnp.concatenate([conv_state, u], axis=1)
    conv = sum(full[:, i:i + u.shape[1]] * p["conv_w"][i] for i in range(cw))
    conv = conv + p["conv_b"]
    new_conv = full[:, full.shape[1] - (cw - 1):]
    log_a, gated = _rglru_gates(p, conv, u)
    h = _rglru_scan(log_a, gated, h0)
    out = (y.astype(jnp.float32) * h).astype(x.dtype) @ p["w_o"]
    return out, new_conv, h[:, -1]


def _rglru_chunk(cfg, p, x, conv_state, h0, valid_len, use_pallas):
    """Valid-length-masked recurrent mixer for one prefill chunk.

    Pad positions (index >= valid_len) get log_a = 0 (a = 1) and gated = 0, so
    the hidden state passes through them unchanged: h[:, -1] equals the state
    after the last *valid* token regardless of padding, and the conv tail is
    sliced at ``valid_len`` rather than at the padded end.
    """
    S = x.shape[1]
    y = jax.nn.gelu(x @ p["w_y"])
    u = x @ p["w_x"]
    cw = cfg.hybrid.conv_width
    full = jnp.concatenate([conv_state, u], axis=1)
    conv = sum(full[:, i:i + S] * p["conv_w"][i] for i in range(cw))
    conv = conv + p["conv_b"]
    new_conv = lax.dynamic_slice_in_dim(full, valid_len, cw - 1, axis=1)
    log_a, gated = _rglru_gates(p, conv, u)
    valid = (jnp.arange(S) < valid_len)[None, :, None]
    log_a = jnp.where(valid, log_a, 0.0)
    gated = gated * valid
    if use_pallas:
        h, h_last = rglru_scan_op(log_a, gated, h0)
    else:
        h = _rglru_scan(log_a, gated, h0)
        h_last = h[:, -1]
    out = (y.astype(jnp.float32) * h).astype(x.dtype) @ p["w_o"]
    return out, new_conv, h_last


def _rglru_step(cfg, p, x, conv_state, h):
    """Single token. x (B,1,d); h (B,lw) f32."""
    y = jax.nn.gelu(x @ p["w_y"])
    u = x @ p["w_x"]
    full = jnp.concatenate([conv_state, u], axis=1)            # (B,cw,lw)
    conv = jnp.einsum("bwc,wc->bc", full, p["conv_w"]) + p["conv_b"]
    new_conv = full[:, 1:]
    log_a, gated = _rglru_gates(p, conv[:, None], u)
    h = jnp.exp(log_a[:, 0]) * h + gated[:, 0]
    out = (y[:, 0].astype(jnp.float32) * h).astype(x.dtype) @ p["w_o"]
    return out[:, None], new_conv, h


# ------------------------------------------------------------------- blocks


def _sub_seq(cfg, kind, p, x, cos, sin, rope_dim, mask, conv=None, h0=None):
    h_in = cm.apply_norm(cfg, p["ln1"], x)
    if kind == "attn":
        q, k, v = cm.attention_qkv(cfg, p["attn"], h_in, cos, sin, rope_dim)
        o = cm.sdpa(q, k, v, mask, cfg.logit_softcap)
        x = x + o @ p["attn"]["wo"]
        extra = (k, v)
    else:
        o, new_conv, h_last = _rglru_seq(cfg, p["rglru"], h_in, conv, h0)
        x = x + o
        extra = (new_conv, h_last)
    x = x + cm.mlp(cfg, p["mlp"], cm.apply_norm(cfg, p["ln2"], x))
    return x, extra


def _sub_step(cfg, kind, p, x, cos, sin, rope_dim, mask, state):
    h_in = cm.apply_norm(cfg, p["ln1"], x)
    if kind == "attn":
        ck, cv, slot = state
        q, k, v = cm.attention_qkv(cfg, p["attn"], h_in, cos, sin, rope_dim)
        bidx = jnp.arange(x.shape[0])
        ck = ck.at[bidx, slot].set(k[:, 0])
        cv = cv.at[bidx, slot].set(v[:, 0])
        o = cm.sdpa(q, ck, cv, mask, cfg.logit_softcap)
        x = x + o @ p["attn"]["wo"]
        extra = (ck, cv)
    else:
        conv, h = state
        o, conv, h = _rglru_step(cfg, p["rglru"], h_in, conv, h)
        x = x + o
        extra = (conv, h)
    x = x + cm.mlp(cfg, p["mlp"], cm.apply_norm(cfg, p["ln2"], x))
    return x, extra


# ------------------------------------------------------------------ forward


def forward_seq(cfg: ModelConfig, params, x, positions, *, window=None,
                cache_capacity: Optional[int] = None, remat: bool = False):
    B, S, _ = x.shape
    x = cm.constrain_batch(cfg, x)
    pat, n_groups, tail = _pattern(cfg)
    W = cfg.sliding_window or cfg.hybrid.local_window
    cos, sin, rope_dim = cm.rope_for(cfg, positions)
    mask = cm.causal_mask(S, S, window=W)

    def body(x, gp):
        extras = []
        for i, kind in enumerate(pat):
            x, extra = _sub_seq(cfg, kind, gp[f"sub{i}_{kind}"], x, cos, sin,
                                rope_dim, mask)
            extras.append(extra)
        return cm.constrain_batch(cfg, x), tuple(extras)

    if remat:
        body = jax.checkpoint(body)
    x, extras = lax.scan(body, x, params["groups"], unroll=cfg.scan_unroll)

    tail_extras = None
    if tail:
        def tbody(x, tp):
            x, extra = _sub_seq(cfg, pat[0], tp[f"sub0_{pat[0]}"], x, cos, sin,
                                rope_dim, mask)
            return x, extra
        if remat:
            tbody = jax.checkpoint(tbody)
        x, tail_extras = lax.scan(tbody, x, params["tail"], unroll=cfg.scan_unroll)

    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)

    cache = None
    if cache_capacity is not None:
        C = min(cache_capacity, W)
        g = {}
        for i, kind in enumerate(pat):
            if kind == "attn":
                k, v = extras[i]                               # (G,B,S,Hk,D)
                if C >= S:
                    pad = [(0, 0), (0, 0), (0, C - S), (0, 0), (0, 0)]
                    k, v = jnp.pad(k, pad), jnp.pad(v, pad)
                    pos_map = jnp.where(jnp.arange(C)[None] < S,
                                        jnp.arange(C)[None], -1)
                else:
                    keep = jnp.arange(S - C, S)
                    slots = keep % C
                    k = jnp.zeros_like(k[:, :, :C]).at[:, :, slots].set(k[:, :, S - C:])
                    v = jnp.zeros_like(v[:, :, :C]).at[:, :, slots].set(v[:, :, S - C:])
                    pos_map = jnp.zeros((C,), jnp.int32).at[slots].set(keep)[None]
                g[f"k{i}"], g[f"v{i}"] = k, v
                cache_pos = jnp.broadcast_to(pos_map, (B, C)).astype(jnp.int32)
            else:
                conv, h_last = extras[i]
                g[f"conv{i}"], g[f"h{i}"] = conv, h_last
        cache = {"groups": g, "pos_map": cache_pos}
        if tail:
            conv, h_last = tail_extras
            cache["tail"] = {"conv0": conv, "h0": h_last}
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, x, pos, *, window=None):
    B = x.shape[0]
    x = cm.constrain_batch(cfg, x)
    pat, n_groups, tail = _pattern(cfg)
    W = cfg.sliding_window or cfg.hybrid.local_window
    attn_idx = [i for i, k in enumerate(pat) if k == "attn"]
    C = cache["groups"][f"k{attn_idx[0]}"].shape[2]
    slot = (pos % C).astype(jnp.int32)
    pos_map = cache["pos_map"].at[jnp.arange(B), slot].set(pos.astype(jnp.int32))
    mask = cm.decode_mask(pos_map, pos, window=W)
    cos, sin, rope_dim = cm.rope_for(cfg, pos[:, None])

    g = cache["groups"]

    def body(x, xs):
        gp = xs[0]
        states = xs[1]
        new_states = {}
        for i, kind in enumerate(pat):
            if kind == "attn":
                st = (states[f"k{i}"], states[f"v{i}"], slot)
            else:
                st = (states[f"conv{i}"], states[f"h{i}"])
            x, extra = _sub_step(cfg, kind, gp[f"sub{i}_{kind}"], x, cos, sin,
                                 rope_dim, mask, st)
            if kind == "attn":
                new_states[f"k{i}"], new_states[f"v{i}"] = extra
            else:
                new_states[f"conv{i}"], new_states[f"h{i}"] = extra
        return x, new_states

    x, new_g = lax.scan(body, x, (params["groups"], g), unroll=cfg.scan_unroll)

    new_cache = {"groups": new_g, "pos_map": pos_map}
    if tail:
        def tbody(x, xs):
            tp, st = xs
            x, extra = _sub_step(cfg, pat[0], tp[f"sub0_{pat[0]}"], x, cos, sin,
                                 rope_dim, mask, (st["conv0"], st["h0"]))
            return x, {"conv0": extra[0], "h0": extra[1]}
        x, new_tail = lax.scan(tbody, x, (params["tail"], cache["tail"]),
                                unroll=cfg.scan_unroll)
        new_cache["tail"] = new_tail

    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, capacity: int):
    """Empty decode cache for ``batch`` fresh streams: zero k/v rings with
    pos_map -1 (no valid slots) and zero recurrent (conv, h) state."""
    dtype = jnp.dtype(cfg.dtype)
    pat, n_groups, tail = _pattern(cfg)
    W = cfg.sliding_window or cfg.hybrid.local_window
    C = min(capacity, W)
    lw = _lru_width(cfg)
    cw = cfg.hybrid.conv_width
    hd = cfg.head_dim_
    g = {}
    for i, kind in enumerate(pat):
        if kind == "attn":
            g[f"k{i}"] = jnp.zeros((n_groups, batch, C, cfg.n_kv_heads, hd),
                                   dtype)
            g[f"v{i}"] = jnp.zeros((n_groups, batch, C, cfg.n_kv_heads, hd),
                                   dtype)
        else:
            g[f"conv{i}"] = jnp.zeros((n_groups, batch, cw - 1, lw), dtype)
            g[f"h{i}"] = jnp.zeros((n_groups, batch, lw), jnp.float32)
    cache = {"groups": g, "pos_map": jnp.full((batch, C), -1, jnp.int32)}
    if tail:
        cache["tail"] = {"conv0": jnp.zeros((tail, batch, cw - 1, lw), dtype),
                         "h0": jnp.zeros((tail, batch, lw), jnp.float32)}
    return cache


def prefill_chunk(cfg: ModelConfig, params, cache, x, offset, *, valid_len,
                  window=None):
    """One chunk of incremental prefill against a live decode cache.

    x (B,Sq,d): embedded chunk covering absolute positions
    offset..offset+Sq-1, of which the first ``valid_len`` are real tokens and
    the rest padding. Attention layers attend over the concatenation
    [ring cache | chunk] under a combined validity/causal/sliding-window mask,
    then commit the last min(valid_len, C) valid keys into the ring (write
    *after* attend, so in-chunk attention never sees overwritten slots).
    Recurrent layers run the valid-length-masked scan (``_rglru_chunk``), with
    the Pallas kernel on the path when ``attn_impl == "pallas"``. Returns
    (logits, new_cache) with the same pytree structure as ``forward_seq``'s
    cache; decoding can resume from it exactly as from a whole-sequence
    prefill.
    """
    del window
    B, Sq, _ = x.shape
    x = cm.constrain_batch(cfg, x)
    pat, n_groups, tail = _pattern(cfg)
    use_pallas = _use_pallas(cfg)
    W = cfg.sliding_window or cfg.hybrid.local_window
    attn_idx = [i for i, k in enumerate(pat) if k == "attn"]
    C = cache["groups"][f"k{attn_idx[0]}"].shape[2] if attn_idx else 0

    positions = offset + jnp.arange(Sq)
    cos, sin, rope_dim = cm.rope_for(cfg, positions)

    # Additive mask over concat([ring (C) | chunk (Sq)]) keys. Ring entries
    # hold absolute positions < offset, chunk keys sit at offset + j.
    idx = jnp.arange(Sq)
    ok = (idx[None, :] <= idx[:, None]) & (idx[None, :] < valid_len)
    ok = ok & (idx[None, :] > idx[:, None] - W)
    chunk_m = jnp.where(ok, 0.0, cm.NEG_INF)[None, None, None]
    chunk_m = jnp.broadcast_to(chunk_m, (B, 1, 1, Sq, Sq)).astype(jnp.float32)
    if attn_idx:
        ring_m = cm.chunk_mask(cache["pos_map"], positions, window=W)
        mask = jnp.concatenate([ring_m, chunk_m], axis=-1)
        # Ring commit plan, shared by every attention layer: slot c takes the
        # last valid chunk index congruent to it mod C (handles Sq > C wrap).
        cidx = jnp.arange(C, dtype=jnp.int32)
        r = (cidx - jnp.int32(offset)) % C
        has = r < valid_len
        last_rel = jnp.clip(r + C * ((valid_len - 1 - r) // C), 0, Sq - 1)
        pos_map = jnp.where(has[None, :],
                            (offset + last_rel)[None, :].astype(jnp.int32),
                            cache["pos_map"])
        has_kv = has[None, :, None, None]
    else:
        mask = chunk_m
        pos_map = cache["pos_map"]
        last_rel = has_kv = None

    def body(x, xs):
        gp, states = xs
        new_states = {}
        for i, kind in enumerate(pat):
            p = gp[f"sub{i}_{kind}"]
            h_in = cm.apply_norm(cfg, p["ln1"], x)
            if kind == "attn":
                q, k, v = cm.attention_qkv(cfg, p["attn"], h_in, cos, sin,
                                           rope_dim)
                keys = jnp.concatenate([states[f"k{i}"], k], axis=1)
                vals = jnp.concatenate([states[f"v{i}"], v], axis=1)
                o = cm.sdpa(q, keys, vals, mask, cfg.logit_softcap)
                x = x + o @ p["attn"]["wo"]
                new_states[f"k{i}"] = jnp.where(has_kv, k[:, last_rel],
                                                states[f"k{i}"])
                new_states[f"v{i}"] = jnp.where(has_kv, v[:, last_rel],
                                                states[f"v{i}"])
            else:
                o, conv, h = _rglru_chunk(cfg, p["rglru"], h_in,
                                          states[f"conv{i}"], states[f"h{i}"],
                                          valid_len, use_pallas)
                x = x + o
                new_states[f"conv{i}"], new_states[f"h{i}"] = conv, h
            x = x + cm.mlp(cfg, p["mlp"], cm.apply_norm(cfg, p["ln2"], x))
        return cm.constrain_batch(cfg, x), new_states

    x, new_g = lax.scan(body, x, (params["groups"], cache["groups"]),
                        unroll=cfg.scan_unroll)
    new_cache = {"groups": new_g, "pos_map": pos_map}
    if tail:
        # Tail stacks are homogeneous rglru (enforced in init_params; mirrors
        # the (conv0, h0)-only state decode_step threads through its tail).
        def tbody(x, xs):
            tp, st = xs
            p = tp[f"sub0_{pat[0]}"]
            h_in = cm.apply_norm(cfg, p["ln1"], x)
            o, conv, h = _rglru_chunk(cfg, p["rglru"], h_in, st["conv0"],
                                      st["h0"], valid_len, use_pallas)
            x = x + o
            x = x + cm.mlp(cfg, p["mlp"], cm.apply_norm(cfg, p["ln2"], x))
            return x, {"conv0": conv, "h0": h}
        x, new_tail = lax.scan(tbody, x, (params["tail"], cache["tail"]),
                               unroll=cfg.scan_unroll)
        new_cache["tail"] = new_tail

    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)
    return logits, new_cache


def embed_tokens(cfg: ModelConfig, params, tokens):
    return cm.embed(cfg, params["tok"], tokens)
