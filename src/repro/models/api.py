"""Unified model API over all families.

``build_model(cfg)`` returns a :class:`Model` with a uniform functional
interface used by the training step builders, the serving engine and the
dry-run launcher:

  params = model.init(key)
  loss   = model.loss(params, batch)                  # batch per family, below
  logits, cache = model.prefill(params, batch, cache_capacity)
  logits, cache = model.decode(params, cache, batch)  # one token per request

Batch formats (all positions int32):
  dense/moe/ssm/hybrid : train/prefill {"tokens": (B,S)}
                         decode        {"token": (B,1), "pos": (B,)}
  vlm                  : train/prefill {"embeds": (B,S,d), "positions": (B,3,S),
                                        "labels": (B,S)}
                         decode        {"token": (B,1), "positions": (B,3,1),
                                        "pos": (B,)}
  encdec (whisper)     : train/prefill {"audio_embeds": (B,F,d), "tokens": (B,S)}
                         decode        {"token": (B,1), "pos": (B,)}

``window`` semantics: models with cfg.sliding_window always mask locally; for
the long-context variant shapes, pass ``window=cfg.long_context_window`` (the
step builders do this for the ``long_500k`` shape).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import dense, encdec, hybrid, moe, ssm


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[Any], Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]


def _shift_loss(cfg, logits, tokens):
    from repro.models import common as cm
    return cm.lm_loss(cfg, logits[:, :-1], tokens[:, 1:])


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        mod = dense
    elif fam == "moe":
        mod = moe
    elif fam == "ssm":
        mod = ssm
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "encdec":
        mod = encdec
    else:
        raise ValueError(fam)

    # ----------------------------------------------------------- enc-dec
    if fam == "encdec":
        def loss(params, batch, *, window=None, remat=False):
            logits, _ = encdec.forward_seq(cfg, params, batch["tokens"],
                                           batch["audio_embeds"], remat=remat)
            return _shift_loss(cfg, logits, batch["tokens"])

        def prefill(params, batch, cache_capacity, *, window=None, remat=False):
            return encdec.forward_seq(cfg, params, batch["tokens"],
                                      batch["audio_embeds"],
                                      cache_capacity=cache_capacity, remat=remat)

        def decode(params, cache, batch, *, window=None):
            return encdec.decode_step(cfg, params, cache, batch["token"],
                                      batch["pos"])

        return Model(cfg, lambda k: encdec.init_params(cfg, k), loss, prefill, decode)

    # --------------------------------------------------------------- vlm
    if fam == "vlm":
        def loss(params, batch, *, window=None, remat=False):
            S = batch["embeds"].shape[1]
            logits, _ = dense.forward_seq(
                cfg, params, batch["embeds"], jnp.arange(S),
                mrope_positions=batch["positions"], window=window, remat=remat)
            from repro.models import common as cm
            return cm.lm_loss(cfg, logits[:, :-1], batch["labels"][:, 1:])

        def prefill(params, batch, cache_capacity, *, window=None, remat=False):
            S = batch["embeds"].shape[1]
            return dense.forward_seq(
                cfg, params, batch["embeds"], jnp.arange(S),
                mrope_positions=batch["positions"], window=window,
                cache_capacity=cache_capacity, remat=remat)

        def decode(params, cache, batch, *, window=None):
            x = dense.embed_tokens(cfg, params, batch["token"])
            return dense.decode_step(cfg, params, cache, x, batch["pos"],
                                     mrope_positions=batch["positions"],
                                     window=window)

        return Model(cfg, lambda k: dense.init_params(cfg, k), loss, prefill, decode)

    # ------------------------------------------------- dense / moe / ssm / hybrid
    def loss(params, batch, *, window=None, remat=False):
        tokens = batch["tokens"]
        x = mod.embed_tokens(cfg, params, tokens)
        out = mod.forward_seq(cfg, params, x, jnp.arange(tokens.shape[1]),
                              window=window, remat=remat)
        logits = out[0]
        base = _shift_loss(cfg, logits, tokens)
        if fam == "moe":
            base = base + 0.01 * out[2]
        return base

    def prefill(params, batch, cache_capacity, *, window=None, remat=False):
        tokens = batch["tokens"]
        x = mod.embed_tokens(cfg, params, tokens)
        out = mod.forward_seq(cfg, params, x, jnp.arange(tokens.shape[1]),
                              window=window, cache_capacity=cache_capacity,
                              remat=remat)
        return out[0], out[1]

    def decode(params, cache, batch, *, window=None):
        x = mod.embed_tokens(cfg, params, batch["token"])
        return mod.decode_step(cfg, params, cache, x, batch["pos"], window=window)

    return Model(cfg, lambda k: mod.init_params(cfg, k), loss, prefill, decode)
