"""Mixture-of-Experts decoder (dbrx, olmoe). Attention identical to dense; the
FFN is a top-k routed expert bank with **sort-based dispatch** (argsort by
expert id + capacity-clipped scatter), so compiled FLOPs count *active* experts
only — no one-hot dispatch einsum.

With experts sharded over the ``model`` mesh axis this is expert parallelism;
the dispatch scatter/gather lowers to all-to-all-style collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import dense


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    kg = cm.KeyGen(key)
    L = (cfg.n_layers,)
    m = cfg.moe
    E, ff, d = m.n_experts, m.d_ff_expert, cfg.d_model
    layers = {
        "ln1": cm.init_norm(cfg, L, d, dtype),
        "attn": cm.init_attention(cfg, kg, L, dtype),
        "ln2": cm.init_norm(cfg, L, d, dtype),
        "moe": {
            "router": cm.ninit(kg(), L + (d, E), dtype),
            "w_gate": cm.ninit(kg(), L + (E, d, ff), dtype),
            "w_up": cm.ninit(kg(), L + (E, d, ff), dtype),
            "w_down": cm.ninit(kg(), L + (E, ff, d), dtype),
        },
    }
    return {
        "tok": cm.init_embedding(cfg, kg, dtype),
        "layers": layers,
        "final_norm": cm.init_norm(cfg, (), d, dtype),
    }


def moe_ffn(cfg: ModelConfig, p, x):
    """x (B,S,d) -> (B,S,d), plus aux load-balance loss term (scalar).

    Sort-based dispatch, optionally grouped (cfg.moe_groups > 1): each group
    packs its own (E, C_g, d) buffer with group-local indices, so the only
    cross-device movement is the group<->expert reshard of the buffer
    (all-to-all under GSPMD). G=1 reproduces the global sort.
    """
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    N = B * S
    G = cfg.moe_groups if cfg.moe_groups and N % max(cfg.moe_groups, 1) == 0 \
        else 1
    Ng = N // G
    xg = x.reshape(G, Ng, d)

    logits = (xg @ p["router"]).astype(jnp.float32)            # (G, Ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)                         # (G, Ng, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- aux loss (Switch-style load balance) ----
    density = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, E), axis=2), axis=(0, 1))
    router_mean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density / K * router_mean)

    C = int(max(8, -(-Ng * K // E) * m.capacity_factor))       # per-group cap
    C = -(-int(C) // 8) * 8

    def dispatch(xf, flat_e):
        """Group-local sort-based pack. xf (Ng,d); flat_e (Ng*K,)."""
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        sorted_tok = (order // K).astype(jnp.int32)
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(Ng * K) - starts[sorted_e]
        keep = rank < C
        dest = jnp.where(keep, sorted_e * C + rank, E * C)     # E*C = drop row
        buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(xf[sorted_tok])
        return buf[: E * C].reshape(E, C, d), order, dest

    buf, order, dest = jax.vmap(dispatch)(xg, top_e.reshape(G, Ng * K))

    from jax.sharding import PartitionSpec as P
    ax = cfg.act_batch_axes
    bax = (ax if ax and len(ax) > 1 else (ax[0] if ax else None))
    if cfg.moe_ep_axis:
        # group-sharded -> expert-sharded reshard == all-to-all under GSPMD
        buf = jax.lax.with_sharding_constraint(
            buf, P(bax, cfg.moe_ep_axis, None, None))

    # ---- expert FFN (batched over experts; groups fold into capacity) ----
    h = jnp.swapaxes(buf, 0, 1).reshape(E, G * C, d)           # (E, G*C, d)
    if cfg.activation in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        g_ = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
        u_ = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
        h = jnp.einsum("ecf,efd->ecd", act(g_) * u_, p["w_down"])
    else:
        h = jnp.einsum("ecf,efd->ecd",
                       jax.nn.gelu(jnp.einsum("ecd,edf->ecf", h, p["w_gate"])),
                       p["w_down"])
    hbuf = jnp.swapaxes(h.reshape(E, G, C, d), 0, 1)           # (G, E, C, d)
    if cfg.moe_ep_axis:
        # expert-sharded -> group-sharded (all-to-all back)
        hbuf = jax.lax.with_sharding_constraint(
            hbuf, P(bax, None, None, None))

    def combine(hflat, order, dest):
        got = jnp.concatenate([hflat.reshape(E * C, d),
                               jnp.zeros((1, d), hflat.dtype)])[dest]
        y = jnp.zeros((Ng * K, d), hflat.dtype).at[order].set(got)
        return y

    y = jax.vmap(combine)(hbuf, order, dest)                   # (G, Ng*K, d)
    y = y.reshape(G, Ng, K, d)
    y = jnp.sum(y * top_p[..., None].astype(y.dtype), axis=2)
    return y.reshape(B, S, d), aux


def _block(cfg: ModelConfig, p, x, cos, sin, rope_dim, mask, kv_cache=None,
           slot=None):
    h = cm.apply_norm(cfg, p["ln1"], x)
    q, k, v = cm.attention_qkv(cfg, p["attn"], h, cos, sin, rope_dim)
    if kv_cache is None:
        q, k, v = cm.constrain_seq_attention(cfg, q, k, v)
        o = cm.sdpa(q, k, v, mask, cfg.logit_softcap)
        out_kv = (k, v)
    else:
        ck, cv = kv_cache
        bidx = jnp.arange(x.shape[0])
        ck = ck.at[bidx, slot].set(k[:, 0])
        cv = cv.at[bidx, slot].set(v[:, 0])
        o = cm.sdpa(q, ck, cv, mask, cfg.logit_softcap)
        out_kv = (ck, cv)
    x = x + o @ p["attn"]["wo"]
    h = cm.apply_norm(cfg, p["ln2"], x)
    y, aux = moe_ffn(cfg, p["moe"], h)
    return x + y, out_kv, aux


def forward_seq(cfg: ModelConfig, params, x, positions, *, window=None,
                cache_capacity: Optional[int] = None, remat: bool = False):
    B, S, _ = x.shape
    x = cm.constrain_batch(cfg, x)
    cos, sin, rope_dim = cm.rope_for(cfg, positions)
    mask = cm.causal_mask(S, S, window=window)

    def body(x, lp):
        x, kv, aux = _block(cfg, lp, x, cos, sin, rope_dim, mask)
        return cm.constrain_batch(cfg, x), (kv, aux)

    if remat:
        body = jax.checkpoint(body)
    x, ((ks, vs), auxs) = lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)

    cache = None
    if cache_capacity is not None:
        C = cache_capacity
        if C >= S:
            pad = [(0, 0), (0, 0), (0, C - S), (0, 0), (0, 0)]
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
            pos_map = jnp.where(jnp.arange(C)[None] < S, jnp.arange(C)[None], -1)
            pos_map = jnp.broadcast_to(pos_map, (B, C)).astype(jnp.int32)
        else:
            keep_pos = jnp.arange(S - C, S)
            slots = keep_pos % C
            ks_l, vs_l = ks[:, :, S - C:], vs[:, :, S - C:]
            ks = jnp.zeros_like(ks_l).at[:, :, slots].set(ks_l)
            vs = jnp.zeros_like(vs_l).at[:, :, slots].set(vs_l)
            pos_map = jnp.zeros((C,), jnp.int32).at[slots].set(keep_pos)
            pos_map = jnp.broadcast_to(pos_map[None], (B, C)).astype(jnp.int32)
        cache = {"k": ks, "v": vs, "pos_map": pos_map}
    return logits, cache, jnp.mean(auxs)


def decode_step(cfg: ModelConfig, params, cache, x, pos, *, window=None):
    B = x.shape[0]
    x = cm.constrain_batch(cfg, x)
    C = cache["k"].shape[2]
    slot = (pos % C).astype(jnp.int32)
    pos_map = cache["pos_map"].at[jnp.arange(B), slot].set(pos.astype(jnp.int32))
    mask = cm.decode_mask(pos_map, pos, window=window)
    cos, sin, rope_dim = cm.rope_for(cfg, pos[:, None])

    def body(x, xs):
        lp, ck, cv = xs
        x, (ck, cv), _aux = _block(cfg, lp, x, cos, sin, rope_dim, mask,
                                   kv_cache=(ck, cv), slot=slot)
        return x, (ck, cv)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                           unroll=cfg.scan_unroll)
    x = cm.apply_norm(cfg, params["final_norm"], x)
    logits = cm.unembed(cfg, params["tok"], x)
    return logits, {"k": ks, "v": vs, "pos_map": pos_map}


embed_tokens = dense.embed_tokens
