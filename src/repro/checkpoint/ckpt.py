"""Minimal msgpack checkpointing for pytrees of jnp arrays (params + opt
state). Flat path-keyed layout; restores onto host then (re)shards at load."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):                      # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save_checkpoint(path: str, tree: Any) -> None:
    flat = _flatten(tree)
    payload = {k: {"dtype": str(v.dtype), "shape": list(v.shape),
                   "data": v.tobytes()} for k, v in flat.items()}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)


def load_checkpoint(path: str, like: Any) -> Any:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), strict_map_key=False)

    def restore(key_prefix, node):
        if isinstance(node, dict):
            return {k: restore(f"{key_prefix}{k}/", v) for k, v in node.items()}
        if hasattr(node, "_fields"):
            vals = {k: restore(f"{key_prefix}{k}/", getattr(node, k))
                    for k in node._fields}
            return type(node)(**vals)
        if isinstance(node, (list, tuple)):
            return type(node)(restore(f"{key_prefix}{i}/", v)
                              for i, v in enumerate(node))
        rec = payload[key_prefix[:-1]]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        return jnp.asarray(arr)

    return restore("", like)
