"""Distributed step builders + dry-run input specs.

``make_step_and_specs(cfg, shape, mesh)`` returns (fn, in_specs, in_shardings)
ready for ``jax.jit(fn, in_shardings=...).lower(*in_specs).compile()`` — the
multi-pod dry-run contract. Shapes never allocate: everything is
ShapeDtypeStruct (params/caches via jax.eval_shape).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.optim import adamw_init, adamw_update


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _prep_cfg(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    if cfg.family == "encdec" and cfg.max_seq_len < shape.seq_len:
        cfg = cfg.replace(max_seq_len=shape.seq_len)  # stretch learned pos table
    return cfg


def window_for(cfg: ModelConfig, shape: InputShape) -> Optional[int]:
    if shape.name == "long_500k":
        if cfg.long_context_window is None and cfg.family in ("dense", "vlm", "moe"):
            raise ValueError(f"{cfg.arch_id} cannot run long_500k")
        return cfg.long_context_window
    return cfg.sliding_window


def supports(cfg: ModelConfig, shape: InputShape) -> bool:
    """DESIGN.md §4 skips: whisper has no sub-quadratic long-context variant."""
    if shape.name == "long_500k":
        if cfg.family == "encdec":
            return False
        if cfg.family in ("dense", "vlm", "moe") and cfg.long_context_window is None:
            return False
    return True


def cache_capacity(cfg: ModelConfig, shape: InputShape) -> int:
    w = window_for(cfg, shape)
    cap = shape.seq_len
    if w is not None:
        cap = min(cap, w)
    return cap


# ------------------------------------------------------------- batch specs


def batch_specs(cfg: ModelConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            return {"audio_embeds": _sds((B, cfg.encoder.n_frames, cfg.d_model), dt),
                    "tokens": _sds((B, S), "int32")}
        if cfg.family == "vlm":
            return {"embeds": _sds((B, S, cfg.d_model), dt),
                    "positions": _sds((B, 3, S), "int32"),
                    "labels": _sds((B, S), "int32")}
        return {"tokens": _sds((B, S), "int32")}
    # decode: one new token against a seq_len-deep cache
    b = {"token": _sds((B, 1), "int32"), "pos": _sds((B,), "int32")}
    if cfg.family == "vlm":
        b["positions"] = _sds((B, 3, 1), "int32")
    return b


def batch_shardings(specs, mesh: Mesh):
    out = {}
    for k, v in specs.items():
        bd = 0
        out[k] = NamedSharding(mesh, shd.batch_spec(v.shape, mesh, batch_dim=bd))
    return out


# --------------------------------------------------------------- steps


def make_train_step(cfg: ModelConfig, shape: InputShape):
    cfg = _prep_cfg(cfg, shape)
    model = build_model(cfg)
    w = cfg.sliding_window

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, window=w, remat=True))(params)
        params, opt_state = adamw_update(params, grads, opt_state)
        return params, opt_state, loss

    return model, train_step


def make_prefill_step(cfg: ModelConfig, shape: InputShape):
    cfg = _prep_cfg(cfg, shape)
    model = build_model(cfg)
    w = window_for(cfg, shape)
    cap = cache_capacity(cfg, shape)

    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, cache_capacity=cap,
                                      window=w)
        # serving returns last-position logits only (sampler input)
        return logits[:, -1], cache

    return model, prefill_step


def make_decode_step(cfg: ModelConfig, shape: InputShape):
    cfg = _prep_cfg(cfg, shape)
    model = build_model(cfg)
    w = window_for(cfg, shape)

    def decode_step(params, cache, batch):
        return model.decode(params, cache, batch, window=w)

    return model, decode_step


# ------------------------------------------------------- dry-run assembly


def build_dryrun(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 opts: frozenset = frozenset()):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs).

    opts — §Perf hillclimb variants:
      "act_shard"     pin activation batch dims to the mesh (models call
                      with_sharding_constraint; fixes GSPMD de-sharding after
                      the vocab-sharded embedding gather)
      "kv_seq_shard"  shard decode KV caches over 'model' on the sequence dim
                      when kv-heads don't divide (flash-decoding style)
    """
    cfg = _prep_cfg(cfg, shape)
    if not supports(cfg, shape):
        raise ValueError(f"{cfg.arch_id} x {shape.name} skipped (DESIGN.md §4)")
    if "act_shard" in opts:
        axes = shd.act_batch_axes_for(mesh, shape.global_batch)
        if axes:
            cfg = cfg.replace(act_batch_axes=axes)
    if "seq_attn" in opts and shape.seq_len % shd.model_size(mesh) == 0:
        cfg = cfg.replace(attn_seq_axis="model")
    if "moe_ep" in opts and cfg.moe is not None and \
            cfg.moe.n_experts % shd.model_size(mesh) == 0:
        groups = 1
        ax = shd.act_batch_axes_for(mesh, shape.global_batch)
        if ax:
            groups = 1
            for a in ax:
                groups *= mesh.shape[a]
        cfg = cfg.replace(moe_ep_axis="model", moe_groups=groups)
    seq_shard = "kv_seq_shard" in opts
    bspecs = batch_specs(cfg, shape)
    bshard = batch_shardings(bspecs, mesh)

    if shape.kind == "train":
        model, step = make_train_step(cfg, shape)
        pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pshard = shd.param_shardings(pshape, mesh)
        oshape = jax.eval_shape(adamw_init, pshape)
        oshard = shd.param_shardings(oshape, mesh)
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     donate_argnums=(0, 1))
        return fn, (pshape, oshape, bspecs)

    if shape.kind == "prefill":
        model, step = make_prefill_step(cfg, shape)
        pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pshard = shd.param_shardings(pshape, mesh)
        fn = jax.jit(step, in_shardings=(pshard, bshard))
        return fn, (pshape, bspecs)

    # decode: build the cache spec via eval_shape of prefill at full depth
    model, step = make_decode_step(cfg, shape)
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = shd.param_shardings(pshape, mesh)
    cap = cache_capacity(cfg, shape)
    w = window_for(cfg, shape)
    pf_specs = prefill_like_specs_for_decode(cfg, shape)
    cshape = jax.eval_shape(
        lambda p, b: model.prefill(p, b, cache_capacity=cap, window=w)[1],
        pshape, pf_specs)
    cshard = shd.cache_shardings(cshape, mesh, seq_shard=seq_shard)
    fn = jax.jit(step, in_shardings=(pshard, cshard,
                                     batch_shardings(batch_specs(cfg, shape), mesh)),
                 donate_argnums=(1,))
    return fn, (pshape, cshape, batch_specs(cfg, shape))


def prefill_like_specs_for_decode(cfg: ModelConfig, shape: InputShape):
    """A small prefill batch spec used only to eval_shape the cache pytree
    (cache capacity is what matters, not the prefill length)."""
    B = shape.global_batch
    dt = cfg.dtype
    S = min(shape.seq_len, cache_capacity(cfg, shape))
    if cfg.family == "ssm":
        S = max(cfg.ssm.chunk, S - S % cfg.ssm.chunk)
    if cfg.family == "encdec":
        return {"audio_embeds": _sds((B, cfg.encoder.n_frames, cfg.d_model), dt),
                "tokens": _sds((B, S), "int32")}
    if cfg.family == "vlm":
        return {"embeds": _sds((B, S, cfg.d_model), dt),
                "positions": _sds((B, 3, S), "int32"),
                "labels": _sds((B, S), "int32")}
    return {"tokens": _sds((B, S), "int32")}
