"""Sharding rules: params Megatron-style over the ``model`` axis, activations
batch-sharded over (``pod``,) ``data``. Rules are name+shape based and only
shard a dimension when it divides the axis size (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

BATCH_AXES = ("pod", "data")      # pod present only on the multi-pod mesh


def batch_axes(mesh: Mesh):
    ax = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


def model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def batch_size_divides(mesh: Mesh, b: int) -> bool:
    n = 1
    for a in BATCH_AXES:
        n *= mesh.shape.get(a, 1)
    return b % n == 0


def _maybe(axis: str, dim: int, size: int) -> Optional[str]:
    return axis if dim % size == 0 and size > 1 else None


def param_spec(path: str, shape, msize: int) -> P:
    """path: '/'-joined key path, e.g. 'layers/attn/wq'."""
    leaf = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""
    nd = len(shape)

    def tail(spec_tail):
        """Pad with leading Nones (stacked layer/group dims)."""
        return P(*([None] * (nd - len(spec_tail)) + list(spec_tail)))

    if leaf in ("embed",):                       # (V, d): shard vocab
        return P(_maybe("model", shape[0], msize), None)
    if leaf in ("unembed",):                     # (d, V)
        return P(None, _maybe("model", shape[1], msize))
    if leaf in ("pos",):
        return P(None, None)
    if leaf in ("wq", "wk", "wv"):               # (..., d, H*hd): shard out
        return tail([None, _maybe("model", shape[-1], msize)])
    if leaf == "wo":                             # (..., H*hd, d): shard in
        return tail([_maybe("model", shape[-2], msize), None])
    if leaf in ("w_gate", "w_up", "w_in"):
        if parent == "moe":                      # (L, E, d, ff): expert-parallel
            return tail([_maybe("model", shape[-3], msize), None, None])
        return tail([None, _maybe("model", shape[-1], msize)])
    if leaf in ("w_down", "w_out"):
        if parent == "moe":                      # (L, E, ff, d)
            return tail([_maybe("model", shape[-3], msize), None, None])
        if parent == "":
            pass
        return tail([_maybe("model", shape[-2], msize), None])
    if leaf == "router":                         # (L, d, E): replicate
        return tail([None, None])
    if leaf == "w_in" or leaf == "conv_w" or leaf == "conv_b":
        return tail([None])
    # ssm in-proj (L, d, X) handled by w_in above; ssm out-proj by w_out
    if leaf in ("w_y", "w_x"):                   # (..., d, lw)
        return tail([None, _maybe("model", shape[-1], msize)])
    if leaf in ("w_a", "w_i"):                   # (..., lw, lw): shard out
        return tail([None, _maybe("model", shape[-1], msize)])
    if leaf == "w_o":                            # (..., lw, d): shard in
        return tail([_maybe("model", shape[-2], msize), None])
    return P(*([None] * nd))                     # norms, biases, A_log, ...


def param_shardings(params_shape, mesh: Mesh):
    msize = model_size(mesh)

    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
        if hasattr(node, "_fields"):
            return type(node)(**{k: walk(getattr(node, k), f"{prefix}{k}/")
                                 for k in node._fields})
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, f"{prefix}{i}/")
                              for i, v in enumerate(node))
        return NamedSharding(mesh, param_spec(prefix[:-1], node.shape, msize))

    return walk(params_shape)


# --------------------------------------------------------------- activations


def batch_spec(shape, mesh: Mesh, *, batch_dim: int = 0) -> P:
    """Shard the batch dimension over (pod, data) when it divides."""
    nd = len(shape)
    ax = batch_axes(mesh)
    if ax is None or not batch_size_divides(mesh, shape[batch_dim]):
        return P(*([None] * nd))
    spec = [None] * nd
    spec[batch_dim] = ax
    return P(*spec)


def cache_spec(path: str, shape, mesh: Mesh, *, seq_shard: bool = False) -> P:
    """KV/state cache leaves. k/v (L,B,C,Hk,D): batch over data, kv-heads over
    model when divisible; states (L,B,...) batch over data + widest trailing
    dim over model when divisible.

    seq_shard (§Perf hillclimb B): when the kv-head count does not divide the
    model axis, shard the cache *sequence* dim over 'model' instead —
    flash-decoding-style distributed attention (GSPMD inserts the partial-
    softmax reductions). Cuts per-device KV residency by model_size."""
    leaf = path.split("/")[-1]
    msize = model_size(mesh)
    ax = batch_axes(mesh)
    nd = len(shape)
    bdim = 1 if nd >= 2 else 0
    spec = [None] * nd
    if leaf.startswith("pos_map"):
        if batch_size_divides(mesh, shape[0]):
            spec[0] = ax
        return P(*spec)
    if ax is not None and batch_size_divides(mesh, shape[bdim]):
        spec[bdim] = ax
    if leaf.startswith(("k", "v", "ck", "cv")) and nd == 5:
        spec[3] = _maybe("model", shape[3], msize)
        if spec[3] is None and seq_shard:
            spec[2] = _maybe("model", shape[2], msize)
    elif leaf.startswith("ssm") and nd == 5:     # (L,B,H,P,N)
        spec[2] = _maybe("model", shape[2], msize)
    elif leaf.startswith("conv") and nd == 4:    # (L,B,W-1,Ch)
        spec[3] = _maybe("model", shape[3], msize)
    elif leaf.startswith("h") and nd >= 3:       # (G,B,lw)
        spec[-1] = _maybe("model", shape[-1], msize)
    return P(*spec)


def cache_shardings(cache_shape, mesh: Mesh, *, seq_shard: bool = False):
    def walk(node, prefix=""):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in node.items()}
        return NamedSharding(mesh, cache_spec(prefix[:-1], node.shape, mesh,
                                              seq_shard=seq_shard))
    return walk(cache_shape)


def act_batch_axes_for(mesh: Mesh, global_batch: int):
    """Mesh axes to pin activation batch dims to (None when B doesn't divide)."""
    ax = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    if not ax or global_batch % n != 0:
        return None
    return ax
