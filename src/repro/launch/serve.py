"""Serving launcher — one ServingSystem front-end over both backends: the
real-compute Arrow cluster on CPU with a reduced model, or the cluster-scale
simulator for full configs. Requests, traces and reporting share one path
(DESIGN.md §1), so sim-vs-engine runs are directly comparable.

  PYTHONPATH=src python -m repro.launch.serve --mode engine --requests 16
  PYTHONPATH=src python -m repro.launch.serve --mode engine --trace azure_code \
      --rate 2 --duration 10 --policy colocated
  PYTHONPATH=src python -m repro.launch.serve --mode sim --arch gemma-2b \
      --trace azure_code --rate 8
  PYTHONPATH=src python -m repro.launch.serve --mode sim --trace spike \
      --policy arrow_elastic --instances 4 --min-instances 2 --max-instances 12

``--list-traces`` / ``--list-policies`` print the available presets/policies
and exit (docs/OPERATOR.md).
"""
from __future__ import annotations

import argparse
from typing import List, Optional

import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.autoscaler import AutoScalerConfig
from repro.core.faults import FaultPlan
from repro.core.policies import POLICIES
from repro.core.request import Request, SamplingParams
from repro.core.serving import ServeReport, ServingSystem, replay_trace
from repro.core.slo import SLO


def synth_requests(n: int, gap: float, vocab: int, seed: int = 0
                   ) -> List[Request]:
    rng = np.random.default_rng(seed)
    return [Request(rid=i, arrival=float(i) * gap,
                    input_len=int(rng.integers(8, 64)),
                    output_len=int(rng.integers(2, 16)))
            for i in range(n)]


def sampling_params(args) -> Optional[SamplingParams]:
    """Build the per-request SamplingParams from the CLI (DESIGN.md §12);
    None (the default temperature 0) keeps exact greedy argmax."""
    if args.temperature <= 0.0:
        return None
    return SamplingParams(temperature=args.temperature, top_p=args.top_p,
                          seed=None)


def apply_sampling(trace: List[Request], args) -> List[Request]:
    sp = sampling_params(args)
    if sp is not None:
        for r in trace:
            r.sampling = sp
    return trace


def run_and_report(system: ServingSystem, trace: List[Request], *,
                   tier: str, label: str,
                   timeout: Optional[float] = None) -> ServeReport:
    replay_trace(system, trace, tier=tier)
    report = system.drain(timeout=timeout)
    print(f"[{label}] {report.summary()}")
    by_tier = report.attainment_by_tier()
    if len(by_tier) > 1:
        print(f"[{label}] attainment by tier: " +
              " ".join("{}={}".format(k, "n/a" if v is None else f"{v:.2f}")
                       for k, v in by_tier.items()))
    if report.per_tenant:
        print(f"[{label}] per-tenant:")
        print(report.tenant_summary())
    return report


def list_traces() -> None:
    from repro.traces import TRACE_PRESETS
    print(f"{'name':<12} {'dur':>5} {'rate':>6} {'in_med':>7} {'out_med':>8} "
          f"{'corr':>5} {'slo_ttft':>9} {'slo_tpot':>9}  arrivals")
    for p in TRACE_PRESETS.values():
        shape = {"mmpp": f"MMPP x{p.burst_rate_mult:g} "
                         f"{p.burst_frac:.0%} of time",
                 "spike": f"spike x{p.shape_mult:g} over "
                          f"[{p.spike_window[0]:.0%},{p.spike_window[1]:.0%})",
                 "diurnal": f"diurnal x{p.shape_mult:g} peak",
                 "sessions": f"sessions ~{p.turns_mean:g} turns, "
                             f"think {p.think_mean:g}s",
                 "tenants": f"{p.n_tenants}+flood x{p.shape_mult:g} over "
                            f"[{p.spike_window[0]:.0%},"
                            f"{p.spike_window[1]:.0%})"}[p.rate_shape]
        print(f"{p.name:<12} {p.duration:>5.0f} {p.base_rate:>5.1f}/s "
              f"{p.in_median:>7.0f} {p.out_median:>8.0f} {p.in_out_corr:>5.2f} "
              f"{p.slo_ttft:>8.2f}s {p.slo_tpot:>8.3f}s  {shape}")
    print("\n(see repro/traces/synth.py for provenance; --rate divides "
          "inter-arrival times, §7.1)")


def list_policies() -> None:
    print(f"{'name':<16} {'adaptive':>8} {'elastic':>8}  summary")
    for name, cls in POLICIES.items():
        doc = (cls.__doc__ or "").strip().splitlines()[0]
        print(f"{name:<16} {str(cls.adaptive):>8} "
              f"{str(getattr(cls, 'elastic', False)):>8}  {doc}")
    print("\n(arrow_proactive = arrow + SchedulerConfig.proactive burst "
          "detection)")


def run_engine(args) -> ServeReport:
    from repro.engine import ArrowEngineCluster
    cfg = get_smoke_config(args.arch).replace(attn_impl=args.attn_impl)
    if cfg.family not in ("dense", "ssm", "hybrid"):
        raise SystemExit("--mode engine supports dense/ssm/hybrid archs; use "
                         "--mode sim for the rest (DESIGN.md §2, §13)")
    cluster = ArrowEngineCluster(cfg, n_instances=args.instances,
                                 n_prefill=max(args.instances // 2, 1),
                                 n_slots=8, capacity=256,
                                 slo=SLO(args.ttft, args.tpot),
                                 policy=args.policy, seed=args.seed,
                                 speculate=args.speculate,
                                 autoscaler_cfg=autoscaler_cfg(args),
                                 prefix_cache=args.prefix_cache == "on",
                                 fault_plan=fault_plan(args),
                                 tenants=tenant_registry(args),
                                 admission=args.admission == "on",
                                 deflection=deflection_cfg(args),
                                 health=health_cfg(args))
    if args.trace:
        from repro.traces import load_trace
        trace = load_trace(args.trace, rate_scale=args.rate, seed=0,
                           duration=args.duration)
    else:
        trace = synth_requests(args.requests, args.gap, cfg.vocab_size)
    trace = apply_sampling(trace, args)
    return run_and_report(cluster, trace, tier=args.tier,
                          timeout=args.timeout,
                          label=f"serve-engine {args.policy}")


def run_sim(args) -> ServeReport:
    from repro.sim import Simulator
    from repro.traces import TRACE_PRESETS, load_trace
    cfg = get_config(args.arch)
    trace_name = args.trace or "azure_code"
    p = TRACE_PRESETS[trace_name]
    trace = load_trace(trace_name, rate_scale=args.rate, seed=0,
                       duration=args.duration)
    sim = Simulator(cfg, n_instances=args.instances,
                    n_prefill=max(args.instances // 2, 1),
                    policy=args.policy, slo=SLO(p.slo_ttft, p.slo_tpot),
                    seed=args.seed, speculate=args.speculate,
                    autoscaler_cfg=autoscaler_cfg(args),
                    prefix_cache=args.prefix_cache == "on",
                    fault_plan=fault_plan(args),
                    tenants=tenant_registry(args),
                    admission=args.admission == "on",
                    deflection=deflection_cfg(args),
                    health=health_cfg(args))
    trace = apply_sampling(trace, args)
    # no timeout: --timeout is wall-clock; the sim's drain limit is virtual
    # time and must cover the whole trace
    return run_and_report(sim, trace, tier=args.tier,
                          label=f"serve-sim {args.arch} {trace_name} "
                                f"x{args.rate} {args.policy}")


def fault_plan(args) -> Optional[FaultPlan]:
    """Parse ``--fault-plan`` (DESIGN.md §8); None = no injection."""
    if args.fault_plan is None:
        return None
    return FaultPlan.parse(args.fault_plan)


def tenant_registry(args):
    """Build the ``--tenants`` roster (DESIGN.md §10); None = the implicit
    single tenant. ``--admission on`` without ``--tenants`` still arms the
    controller (every request lands on the auto-registered 'anonymous'
    tenant)."""
    if args.tenants is None:
        return None
    from repro.core.tenants import default_registry
    return default_registry(args.tenants)


def deflection_cfg(args):
    """Build the ``--deflection`` config (DESIGN.md §11); None keeps the
    policy's defaults (``arrow_deflect`` arms DeflectionConfig() on its own;
    non-deflective policies reject an explicit config)."""
    if args.deflection != "on" and args.deflect_ratio is None:
        return None
    from repro.core.global_scheduler import DeflectionConfig
    base = DeflectionConfig()
    return DeflectionConfig(**{
        **base.__dict__,
        "ratio": base.ratio if args.deflect_ratio is None
        else args.deflect_ratio,
    })


def health_cfg(args):
    """Build the self-healing layer's config (DESIGN.md §14); None/False
    keeps the layer off — byte-identical to pre-health builds. ``--preemption
    on`` implies ``--health on`` (preemption rides the health config)."""
    if args.health != "on" and args.preemption != "on":
        return False
    from repro.core.health import HealthConfig
    base = HealthConfig()
    return HealthConfig(**{
        **base.__dict__,
        "straggler_factor": base.straggler_factor
        if args.quarantine_factor is None else args.quarantine_factor,
        "sustain_s": base.sustain_s
        if args.quarantine_sustain is None else args.quarantine_sustain,
        "preemption": args.preemption == "on",
    })


def autoscaler_cfg(args) -> Optional[AutoScalerConfig]:
    """AutoScaler bounds from the CLI; None keeps the policy's defaults
    (non-elastic policies reject an explicit config)."""
    if args.min_instances is None and args.max_instances is None:
        return None
    base = AutoScalerConfig()
    return AutoScalerConfig(**{
        **base.__dict__,
        "min_instances": base.min_instances if args.min_instances is None
        else args.min_instances,
        "max_instances": base.max_instances if args.max_instances is None
        else args.max_instances,
    })


def build_parser() -> argparse.ArgumentParser:
    """The launcher's full CLI surface. Kept as a named function so
    ``tools/check_docs.py`` can diff the argparse flags against the
    operator guide's flag table (drift fails the docs CI job)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("engine", "sim"), default="engine")
    ap.add_argument("--arch", "--model-arch", choices=ARCH_IDS,
                    default="qwen3-1.7b",
                    help="architecture preset (--model-arch is an alias). "
                         "Engine mode serves dense, ssm (mamba2-370m) and "
                         "hybrid (recurrentgemma-9b) families on their "
                         "per-architecture decode state (DESIGN.md §13); "
                         "sim mode models any preset")
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--gap", type=float, default=0.05)
    ap.add_argument("--ttft", type=float, default=5.0)
    ap.add_argument("--tpot", type=float, default=2.0)
    ap.add_argument("--timeout", type=float, default=300.0)
    ap.add_argument("--trace", default=None,
                    help="replay a repro.traces preset (both modes); "
                         "engine default is synthetic requests")
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--policy", default="arrow", choices=sorted(POLICIES))
    ap.add_argument("--tier", default="standard",
                    choices=("interactive", "standard", "batch"))
    ap.add_argument("--min-instances", type=int, default=None,
                    help="AutoScaler floor (elastic policies only)")
    ap.add_argument("--max-instances", type=int, default=None,
                    help="AutoScaler ceiling (elastic policies only)")
    ap.add_argument("--fault-plan", default=None,
                    help="inject faults (DESIGN.md §8): ';'-separated "
                         "events, e.g. 'crash@20;crash@45:target=3;"
                         "slow@60:factor=4,duration=5'. Crashed instances "
                         "lose their KV; the runtime recovers the lost "
                         "requests (and an elastic policy replaces the "
                         "instance)")
    ap.add_argument("--attn-impl", choices=("reference", "pallas"),
                    default="reference",
                    help="engine-mode attention implementation (DESIGN.md "
                         "§9): 'reference' = pure-jnp sdpa; 'pallas' = the "
                         "flash_prefill/paged_attention kernels (interpret "
                         "mode on CPU — validates the kernel contract, not "
                         "CPU speed). Greedy streams are identical either "
                         "way; sim mode ignores this flag")
    ap.add_argument("--prefix-cache", choices=("on", "off"), default="off",
                    help="prefix-aware KV reuse (DESIGN.md §7): retain "
                         "finished contexts and prefill only the uncached "
                         "suffix of multi-turn / repeated prompts")
    ap.add_argument("--tenants", type=int, default=None,
                    help="multi-tenant serving (DESIGN.md §10): register N "
                         "well-behaved tenants t0..t{N-1} (tiers cycling "
                         "interactive/standard/batch) plus the adversarial "
                         "'flood' tenant the 'tenants' trace preset drives; "
                         "requests carry tenant ids from the trace")
    ap.add_argument("--admission", choices=("on", "off"), default="off",
                    help="credit-based admission control (DESIGN.md §10): "
                         "watermark guard over cluster pressure — admit "
                         "all below the low watermark, credit-gate with "
                         "deadline-aware retries between watermarks, shed "
                         "above the high watermark")
    ap.add_argument("--deflection", choices=("on", "off"), default="off",
                    help="cross-pool prefill deflection (DESIGN.md §11), "
                         "requires --policy arrow_deflect: above the Eq.(1) "
                         "pressure watermark, decode instances absorb "
                         "bounded prefill chunks in-step (and idle prefill "
                         "instances pick up decode slack), refused whenever "
                         "the predictors say it would break the victim "
                         "pool's SLO budget")
    ap.add_argument("--deflect-ratio", type=float, default=None,
                    help="§11 micro-batch knob: max deflected prefill "
                         "tokens per fused step as a fraction of the "
                         "victim's mixed-chunk budget (default 0.25; 0 "
                         "disables deflection — byte-identical to "
                         "arrow_elastic). Implies --deflection on")
    ap.add_argument("--health", choices=("on", "off"), default="off",
                    help="self-healing layer (DESIGN.md §14): straggler "
                         "detection against the fleet-median TPOT, "
                         "quarantine (DEGRADED — never schedulable, decode "
                         "residents drained), probation back to ACTIVE when "
                         "the signal clears, escalation to a crash after "
                         "the quarantine deadline; also arms the transfer "
                         "retry ladder (checksummed migrations, bounded "
                         "exponential backoff). Off = byte-identical to "
                         "pre-health builds")
    ap.add_argument("--quarantine-factor", type=float, default=None,
                    help="§14 straggler threshold: quarantine when an "
                         "instance's recent token interval sustains above "
                         "this multiple of the fleet median (default 3.0; "
                         "hysteresis clears at 1.5x)")
    ap.add_argument("--quarantine-sustain", type=float, default=None,
                    help="§14 sustain window: seconds the straggler signal "
                         "must persist before quarantine (default 2.0; "
                         "transients shorter than this never quarantine)")
    ap.add_argument("--preemption", choices=("on", "off"), default="off",
                    help="SLO-aware preemption (DESIGN.md §14): when the "
                         "§5.4 memory gate refuses a migration and eviction "
                         "cannot free enough KV, preempt the lowest-value "
                         "decode resident (by tenant credits, then tier, "
                         "then remaining length) and re-dispatch it through "
                         "crash recovery — streams stay bit-identical. "
                         "Rate-limited per instance; implies --health on")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (DESIGN.md §12); 0 = exact "
                         "greedy argmax (the default). Sampled streams are "
                         "replayable: same trace + --seed => bit-identical "
                         "tokens, across runs, step modes, migration and "
                         "crash recovery")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (only with "
                         "--temperature > 0): sample from the smallest "
                         "prefix of the sorted distribution holding at "
                         "least this probability")
    ap.add_argument("--seed", type=int, default=0,
                    help="run seed recorded in the report; per-request "
                         "sampling keys derive statelessly from (seed, rid, "
                         "position), so replaying a trace with the same "
                         "seed reproduces every sampled stream bit-for-bit")
    ap.add_argument("--speculate", type=int, default=0,
                    help="self-speculative decoding (DESIGN.md §12): draft "
                         "k tokens per round with the truncated-layer "
                         "model, verify in one full pass, emit the longest "
                         "agreeing prefix + 1 — streams stay bit-identical "
                         "to non-speculative decoding; 0 disables. Engine "
                         "mode runs it in the fused step; sim mode models "
                         "the round cost and acceptance analytically")
    ap.add_argument("--list-traces", action="store_true",
                    help="print the trace-preset table and exit")
    ap.add_argument("--list-policies", action="store_true",
                    help="print the policy registry and exit")
    return ap


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    if args.list_traces:
        return list_traces()
    if args.list_policies:
        return list_policies()
    if args.mode == "engine":
        run_engine(args)
    else:
        if args.trace is None:
            args.trace = "azure_code"
        run_sim(args)


if __name__ == "__main__":
    main()
