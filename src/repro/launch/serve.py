"""Serving launcher — the real-compute Arrow cluster on CPU with a reduced
model, or the cluster-scale simulator for full configs.

  PYTHONPATH=src python -m repro.launch.serve --mode engine --requests 16
  PYTHONPATH=src python -m repro.launch.serve --mode sim --arch gemma-2b \
      --trace azure_code --rate 8
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.slo import SLO


def run_engine(args) -> None:
    from repro.engine import ArrowEngineCluster, ServeRequest
    cfg = get_smoke_config(args.arch)
    if cfg.family != "dense":
        raise SystemExit("--mode engine supports dense-family archs; use "
                         "--mode sim for the rest (DESIGN.md §2)")
    cluster = ArrowEngineCluster(cfg, n_instances=args.instances,
                                 n_prefill=max(args.instances // 2, 1),
                                 n_slots=8, capacity=256,
                                 slo=SLO(args.ttft, args.tpot))
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(
        rid=i,
        prompt=rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(8, 64))).astype(np.int32),
        max_new_tokens=int(rng.integers(2, 16)),
        arrival_offset=float(i) * args.gap)
        for i in range(args.requests)]
    out = cluster.serve(reqs, timeout=args.timeout)
    done = [r for r in out if r.req and r.req.finish_time is not None]
    ttfts = sorted(r.req.ttft for r in done)
    tpots = sorted(r.req.tpot for r in done)
    ok = sum(1 for r in done if r.req.meets_slo(SLO(args.ttft, args.tpot)))
    print(f"[serve] finished {len(done)}/{len(out)} "
          f"p50_ttft={ttfts[len(ttfts)//2]*1e3:.1f}ms "
          f"p90_tpot={tpots[int(len(tpots)*0.9)]*1e3:.1f}ms "
          f"slo_attainment={ok/max(len(done),1):.2f} "
          f"pool_flips={cluster.pools.flips}")


def run_sim(args) -> None:
    from repro.sim import Simulator
    from repro.traces import TRACE_PRESETS, load_trace
    cfg = get_config(args.arch)
    p = TRACE_PRESETS[args.trace]
    trace = load_trace(args.trace, rate_scale=args.rate, seed=0,
                       duration=args.duration)
    sim = Simulator(cfg, n_instances=args.instances,
                    n_prefill=max(args.instances // 2, 1),
                    policy=args.policy, slo=SLO(p.slo_ttft, p.slo_tpot))
    res = sim.run(trace)
    print(f"[serve-sim] {args.arch} {args.trace} x{args.rate} "
          f"policy={args.policy}: n={len(trace)} "
          f"attainment={res.attainment:.3f} p90_ttft={res.p90('ttft'):.3f}s "
          f"p90_tpot={res.p90('tpot')*1e3:.1f}ms flips={res.flips}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("engine", "sim"), default="engine")
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--gap", type=float, default=0.05)
    ap.add_argument("--ttft", type=float, default=5.0)
    ap.add_argument("--tpot", type=float, default=2.0)
    ap.add_argument("--timeout", type=float, default=300.0)
    ap.add_argument("--trace", default="azure_code")
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--policy", default="arrow")
    args = ap.parse_args(argv)
    if args.mode == "engine":
        run_engine(args)
    else:
        run_sim(args)


if __name__ == "__main__":
    main()
