"""Training launcher: real steps on the local device mesh (CPU-friendly with
reduced configs; the full configs are exercised via dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ck.msgpack
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data import SyntheticTokenPipeline
from repro.models import build_model
from repro.optim import adamw_init, adamw_update


def make_batch_for(cfg, tokens):
    """LM batch -> family batch (stub embeddings for vlm/whisper)."""
    B, S = tokens.shape
    if cfg.family == "vlm":
        key = jax.random.PRNGKey(0)
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.dtype(cfg.dtype)) * 0.02,
            "positions": jnp.broadcast_to(jnp.arange(S)[None, None], (B, 3, S)),
            "labels": jnp.asarray(tokens),
        }
    if cfg.family == "encdec":
        key = jax.random.PRNGKey(0)
        F = cfg.encoder.n_frames
        return {
            "audio_embeds": jax.random.normal(key, (B, F, cfg.d_model),
                                              jnp.dtype(cfg.dtype)) * 0.02,
            "tokens": jnp.asarray(tokens),
        }
    return {"tokens": jnp.asarray(tokens)}


def main(argv=None, cfg_override=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = cfg_override or (get_smoke_config(args.arch) if args.smoke
                           else get_config(args.arch))
    if cfg.family == "ssm":
        args.seq = max(args.seq - args.seq % cfg.ssm.chunk, cfg.ssm.chunk)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.arch_id} ({'smoke' if args.smoke else 'full'}) "
          f"params={n_params/1e6:.1f}M seq={args.seq} batch={args.batch}")

    pipe = iter(SyntheticTokenPipeline(cfg.vocab_size, args.seq, args.batch,
                                       seed=1))

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch))(params)
        params, opt = adamw_update(params, grads, opt, lr=args.lr)
        return params, opt, loss

    losses = []
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = make_batch_for(cfg, next(pipe)["tokens"])
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.perf_counter() - t0
            tok_s = (i + 1) * args.batch * args.seq / dt
            print(f"  step {i:4d} loss={losses[-1]:.4f} ({tok_s:.0f} tok/s)")
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    if args.ckpt:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt, {"params": params})
        print(f"[train] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
