import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on the
production meshes, record memory/cost analysis and the collective-bytes sum
for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]

Results append to benchmarks/results/dryrun.json (incremental; safe to rerun).
"""
import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.distributed.steps import build_dryrun, supports
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"

_COLL_RE = re.compile(
    r"=\s*(.*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in the post-SPMD,
    post-optimization HLO (``compiled.as_text()``), bucketed by op kind.
    Bytes are per-device (the module is the per-device program); '-done' ops
    are skipped so async pairs count once."""
    out = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
    return out


def _cost_dict(compiled) -> dict:
    """cost_analysis() returns a dict on current jax but a one-element list
    of dicts on older jaxlib (e.g. 0.4.36) — normalise to a dict."""
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return c


def _measure(cfg, shape, mesh, opts: frozenset = frozenset()) -> dict:
    """lower+compile one config; return per-device cost terms."""
    fn, args = build_dryrun(cfg, shape, mesh, opts)
    t0 = time.time()
    lowered = fn.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    cost = _cost_dict(compiled)
    mem = compiled.memory_analysis()
    return {
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": collective_bytes(compiled.as_text()),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
    }


def _layer_probes(cfg):
    """Reduced-layer unrolled probe configs + extrapolation weights.

    XLA cost analysis counts while-loop (scan) bodies once, and a full unroll
    of a 40-layer model takes minutes on this box — so we compile tiny
    *unrolled* probes at 2-3 layer counts and extrapolate the exactly-linear
    per-layer terms to the full depth. Returns (probe_cfgs, combine) where
    combine(values: list) -> extrapolated full-model value.
    """
    if cfg.family == "encdec":
        e, d = cfg.encoder.n_layers, cfg.n_layers
        probes = [
            cfg.replace(n_layers=2, encoder=cfg.encoder.__class__(
                n_layers=2, n_frames=cfg.encoder.n_frames)),
            cfg.replace(n_layers=2, encoder=cfg.encoder.__class__(
                n_layers=4, n_frames=cfg.encoder.n_frames)),
            cfg.replace(n_layers=4, encoder=cfg.encoder.__class__(
                n_layers=2, n_frames=cfg.encoder.n_frames)),
        ]

        def combine(v):
            per_enc = (v[1] - v[0]) / 2.0
            per_dec = (v[2] - v[0]) / 2.0
            ovh = v[0] - 2 * per_enc - 2 * per_dec
            return ovh + e * per_enc + d * per_dec
        return probes, combine

    if cfg.family == "hybrid":
        plen = len(cfg.hybrid.pattern)
        groups = cfg.n_layers // plen
        tail = cfg.n_layers % plen
        probes = [cfg.replace(n_layers=plen), cfg.replace(n_layers=2 * plen)]
        if tail:
            probes.append(cfg.replace(n_layers=plen + tail))

        def combine(v):
            per_group = v[1] - v[0]
            ovh = v[0] - per_group
            total = ovh + groups * per_group
            if tail:
                total += v[2] - v[0]
            return total
        return probes, combine

    probes = [cfg.replace(n_layers=2), cfg.replace(n_layers=4)]

    def combine(v):
        per = (v[1] - v[0]) / 2.0
        return (v[0] - 2 * per) + cfg.n_layers * per
    return probes, combine


def run_one(arch: str, shape_name: str, multi_pod: bool,
            opts: frozenset = frozenset()) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "opts": sorted(opts), "ts": time.time()}
    if not supports(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = "no sub-quadratic variant (DESIGN.md §4)"
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        # 1) the gate: the FULL config must lower + compile (scan-over-layers)
        full = _measure(cfg, shape, mesh, opts)
        # 2) unrolled reduced-layer probes -> exact per-layer extrapolation
        probes, combine = _layer_probes(cfg.replace(scan_unroll=True))
        pvals = [_measure(p, shape, mesh, opts) for p in probes]

        def extra(key):
            return combine([p[key] for p in pvals])

        coll_kinds = set()
        for p in pvals:
            coll_kinds |= set(p["collective_bytes"])
        coll = {k: max(combine([p["collective_bytes"].get(k, 0)
                                for p in pvals]), 0.0) for k in coll_kinds}
    rec.update({
        "status": "ok",
        "lower_s": full["lower_s"],
        "compile_s": full["compile_s"],
        "flops": max(extra("flops"), 0.0),            # per-device, full depth
        "bytes_accessed": max(extra("bytes_accessed"), 0.0),
        "collective_bytes": coll,
        "flops_scanned_hlo": full["flops"],           # loop-body-once figure
        "memory": full["memory"],
    })
    return rec


def _results_dir(opts: frozenset) -> pathlib.Path:
    return RESULTS_DIR if not opts else RESULTS_DIR.parent / "dryrun_opt"


def load_results(opts: frozenset = frozenset()) -> list:
    d = _results_dir(opts)
    if not d.exists():
        return []
    return [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]


def save_result(rec: dict, opts: frozenset = frozenset()) -> None:
    d = _results_dir(opts)
    d.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh'].replace('x','-')}.json"
    (d / name).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list: act_shard,kv_seq_shard (results land "
                         "in dryrun_opt/)")
    args = ap.parse_args()
    opts = frozenset(o for o in args.opt.split(",") if o)

    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    if args.multi_pod or not args.single_pod:
        meshes.append(True)

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    done = {(r["arch"], r["shape"], r["mesh"]) for r in load_results(opts)
            if r.get("status") in ("ok", "skipped")} if args.skip_done else set()

    failures = 0
    for arch, shape in combos:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            if (arch, shape, mesh_name) in done:
                continue
            try:
                rec = run_one(arch, shape, mp, opts)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "error", "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                failures += 1
            save_result(rec, opts)
            msg = rec["status"]
            if rec["status"] == "ok":
                msg += (f" flops={rec['flops']:.3e} "
                        f"coll={sum(rec['collective_bytes'].values()):.3e}B "
                        f"compile={rec['compile_s']}s")
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: {msg}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
