from repro.traces.synth import TRACE_PRESETS, load_trace, trace_stats  # noqa: F401
