"""Synthetic workload traces statistically matched to the four production
traces the paper evaluates on (Fig. 1/2, §3.1). The originals are not
redistributable; generation is seeded and targets the published moments:

  Azure Code        : bursty (input-length c_v ≈ 0.8/min), long inputs, short
                      outputs, strong in/out correlation (r ≈ 0.95)
  Azure Conversation: moderate lengths, weak correlation (r ≈ 0.29)
  BurstGPT          : frequent bursts (c_v ≈ 1.11/min) via a 2-state MMPP
  Mooncake          : very long inputs, low rate, stable load (c_v ≈ 0.16)

``load_trace(name, rate_scale)`` replays at a scaled request rate by dividing
inter-arrival times — the paper's evaluation-workflow trick (§7.1).
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.core.request import Request


@dataclass(frozen=True)
class TracePreset:
    name: str
    duration: float            # seconds of trace
    base_rate: float           # requests/second at scale 1.0
    in_median: float
    in_sigma: float            # lognormal sigma
    out_median: float
    out_sigma: float
    in_out_corr: float         # target correlation of log-lengths
    burst_rate_mult: float = 1.0   # MMPP high-state rate multiplier
    burst_frac: float = 0.0        # fraction of time in high state
    max_input: int = 32768
    max_output: int = 4096
    slo_ttft: float = 3.0
    slo_tpot: float = 0.1


TRACE_PRESETS: Dict[str, TracePreset] = {
    "azure_code": TracePreset(
        "azure_code", duration=600.0, base_rate=2.0,
        in_median=2600.0, in_sigma=1.3, out_median=28.0, out_sigma=0.9,
        in_out_corr=0.95, burst_rate_mult=10.0, burst_frac=0.10,
        max_input=32768, max_output=2048, slo_ttft=3.0, slo_tpot=0.1),
    "azure_conv": TracePreset(
        "azure_conv", duration=600.0, base_rate=4.0,
        in_median=1024.0, in_sigma=1.1, out_median=220.0, out_sigma=0.8,
        in_out_corr=0.29, burst_rate_mult=2.5, burst_frac=0.15,
        max_input=16384, max_output=2048, slo_ttft=2.0, slo_tpot=0.15),
    "burstgpt": TracePreset(
        "burstgpt", duration=600.0, base_rate=3.0,
        in_median=620.0, in_sigma=1.0, out_median=190.0, out_sigma=0.7,
        in_out_corr=0.55, burst_rate_mult=8.0, burst_frac=0.10,
        max_input=8192, max_output=1024, slo_ttft=0.25, slo_tpot=0.075),
    "mooncake": TracePreset(
        "mooncake", duration=600.0, base_rate=3.0,
        in_median=14000.0, in_sigma=0.55, out_median=300.0, out_sigma=0.5,
        in_out_corr=0.4, burst_rate_mult=1.0, burst_frac=0.0,
        max_input=131072, max_output=2048, slo_ttft=30.0, slo_tpot=0.1),
}


def _arrivals(rng: np.random.Generator, p: TracePreset, rate: float) -> np.ndarray:
    """2-state MMPP: exponential inter-arrivals at low/high rate, switching
    with exponentially-distributed dwell times."""
    lo = rate * (1 - p.burst_frac * p.burst_rate_mult) / max(1 - p.burst_frac, 1e-9)
    lo = max(lo, rate * 0.1)
    hi = rate * p.burst_rate_mult
    t, high = 0.0, False
    dwell_lo, dwell_hi = 60.0, 60.0 * p.burst_frac / max(1 - p.burst_frac, 1e-9)
    next_switch = rng.exponential(dwell_lo)
    out = []
    while t < p.duration:
        r = hi if high else lo
        t += rng.exponential(1.0 / max(r, 1e-9))
        while t >= next_switch:
            high = not high
            next_switch += rng.exponential(dwell_hi if high else dwell_lo)
        if t < p.duration:
            out.append(t)
    return np.asarray(out)


def load_trace(name: str, rate_scale: float = 1.0, *, seed: int = 0,
               duration: float | None = None) -> List[Request]:
    """Generate the named trace, then replay it at ``rate_scale``× speed by
    scaling timestamps (the paper's §7.1 evaluation workflow) — every rate
    sees the *same* request sequence, just denser."""
    p = TRACE_PRESETS[name]
    base_duration = duration * rate_scale if duration is not None else p.duration
    p = TracePreset(**{**p.__dict__, "duration": base_duration})
    # NB: stable across processes (builtin hash() is salted per interpreter)
    rng = np.random.default_rng(zlib.crc32(f"{name}:{seed}".encode()))
    times = _arrivals(rng, p, p.base_rate) / rate_scale
    n = len(times)
    # correlated lognormal lengths
    rho = p.in_out_corr
    z = rng.standard_normal((n, 2))
    z_in = z[:, 0]
    z_out = rho * z[:, 0] + math.sqrt(max(1 - rho * rho, 0.0)) * z[:, 1]
    in_len = np.clip(np.exp(math.log(p.in_median) + p.in_sigma * z_in),
                     16, p.max_input).astype(int)
    out_len = np.clip(np.exp(math.log(p.out_median) + p.out_sigma * z_out),
                      1, p.max_output).astype(int)
    return [Request(rid=i, arrival=float(times[i]), input_len=int(in_len[i]),
                    output_len=int(out_len[i])) for i in range(n)]


def trace_stats(trace: List[Request], bucket: float = 60.0) -> Dict[str, float]:
    """Per-minute load stats matching the paper's Fig. 1/2 measurements."""
    if not trace:
        return {}
    end = max(r.arrival for r in trace) + 1e-9
    nb = int(math.ceil(end / bucket))
    tot_in = np.zeros(nb)
    tot_out = np.zeros(nb)
    for r in trace:
        b = int(r.arrival // bucket)
        tot_in[b] += r.input_len
        tot_out[b] += r.output_len
    ins = np.asarray([r.input_len for r in trace], float)
    outs = np.asarray([r.output_len for r in trace], float)
    corr = float(np.corrcoef(np.log(ins), np.log(outs))[0, 1]) if len(ins) > 2 else 0.0
    return {
        "n_requests": len(trace),
        "input_cv_per_min": float(tot_in.std() / max(tot_in.mean(), 1e-9)),
        "output_cv_per_min": float(tot_out.std() / max(tot_out.mean(), 1e-9)),
        "in_out_corr": corr,
        "input_median": float(np.median(ins)),
        "output_median": float(np.median(outs)),
        "input_p99": float(np.percentile(ins, 99)),
        "rate_req_s": len(trace) / end,
    }
