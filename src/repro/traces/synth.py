"""Synthetic workload traces statistically matched to the four production
traces the paper evaluates on (Fig. 1/2, §3.1), plus two elasticity presets
(spike/diurnal) exercising the AutoScaler (DESIGN.md §6) and a multi-turn
conversation preset (multiturn) exercising the prefix cache (DESIGN.md §7).
The originals are not redistributable; generation is seeded and targets the
published moments.

Preset provenance and target moments (at ``rate_scale=1.0``):

  name        provenance                    rate    in_med  out_med  corr  arrivals
  ----------  ----------------------------  ------  ------  -------  ----  -----------------
  azure_code  Azure LLM code trace (paper   2.0/s   2600    28       0.95  MMPP, 10x bursts
              Fig. 1: c_v≈0.8/min, long                                    10% of time
              inputs, short outputs)
  azure_conv  Azure LLM conversation trace  4.0/s   1024    220      0.29  MMPP, 2.5x bursts
              (moderate lengths, weak                                      15% of time
              in/out correlation)
  burstgpt    BurstGPT open trace (the      3.0/s   620     190      0.55  MMPP, 8x bursts
              burstiest: c_v≈1.11/min)                                     10% of time
  mooncake    Mooncake production trace     3.0/s   14000   300      0.40  Poisson (stable,
              (very long inputs, stable                                    c_v≈0.16)
              load)
  spike       synthetic elasticity study:   1.0/s   1800    160      0.50  6x plateau over
              flash-crowd plateau on an                                    t∈[40%,60%) of
              otherwise calm day                                           the duration
  diurnal     synthetic elasticity study:   1.2/s   1400    180      0.45  sinusoid, 5x
              one compressed day/night                                     peak-to-trough,
              load cycle                                                   peak mid-trace
  multiturn   synthetic chat-session study  0.8/s*  512**   192      0.30  Poisson session
              (multi-turn prefix reuse,                                    starts; turns
              DESIGN.md §7): each session                                  gated on the
              runs ~4 turns whose prompt                                   previous turn's
              is the full history plus a                                   completion + an
              fresh user message                                           exp. think gap
                                                                           (mean 12 s)

  tenants     synthetic multi-tenancy       2.0/s   1200    150      0.50  Poisson per tenant
              study (DESIGN.md §10): 4                                     (rate/5 each); the
              well-behaved tenants plus                                    "flood" tenant
              one adversarial flooder                                      ramps 10x over
                                                                           t∈[45%,70%)

  *  multiturn's base_rate counts *sessions* per second; the request rate
     is ~turns_mean higher.
  ** first-turn prompt median; a follow-up prompt is the whole previous
     context (prompt + output) plus a fresh message of median 96 tokens.

``load_trace(name, rate_scale)`` replays at a scaled request rate by dividing
inter-arrival times — the paper's evaluation-workflow trick (§7.1). The MMPP
presets draw arrivals from a 2-state Markov-modulated Poisson process; the
shaped presets (spike/diurnal) draw from a non-homogeneous Poisson process
via thinning against the deterministic rate profile ``rate_at``. The session
preset (multiturn) draws Poisson session starts and emits one request per
turn carrying ``session_id``/``parent_rid``/``history_len``; a follow-up's
nominal arrival is its parent's plus an exponential think gap, and the
serving runtime additionally gates dispatch on the parent actually finishing
(core/runtime.py), so effective arrival = max(nominal, parent finish).
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.core.request import Request


@dataclass(frozen=True)
class TracePreset:
    name: str
    duration: float            # seconds of trace
    base_rate: float           # requests/second at scale 1.0
    in_median: float
    in_sigma: float            # lognormal sigma
    out_median: float
    out_sigma: float
    in_out_corr: float         # target correlation of log-lengths
    burst_rate_mult: float = 1.0   # MMPP high-state rate multiplier
    burst_frac: float = 0.0        # fraction of time in high state
    max_input: int = 32768
    max_output: int = 4096
    slo_ttft: float = 3.0
    slo_tpot: float = 0.1
    # deterministic rate shaping (elasticity presets): "mmpp" keeps the
    # 2-state MMPP arrivals; "spike"/"diurnal" thin a Poisson process against
    # rate_at(t); "sessions" draws Poisson *session* starts and unrolls each
    # into a gated multi-turn chain (DESIGN.md §7).
    rate_shape: str = "mmpp"
    shape_mult: float = 1.0
    spike_window: Tuple[float, float] = (0.4, 0.6)   # fractions of duration
    # session-preset knobs (rate_shape == "sessions")
    turns_mean: float = 4.0        # geometric mean turns per session
    followup_median: float = 96.0  # fresh user-message tokens per follow-up
    think_mean: float = 12.0       # exp. think-time gap between turns (s)
    # tenancy-preset knobs (rate_shape == "tenants", DESIGN.md §10):
    # n_tenants well-behaved tenants t0..t{n-1} share base_rate evenly; one
    # adversarial "flood" tenant starts at the same per-tenant rate and
    # ramps shape_mult× inside spike_window.
    n_tenants: int = 4

    def rate_at(self, t: float) -> float:
        """Deterministic request rate (req/s) at trace time ``t`` for the
        shaped presets; the MMPP presets return base_rate (their burstiness
        is stochastic)."""
        if self.rate_shape == "spike":
            a, b = self.spike_window
            inside = a * self.duration <= t < b * self.duration
            return self.base_rate * (self.shape_mult if inside else 1.0)
        if self.rate_shape == "diurnal":
            # one full day compressed into `duration`: trough at t=0, peak
            # mid-trace, trough again at the end.
            phase = 0.5 * (1.0 - math.cos(2 * math.pi * t / self.duration))
            return self.base_rate * (1.0 + (self.shape_mult - 1.0) * phase)
        return self.base_rate


TRACE_PRESETS: Dict[str, TracePreset] = {
    "azure_code": TracePreset(
        "azure_code", duration=600.0, base_rate=2.0,
        in_median=2600.0, in_sigma=1.3, out_median=28.0, out_sigma=0.9,
        in_out_corr=0.95, burst_rate_mult=10.0, burst_frac=0.10,
        max_input=32768, max_output=2048, slo_ttft=3.0, slo_tpot=0.1),
    "azure_conv": TracePreset(
        "azure_conv", duration=600.0, base_rate=4.0,
        in_median=1024.0, in_sigma=1.1, out_median=220.0, out_sigma=0.8,
        in_out_corr=0.29, burst_rate_mult=2.5, burst_frac=0.15,
        max_input=16384, max_output=2048, slo_ttft=2.0, slo_tpot=0.15),
    "burstgpt": TracePreset(
        "burstgpt", duration=600.0, base_rate=3.0,
        in_median=620.0, in_sigma=1.0, out_median=190.0, out_sigma=0.7,
        in_out_corr=0.55, burst_rate_mult=8.0, burst_frac=0.10,
        max_input=8192, max_output=1024, slo_ttft=0.25, slo_tpot=0.075),
    "mooncake": TracePreset(
        "mooncake", duration=600.0, base_rate=3.0,
        in_median=14000.0, in_sigma=0.55, out_median=300.0, out_sigma=0.5,
        in_out_corr=0.4, burst_rate_mult=1.0, burst_frac=0.0,
        max_input=131072, max_output=2048, slo_ttft=30.0, slo_tpot=0.1),
    # ---- elasticity presets (DESIGN.md §6): deterministic load shapes that
    # a fixed-size cluster must over-provision for. Exercised by
    # benchmarks/bench_elastic.py and tests/test_autoscaler.py.
    "spike": TracePreset(
        "spike", duration=600.0, base_rate=1.0,
        in_median=1800.0, in_sigma=1.0, out_median=160.0, out_sigma=0.7,
        in_out_corr=0.5, max_input=16384, max_output=1024,
        slo_ttft=2.0, slo_tpot=0.1,
        rate_shape="spike", shape_mult=6.0, spike_window=(0.4, 0.6)),
    "diurnal": TracePreset(
        "diurnal", duration=600.0, base_rate=1.2,
        in_median=1400.0, in_sigma=1.0, out_median=180.0, out_sigma=0.7,
        in_out_corr=0.45, max_input=16384, max_output=1024,
        slo_ttft=2.0, slo_tpot=0.1,
        rate_shape="diurnal", shape_mult=5.0),
    # ---- multi-turn conversation preset (DESIGN.md §7): sessions with a
    # growing shared history — the workload where prefix reuse pays.
    # Exercised by benchmarks/bench_prefix.py and tests/test_prefix.py.
    "multiturn": TracePreset(
        "multiturn", duration=600.0, base_rate=0.8,   # sessions/s
        in_median=512.0, in_sigma=0.8, out_median=192.0, out_sigma=0.6,
        in_out_corr=0.3, max_input=16384, max_output=1024,
        slo_ttft=2.0, slo_tpot=0.1,
        rate_shape="sessions", turns_mean=4.0, followup_median=96.0,
        think_mean=12.0),
    # ---- multi-tenant preset (DESIGN.md §10): heterogeneous tenants plus
    # one adversarial flooder ramping 10× mid-trace — the workload where
    # credit-based admission + WDRR dispatch pay. Exercised by
    # benchmarks/bench_tenants.py and tests/test_tenants.py.
    "tenants": TracePreset(
        "tenants", duration=600.0, base_rate=2.0,
        in_median=1200.0, in_sigma=0.9, out_median=150.0, out_sigma=0.7,
        in_out_corr=0.5, max_input=8192, max_output=1024,
        slo_ttft=2.5, slo_tpot=0.12,
        rate_shape="tenants", shape_mult=10.0, spike_window=(0.45, 0.7),
        n_tenants=4),
}


def _shaped_arrivals(rng: np.random.Generator, p: TracePreset) -> np.ndarray:
    """Non-homogeneous Poisson arrivals against the deterministic rate
    profile ``p.rate_at`` (Lewis–Shedler thinning)."""
    lam_max = p.base_rate * max(p.shape_mult, 1.0)
    t, out = 0.0, []
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= p.duration:
            break
        if rng.random() * lam_max <= p.rate_at(t):
            out.append(t)
    return np.asarray(out)


def _arrivals(rng: np.random.Generator, p: TracePreset, rate: float) -> np.ndarray:
    """2-state MMPP: exponential inter-arrivals at low/high rate, switching
    with exponentially-distributed dwell times."""
    if p.rate_shape != "mmpp":
        return _shaped_arrivals(rng, p)
    lo = rate * (1 - p.burst_frac * p.burst_rate_mult) / max(1 - p.burst_frac, 1e-9)
    lo = max(lo, rate * 0.1)
    hi = rate * p.burst_rate_mult
    t, high = 0.0, False
    dwell_lo, dwell_hi = 60.0, 60.0 * p.burst_frac / max(1 - p.burst_frac, 1e-9)
    next_switch = rng.exponential(dwell_lo)
    out = []
    while t < p.duration:
        r = hi if high else lo
        t += rng.exponential(1.0 / max(r, 1e-9))
        while t >= next_switch:
            high = not high
            next_switch += rng.exponential(dwell_hi if high else dwell_lo)
        if t < p.duration:
            out.append(t)
    return np.asarray(out)


def _session_trace(rng: np.random.Generator, p: TracePreset,
                   rate_scale: float) -> List[Request]:
    """Multi-turn sessions (DESIGN.md §7): Poisson session starts; each
    session runs a geometric number of turns. Turn k's prompt is the whole
    previous context (prompt + output) plus a fresh user message, so
    ``input_len`` grows and ``history_len`` records the shared prefix. The
    nominal arrival of a follow-up is its parent's arrival plus an
    exponential think gap — the runtime gates actual dispatch on the parent
    finishing, so the chain is causally ordered whatever the timings."""
    starts = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / p.base_rate)
        if t >= p.duration:
            break
        starts.append(t)
    chains: List[List[Request]] = []
    for sid, t0 in enumerate(starts):
        n_turns = int(rng.geometric(1.0 / max(p.turns_mean, 1.0)))
        t_arr, ctx = t0, 0
        chain: List[Request] = []
        for k in range(n_turns):
            med = p.in_median if k == 0 else p.followup_median
            z = rng.standard_normal(2)
            fresh = int(np.clip(np.exp(math.log(med) + p.in_sigma * z[0]),
                                16, p.max_input))
            in_len = ctx + fresh
            if in_len > p.max_input:      # history would overflow: end here
                break
            rho = p.in_out_corr
            z_out = rho * z[0] + math.sqrt(max(1 - rho * rho, 0.0)) * z[1]
            out_len = int(np.clip(
                np.exp(math.log(p.out_median) + p.out_sigma * z_out),
                1, p.max_output))
            chain.append(Request(
                rid=-1, arrival=t_arr / rate_scale, input_len=in_len,
                output_len=out_len, session_id=sid, history_len=ctx))
            ctx = in_len + out_len
            t_arr += rng.exponential(p.think_mean)
        if chain:
            chains.append(chain)
    # rids in global arrival order; parent links follow the chain order
    flat = sorted((r for c in chains for r in c), key=lambda r: r.arrival)
    rid_of = {}
    for i, r in enumerate(flat):
        r.rid = i
        rid_of[id(r)] = i
    for chain in chains:
        for parent, child in zip(chain, chain[1:]):
            child.parent_rid = rid_of[id(parent)]
    return flat


def _tenant_trace(rng: np.random.Generator, p: TracePreset,
                  rate_scale: float) -> List[Request]:
    """Multi-tenant workload (DESIGN.md §10): ``n_tenants`` well-behaved
    tenants each drive a homogeneous Poisson stream at ``base_rate /
    n_tenants``; an adversarial "flood" tenant starts at the same
    per-tenant rate and ramps ``shape_mult``× inside ``spike_window``
    (Lewis–Shedler thinning). Lengths are the usual correlated lognormals;
    rids are assigned in global arrival order and every request carries its
    ``tenant_id``."""
    per = p.base_rate / max(p.n_tenants, 1)
    a, b = p.spike_window
    labelled: List[Tuple[float, str]] = []
    for i in range(p.n_tenants):
        t = 0.0
        while True:
            t += rng.exponential(1.0 / per)
            if t >= p.duration:
                break
            labelled.append((t, f"t{i}"))
    lam_max = per * max(p.shape_mult, 1.0)
    t = 0.0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= p.duration:
            break
        inside = a * p.duration <= t < b * p.duration
        if rng.random() * lam_max <= per * (p.shape_mult if inside else 1.0):
            labelled.append((t, "flood"))
    labelled.sort(key=lambda x: x[0])
    n = len(labelled)
    rho = p.in_out_corr
    z = rng.standard_normal((n, 2))
    z_out = rho * z[:, 0] + math.sqrt(max(1 - rho * rho, 0.0)) * z[:, 1]
    in_len = np.clip(np.exp(math.log(p.in_median) + p.in_sigma * z[:, 0]),
                     16, p.max_input).astype(int)
    out_len = np.clip(np.exp(math.log(p.out_median) + p.out_sigma * z_out),
                      1, p.max_output).astype(int)
    return [Request(rid=i, arrival=float(labelled[i][0]) / rate_scale,
                    input_len=int(in_len[i]), output_len=int(out_len[i]),
                    tenant_id=labelled[i][1]) for i in range(n)]


def load_trace(name: str, rate_scale: float = 1.0, *, seed: int = 0,
               duration: float | None = None) -> List[Request]:
    """Generate the named trace, then replay it at ``rate_scale``× speed by
    scaling timestamps (the paper's §7.1 evaluation workflow) — every rate
    sees the *same* request sequence, just denser."""
    p = TRACE_PRESETS[name]
    base_duration = duration * rate_scale if duration is not None else p.duration
    p = TracePreset(**{**p.__dict__, "duration": base_duration})
    # NB: stable across processes (builtin hash() is salted per interpreter)
    rng = np.random.default_rng(zlib.crc32(f"{name}:{seed}".encode()))
    if p.rate_shape == "sessions":
        return _session_trace(rng, p, rate_scale)
    if p.rate_shape == "tenants":
        return _tenant_trace(rng, p, rate_scale)
    times = _arrivals(rng, p, p.base_rate) / rate_scale
    n = len(times)
    # correlated lognormal lengths
    rho = p.in_out_corr
    z = rng.standard_normal((n, 2))
    z_in = z[:, 0]
    z_out = rho * z[:, 0] + math.sqrt(max(1 - rho * rho, 0.0)) * z[:, 1]
    in_len = np.clip(np.exp(math.log(p.in_median) + p.in_sigma * z_in),
                     16, p.max_input).astype(int)
    out_len = np.clip(np.exp(math.log(p.out_median) + p.out_sigma * z_out),
                      1, p.max_output).astype(int)
    return [Request(rid=i, arrival=float(times[i]), input_len=int(in_len[i]),
                    output_len=int(out_len[i])) for i in range(n)]


def trace_stats(trace: List[Request], bucket: float = 60.0) -> Dict[str, float]:
    """Per-minute load stats matching the paper's Fig. 1/2 measurements."""
    if not trace:
        return {}
    end = max(r.arrival for r in trace) + 1e-9
    nb = int(math.ceil(end / bucket))
    tot_in = np.zeros(nb)
    tot_out = np.zeros(nb)
    for r in trace:
        b = int(r.arrival // bucket)
        tot_in[b] += r.input_len
        tot_out[b] += r.output_len
    ins = np.asarray([r.input_len for r in trace], float)
    outs = np.asarray([r.output_len for r in trace], float)
    corr = float(np.corrcoef(np.log(ins), np.log(outs))[0, 1]) if len(ins) > 2 else 0.0
    return {
        "n_requests": len(trace),
        "input_cv_per_min": float(tot_in.std() / max(tot_in.mean(), 1e-9)),
        "output_cv_per_min": float(tot_out.std() / max(tot_out.mean(), 1e-9)),
        "in_out_corr": corr,
        "input_median": float(np.median(ins)),
        "output_median": float(np.median(outs)),
        "input_p99": float(np.percentile(ins, 99)),
        "rate_req_s": len(trace) / end,
    }
