"""Deterministic synthetic token pipeline for the training examples/dry-runs.

Generates Zipf-distributed token streams with document structure (BOS-delimited
segments) — enough statistical structure for a language-modeling loss to fall
during the example run, with zero external data dependencies.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class SyntheticTokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int, *,
                 seed: int = 0, zipf_a: float = 1.3, mean_doc_len: int = 512):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.zipf_a = zipf_a
        self.mean_doc_len = mean_doc_len
        # fixed bigram mixing table gives learnable sequential structure
        self._shift = self.rng.integers(1, vocab_size, size=1024)

    def _stream(self, n: int) -> np.ndarray:
        z = self.rng.zipf(self.zipf_a, size=n)
        toks = np.minimum(z, self.vocab_size - 2).astype(np.int64)
        # inject learnable bigram structure: every 2nd token derived from prev
        prev = np.roll(toks, 1)
        mask = (np.arange(n) % 2).astype(bool)
        derived = (prev + self._shift[prev % 1024]) % (self.vocab_size - 2)
        toks = np.where(mask, derived, toks)
        # BOS-delimited "documents"
        doc_breaks = self.rng.random(n) < (1.0 / self.mean_doc_len)
        toks[doc_breaks] = self.vocab_size - 1
        return toks

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            n = self.batch_size * self.seq_len
            yield {"tokens": self._stream(n).reshape(
                self.batch_size, self.seq_len).astype(np.int32)}
