"""Clock abstraction: one scheduling code path over simulated and real time.

The global/local schedulers, the migration manager and the monitor all take
``now`` as a plain float; the ``Clock`` is what a ``ServingSystem`` driver
consults to produce that float. ``VirtualClock`` is advanced explicitly by the
discrete-event simulator; ``WallClock`` measures real elapsed seconds for the
JAX engine. Everything above the clock is shared (core/runtime.py).
"""
from __future__ import annotations

import time
from typing import Optional, Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """Minimal time source: monotonically non-decreasing seconds."""

    def now(self) -> float: ...


class VirtualClock:
    """Discrete-event time, advanced explicitly by the simulator's event loop.

    ``advance`` clamps backwards moves to keep time monotone even if two
    events carry the same timestamp.
    """

    def __init__(self, t0: float = 0.0):
        self._t = t0

    def now(self) -> float:
        return self._t

    def advance(self, t: float) -> None:
        if t > self._t:
            self._t = t


class WallClock:
    """Real elapsed seconds since ``start()``; starts lazily on first use so a
    batch of ``submit()`` calls before the serving loop doesn't eat into the
    requests' arrival offsets."""

    def __init__(self):
        self._t0: Optional[float] = None

    @property
    def started(self) -> bool:
        return self._t0 is not None

    def start(self) -> None:
        if self._t0 is None:
            self._t0 = time.perf_counter()

    def now(self) -> float:
        self.start()
        return time.perf_counter() - self._t0
