"""Instance monitor (§5.2): periodically scrapes per-instance performance
metrics; the global scheduler reads these snapshots (possibly slightly stale,
exactly as in the paper — Insights 3/4 make decode tolerate that)."""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class InstanceStats:
    instance_id: int
    # prefill side
    prefill_queue_len: int = 0
    prefill_backlog_tokens: int = 0
    prefill_ready_at: float = 0.0        # predicted drain time (abs seconds)
    # decode side
    running_tokens: int = 0              # Σ tokens of decode requests on instance
    n_decode_running: int = 0
    avg_token_interval: float = 0.0      # recent mean seconds/token
    # memory
    kv_tokens_used: int = 0
    kv_tokens_capacity: int = 0

    @property
    def has_prefill_work(self) -> bool:
        return self.prefill_queue_len > 0

    @property
    def has_decode_work(self) -> bool:
        return self.n_decode_running > 0


class InstanceMonitor:
    """Keeps the latest stats snapshot + a sliding window of token-generation
    intervals per instance."""

    def __init__(self, instance_ids, window: int = 32):
        self._window = window
        self.stats: Dict[int, InstanceStats] = {
            iid: InstanceStats(iid) for iid in instance_ids}
        self._intervals: Dict[int, deque] = {
            iid: deque(maxlen=window) for iid in instance_ids}
        self._last_token_at: Dict[int, Optional[float]] = {
            iid: None for iid in instance_ids}

    # ----------------------------------------------------------- lifecycle
    def add_instance(self, iid: int) -> None:
        """A freshly provisioned instance joins the scrape set (DESIGN.md §6)."""
        self.stats.setdefault(iid, InstanceStats(iid))
        self._intervals.setdefault(iid, deque(maxlen=self._window))
        self._last_token_at.setdefault(iid, None)

    def remove_instance(self, iid: int) -> None:
        self.stats.pop(iid, None)
        self._intervals.pop(iid, None)
        self._last_token_at.pop(iid, None)

    # --------------------------------------------------------- ingestion
    def record_iteration(self, iid: int, now: float, tokens_emitted: int,
                         duration: float) -> None:
        """Called after an instance finishes one iteration that emitted decode
        tokens. The token-generation interval sample is the *iteration
        duration* (each running request got one token per iteration); gaps
        while an instance sits idle are not decode slowness and must not
        poison the TPOT signal. A straggling record for an instance already
        removed/failed is dropped silently — the async engine step can
        finalize an iteration after the crash teardown popped the monitor
        entry, and a KeyError there would take the whole step loop down."""
        if tokens_emitted > 0 and iid in self._intervals:
            self._intervals[iid].append(duration)
            self._last_token_at[iid] = now

    def update_stats(self, s: InstanceStats) -> None:
        iv = self._intervals.get(s.instance_id)
        if iv is None:          # scrape raced instance removal: drop it
            return
        s.avg_token_interval = (sum(iv) / len(iv)) if iv else 0.0
        self.stats[s.instance_id] = s

    # ----------------------------------------------------------- queries
    def get(self, iid: int) -> InstanceStats:
        return self.stats[iid]

    def avg_token_interval(self, iid: int) -> float:
        iv = self._intervals[iid]
        return (sum(iv) / len(iv)) if iv else 0.0

    def reset_intervals(self, iid: int) -> None:
        self._intervals[iid].clear()
        self._last_token_at[iid] = None
