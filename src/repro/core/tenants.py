"""Multi-tenant credit scheduling + overload admission control (DESIGN.md
§10).

Arrow's elastic pools (§6) match *aggregate* capacity to *aggregate* load;
nothing stops one client from flooding ``submit()`` and killing every other
client's p99. This module adds the missing tenancy layer:

  * :class:`Tenant` / :class:`TenantRegistry` — each tenant declares an SLO
    tier (``interactive``/``standard``/``batch``) and a share weight; the
    registry also tracks per-tenant admission counters and an EWMA of SLO
    violations observed at finish/reject time.
  * :class:`CreditLedger` — credits accrue per monitor tick from
    declared-vs-observed SLO attainment (attainment earns, the violation
    EWMA debits) and are spent at admission, priced per requested token.
    Balances are clamped to a weight-scaled burst cap, so saving up cannot
    buy an unbounded flood.
  * :class:`AdmissionController` — a watermark guard over the same Eq.
    (1)/(2) cluster-pressure signals the AutoScaler reads
    (core/autoscaler.py ``prefill_pressure``/``decode_pressure``): below the
    low watermark everything admits (credits are still drained, never
    gated); between the watermarks admission is credit-gated with a bounded
    :class:`RetryQueue` (deadline-aware re-admission through the backend's
    retry event); above the high watermark load is shed *before* elastic
    scale-up saturates — only a tenant whose savings cover a premium gets
    through.
  * Typed :class:`AdmissionDecision` results — :class:`Admitted`,
    :class:`Deferred` (carries ``retry_at``/``deadline``) and
    :class:`Rejected` (carries ``reason`` ∈ {overload, no_credit,
    retry_queue_full, parent_rejected} and a ``retry_after`` hint).

The controller is backend-agnostic: it reads the runtime's pools/policy/
monitor state and never touches KV accounting — a rejected request is
turned away *before* ``place_prefill``/``enqueue_prefill``, which is what
keeps the §8.4 invariant harness (and ``drain()``'s stranded-rid check)
oblivious to rejected rids by construction.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from repro.core.autoscaler import decode_pressure, prefill_pressure
from repro.core.request import Request, RequestState

DEFAULT_TENANT = "anonymous"

#: per-tier default share weights used by :func:`default_registry` —
#: interactive tenants paid for headroom, batch tenants ride the slack.
TIER_WEIGHTS = {"interactive": 2.0, "standard": 1.0, "batch": 0.5}


@dataclass(frozen=True)
class Tenant:
    """One client of the serving system: a declared SLO tier plus a share
    weight scaling both credit accrual and the WDRR dispatch quantum."""

    tenant_id: str
    tier: str = "standard"
    weight: float = 1.0

    def __post_init__(self):
        from repro.core.serving import TIERS
        if self.tier not in TIERS:
            raise ValueError(f"unknown SLO tier {self.tier!r}; "
                             f"choose from {sorted(TIERS)}")
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")


class EWMA:
    """Exponentially weighted moving average of a 0/1 violation stream."""

    def __init__(self, alpha: float = 0.2, init: float = 0.0):
        self.alpha = alpha
        self.value = init

    def update(self, x: float) -> float:
        self.value += self.alpha * (x - self.value)
        return self.value


@dataclass(frozen=True)
class CreditLedgerConfig:
    """Credit-economy knobs; all rates/caps scale with the tenant weight."""

    earn_rate: float = 2.0     # credits/s at weight 1.0 and zero violations
    debit_rate: float = 4.0    # credits/s drained at violation EWMA = 1.0
    initial: float = 8.0       # starting balance at weight 1.0
    cap: float = 20.0          # burst allowance ceiling at weight 1.0


class CreditLedger:
    """Per-tenant credit balances: accrue on the monitor tick, spend at
    admission. Attainment earns, the violation EWMA debits (declared-vs-
    observed SLO), and balances clamp to ``[0, cap × weight]``."""

    def __init__(self, cfg: Optional[CreditLedgerConfig] = None):
        self.cfg = cfg or CreditLedgerConfig()
        self._balance: Dict[str, float] = {}

    def open(self, tenant: Tenant) -> None:
        self._balance.setdefault(tenant.tenant_id,
                                 self.cfg.initial * tenant.weight)

    def balance(self, tenant_id: str) -> float:
        return self._balance.get(tenant_id, 0.0)

    def accrue(self, tenant: Tenant, violation_ewma: float,
               dt: float) -> float:
        """One monitor tick's worth of accrual: ``(1 - v)`` of the earn rate
        minus ``v`` of the debit rate, weight-scaled and clamped."""
        v = min(max(violation_ewma, 0.0), 1.0)
        delta = dt * tenant.weight * (self.cfg.earn_rate * (1.0 - v)
                                      - self.cfg.debit_rate * v)
        cap = self.cfg.cap * tenant.weight
        bal = min(max(self.balance(tenant.tenant_id) + delta, 0.0), cap)
        self._balance[tenant.tenant_id] = bal
        return bal

    def spend(self, tenant_id: str, cost: float) -> bool:
        """Gated spend: deduct ``cost`` iff the balance covers it."""
        bal = self.balance(tenant_id)
        if bal < cost:
            return False
        self._balance[tenant_id] = bal - cost
        return True

    def drain(self, tenant_id: str, cost: float) -> None:
        """Ungated spend (below the low watermark admission never blocks,
        but the flood still pays): deduct down to the zero floor."""
        self._balance[tenant_id] = max(self.balance(tenant_id) - cost, 0.0)


class TenantRegistry:
    """Tenant roster + ledger + per-tenant admission/SLO observation state.

    Unknown tenant ids auto-register with standard tier and weight 1.0
    (authn/z is out of scope here); use :meth:`register` to declare tiers
    and weights up front."""

    COUNTERS = ("submitted", "admitted", "deferred", "rejected", "shed",
                "finished", "slo_ok")

    def __init__(self, tenants: Iterable[Tenant] = (), *,
                 ledger: Optional[CreditLedger] = None,
                 violation_alpha: float = 0.2):
        self._tenants: "OrderedDict[str, Tenant]" = OrderedDict()
        self.ledger = ledger or CreditLedger()
        self._violation_alpha = violation_alpha
        self._viol: Dict[str, EWMA] = {}
        self.counters: Dict[str, Dict[str, int]] = {}
        self._last_tick: Optional[float] = None
        for t in tenants:
            self.register(t)

    # ------------------------------------------------------------- roster
    def register(self, tenant: Tenant) -> Tenant:
        if tenant.tenant_id in self._tenants:
            raise ValueError(f"tenant {tenant.tenant_id!r} already "
                             f"registered")
        self._tenants[tenant.tenant_id] = tenant
        self.ledger.open(tenant)
        self._viol[tenant.tenant_id] = EWMA(self._violation_alpha)
        self.counters[tenant.tenant_id] = {c: 0 for c in self.COUNTERS}
        return tenant

    def ensure(self, tenant_id: str) -> Tenant:
        t = self._tenants.get(tenant_id)
        if t is None:
            t = self.register(Tenant(tenant_id))
        return t

    def get(self, tenant_id: str) -> Optional[Tenant]:
        return self._tenants.get(tenant_id)

    def ids(self) -> List[str]:
        return list(self._tenants)

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._tenants

    def __len__(self) -> int:
        return len(self._tenants)

    # -------------------------------------------------------- observation
    def note_submit(self, tenant_id: str) -> None:
        self.ensure(tenant_id)
        self.counters[tenant_id]["submitted"] += 1

    def note_admit(self, tenant_id: str) -> None:
        self.ensure(tenant_id)
        self.counters[tenant_id]["admitted"] += 1

    def note_defer(self, tenant_id: str) -> None:
        self.ensure(tenant_id)
        self.counters[tenant_id]["deferred"] += 1

    def note_reject(self, tenant_id: str, *, shed: bool) -> None:
        self.ensure(tenant_id)
        self.counters[tenant_id]["shed" if shed else "rejected"] += 1
        # a turned-away request is a violation of the declared SLO
        self._viol[tenant_id].update(1.0)

    def note_finish(self, tenant_id: str, met_slo: bool) -> None:
        self.ensure(tenant_id)
        c = self.counters[tenant_id]
        c["finished"] += 1
        c["slo_ok"] += int(met_slo)
        self._viol[tenant_id].update(0.0 if met_slo else 1.0)

    def violation_ewma(self, tenant_id: str) -> float:
        e = self._viol.get(tenant_id)
        return e.value if e is not None else 0.0

    # ------------------------------------------------------------ credits
    def on_tick(self, now: float) -> None:
        """Credit accrual, called from the runtime's monitor tick."""
        if self._last_tick is None:
            self._last_tick = now
            return
        dt = now - self._last_tick
        self._last_tick = now
        if dt <= 0:
            return
        for tid, tenant in self._tenants.items():
            self.ledger.accrue(tenant, self._viol[tid].value, dt)

    def credits(self, tenant_id: str) -> float:
        return self.ledger.balance(tenant_id)


# --------------------------------------------------------------- decisions
@dataclass(frozen=True)
class AdmissionDecision:
    """Base of the typed admission results."""

    tenant_id: str
    pressure: float            # watermark signal at decision time


@dataclass(frozen=True)
class Admitted(AdmissionDecision):
    cost: float = 0.0          # credits charged


@dataclass(frozen=True)
class Deferred(AdmissionDecision):
    """Parked in the RetryQueue; the backend re-delivers at ``retry_at``."""

    retry_at: float = 0.0
    deadline: float = 0.0


@dataclass(frozen=True)
class Rejected(AdmissionDecision):
    """Terminal: the request never enters scheduling or KV accounting.
    ``reason`` ∈ {"overload", "no_credit", "retry_queue_full",
    "parent_rejected"}; ``retry_after`` is the client back-off hint in
    seconds."""

    reason: str = "overload"
    retry_after: float = 1.0


class RetryQueue:
    """Bounded deadline bookkeeping for credit-deferred requests. The
    *events* that re-deliver a deferred request live in the backend (sim
    heap / engine pending heap); this structure only bounds how many rids
    may wait and remembers each one's deadline and attempt count."""

    def __init__(self, maxlen: int = 64):
        self.maxlen = maxlen
        self._entries: "OrderedDict[int, float]" = OrderedDict()  # rid -> ddl
        self.attempts: Dict[int, int] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries

    def deadline(self, rid: int) -> Optional[float]:
        return self._entries.get(rid)

    def offer(self, rid: int, deadline: float) -> bool:
        """Admit ``rid`` into the queue (or bump its attempt count when it
        is already waiting). False when the queue is full."""
        if rid in self._entries:
            self.attempts[rid] += 1
            return True
        if len(self._entries) >= self.maxlen:
            return False
        self._entries[rid] = deadline
        self.attempts[rid] = 1
        return True

    def remove(self, rid: int) -> None:
        self._entries.pop(rid, None)
        self.attempts.pop(rid, None)


@dataclass(frozen=True)
class AdmissionConfig:
    """Watermark-guard knobs (see docs/OPERATOR.md §8 for tuning)."""

    low_watermark: float = 0.4     # below: admit everything (credits drain)
    high_watermark: float = 1.2    # above: shed unless savings cover premium
    cost_per_token: float = 1e-4   # credits per requested (in+out) token
    shed_premium: float = 4.0      # cost multiplier to pass the shed gate
    retry_interval: float = 0.25   # seconds between re-admission attempts
    retry_queue_len: int = 64      # bounded RetryQueue size
    deadline_scale: float = 1.0    # deadline = arrival + scale × slo.ttft


class AdmissionController:
    """Watermark guard + credit gate + retry/deadline bookkeeping. One
    ``consider()`` call per delivery of a request into ``dispatch_prefill``;
    the decision is sticky for admitted rids (crash recovery and
    no-ACTIVE-instance re-dispatch must not re-charge)."""

    def __init__(self, runtime, registry: TenantRegistry,
                 cfg: Optional[AdmissionConfig] = None):
        self.runtime = runtime
        self.registry = registry
        self.cfg = cfg or AdmissionConfig()
        self.retry_queue = RetryQueue(self.cfg.retry_queue_len)
        self._status: Dict[int, str] = {}       # rid -> admitted|rejected
        self.stats: Dict[str, int] = {
            "admitted": 0, "deferred": 0, "rejected": 0, "shed": 0,
            "retries": 0}
        self.last_pressure = 0.0

    # ------------------------------------------------------------- signals
    def pressure(self, now: float) -> float:
        """Cluster pressure for the watermark guard: the max of the two
        Eq. (1)/(2) signals the AutoScaler reads (1.0 ≈ at budget; ``inf``
        when a capable set is empty — nothing can take the work)."""
        p = max(prefill_pressure(self.runtime, now),
                decode_pressure(self.runtime))
        self.last_pressure = p
        return p

    def request_cost(self, req: Request) -> float:
        return (req.input_len + req.output_len) * self.cfg.cost_per_token

    def _tenant_of(self, req: Request) -> Tenant:
        return self.registry.ensure(req.tenant_id or DEFAULT_TENANT)

    # ------------------------------------------------------------ decision
    def consider(self, handle, now: float) -> AdmissionDecision:
        req = handle.req
        rid = req.rid
        tenant = self._tenant_of(req)
        tid = tenant.tenant_id
        status = self._status.get(rid)
        if status == "admitted":
            # re-delivery of an already-admitted request (crash recovery,
            # or the no-ACTIVE-instance retry path): never re-charge
            return Admitted(tid, self.last_pressure, cost=0.0)
        ledger = self.registry.ledger
        cost = self.request_cost(req)
        pressure = self.pressure(now)
        deadline = req.arrival + self.cfg.deadline_scale * handle.slo.ttft

        if pressure < self.cfg.low_watermark:
            ledger.drain(tid, cost)
            return self._admit(rid, tid, pressure, cost)

        if pressure >= self.cfg.high_watermark:
            # shed zone: only savings buy entry — reject, never queue
            # (queued work would melt an already-overloaded cluster)
            if ledger.spend(tid, cost * self.cfg.shed_premium):
                return self._admit(rid, tid, pressure, cost)
            return self._reject(rid, tid, pressure, "overload", now,
                                deadline)

        # credit zone: spend or wait (bounded, deadline-aware)
        if ledger.spend(tid, cost):
            return self._admit(rid, tid, pressure, cost)
        if now >= deadline:
            return self._reject(rid, tid, pressure, "no_credit", now,
                                deadline)
        if not self.retry_queue.offer(rid, deadline):
            return self._reject(rid, tid, pressure, "retry_queue_full",
                                now, deadline)
        if self.retry_queue.attempts[rid] > 1:
            self.stats["retries"] += 1
        else:
            self.stats["deferred"] += 1
            self.registry.note_defer(tid)
        retry_at = min(now + self.cfg.retry_interval, deadline)
        return Deferred(tid, pressure, retry_at=retry_at, deadline=deadline)

    # -------------------------------------------------------- transitions
    def _admit(self, rid: int, tid: str, pressure: float,
               cost: float) -> Admitted:
        self._status[rid] = "admitted"
        self.retry_queue.remove(rid)
        self.stats["admitted"] += 1
        self.registry.note_admit(tid)
        return Admitted(tid, pressure, cost=cost)

    def _reject(self, rid: int, tid: str, pressure: float, reason: str,
                now: float, deadline: float) -> Rejected:
        self._status[rid] = "rejected"
        self.retry_queue.remove(rid)
        shed = reason == "overload"
        self.stats["shed" if shed else "rejected"] += 1
        self.registry.note_reject(tid, shed=shed)
        retry_after = max(deadline - now, self.cfg.retry_interval)
        return Rejected(tid, pressure, reason=reason,
                        retry_after=retry_after)

    def cascade(self, handle, now: float) -> Rejected:
        """A multi-turn follow-up whose parent was rejected: the
        conversation cannot continue, so the rejection cascades."""
        req = handle.req
        tenant = self._tenant_of(req)
        deadline = req.arrival + self.cfg.deadline_scale * handle.slo.ttft
        return self._reject(req.rid, tenant.tenant_id, self.last_pressure,
                            "parent_rejected", now, deadline)

    def is_rejected(self, rid: int) -> bool:
        return self._status.get(rid) == "rejected"


def default_registry(n: int, *, flooder: bool = True) -> TenantRegistry:
    """N well-behaved tenants ``t0..t{n-1}`` with tiers cycling through
    interactive/standard/batch (tier-default weights), plus — matching the
    ``tenants`` trace preset — one adversarial ``flood`` tenant declared as
    an ordinary standard-tier client."""
    tiers = ("interactive", "standard", "batch")
    reg = TenantRegistry()
    for i in range(n):
        tier = tiers[i % len(tiers)]
        reg.register(Tenant(f"t{i}", tier=tier, weight=TIER_WEIGHTS[tier]))
    if flooder:
        reg.register(Tenant("flood", tier="standard", weight=1.0))
    return reg


def rejected_state_consistent(handle) -> bool:
    """§8.4-style probe helper for external checkers: a rejected request
    must hold nothing — no placement, no tokens, no KV. (The invariant
    harness applies a stricter version of this check inline.)"""
    req = handle.req
    return (req.state is RequestState.REJECTED
            and req.prefill_instance is None
            and req.decode_instance is None
            and not handle.tokens)
