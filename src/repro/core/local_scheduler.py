"""Local (per-instance) scheduler (§5.4): FCFS KV-migration queue + chunked
prefill continuous batching. Decode requests are packed into the running batch
first; remaining token budget is filled with prefill chunks, so instances in
P→D / D→P pools start serving their new role immediately (no drain stall).
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class PrefillWork:
    rid: int
    input_len: int
    done: int = 0                 # chunked progress (starts at the cached
    #                               prefix length under prefix reuse, §7)
    cached: int = 0               # tokens served from a cached prefix
    tenant: Optional[str] = None  # submitting tenant (§10); None = implicit
    weight: float = 1.0           # tenant share weight for WDRR dispatch
    deflected: bool = False       # cross-pool deflected prefill (§11):
    #                               rate-limited by the deflect_ratio knob

    @property
    def remaining(self) -> int:
        return self.input_len - self.done


@dataclass
class DecodeWork:
    rid: int
    context_len: int              # tokens currently in KV (grows by 1/iter)
    remaining_out: int            # sim ground truth; engine: max-new-tokens


@dataclass
class IterationPlan:
    decode_rids: List[int] = field(default_factory=list)
    prefill_chunks: List[Tuple[int, int, int]] = field(default_factory=list)
    # (rid, chunk_start, chunk_len)

    @property
    def is_empty(self) -> bool:
        return not self.decode_rids and not self.prefill_chunks


class LocalScheduler:
    """One per instance."""

    def __init__(self, iid: int, *, token_budget: int = 8192,
                 max_batch: int = 256, kv_capacity_tokens: int = 1 << 20,
                 mixed_chunk_budget: int = 2048, deflect_ratio: float = 0.0):
        self.iid = iid
        self.token_budget = token_budget       # tokens per iteration batch
        # Sarathi-style: when decode requests share the batch, cap prefill
        # chunk tokens so decode token intervals stay near the TPOT target.
        self.mixed_chunk_budget = mixed_chunk_budget
        # §11 micro-batch ratio knob: max deflected prefill tokens per step
        # = deflect_ratio × mixed_chunk_budget, deficit-tracked so a large
        # deflected prefill drains over several steps instead of starving
        # the host's native work.
        self.deflect_ratio = deflect_ratio
        self._deflect_deficit = 0.0
        self.deflected_chunks = 0          # executed (not merely planned)
        self.deflected_chunk_tokens = 0
        self.max_batch = max_batch
        self.kv_capacity = kv_capacity_tokens
        self.migration_queue: deque = deque()  # FCFS: (rid, kv_tokens)
        self.prefill_queue: "OrderedDict[int, PrefillWork]" = OrderedDict()
        self.decode_running: "OrderedDict[int, DecodeWork]" = OrderedDict()
        # finished requests whose KV is retained as a reusable prefix (§7):
        # rid -> resident kv tokens. Counts toward kv_used, not decode load.
        self.retained: Dict[int, int] = {}
        self.kv_used = 0
        # WDRR deficit counters (§10): tenant -> unspent token allowance,
        # carried across iterations so long-run prefill dispatch converges
        # to the tenants' share weights. Only populated while requests of
        # more than one tenant share the queue.
        self._drr_deficit: Dict[Optional[str], float] = {}

    # ------------------------------------------------------------ enqueues
    def enqueue_prefill(self, rid: int, input_len: int, cached: int = 0,
                        tenant: Optional[str] = None,
                        weight: float = 1.0, deflected: bool = False) -> None:
        """``cached`` prefix tokens come from a retained KV (copy-on-extend)
        — chunking starts at ``cached``, but the request's KV footprint is
        the full ``input_len`` (the copy is its own). ``tenant``/``weight``
        feed the WDRR dispatch order (§10) when several tenants share the
        queue. ``deflected`` marks cross-pool deflected prefill (§11),
        dispatched after native work under the deflect_ratio budget."""
        self.prefill_queue[rid] = PrefillWork(rid, input_len, done=cached,
                                              cached=cached, tenant=tenant,
                                              weight=weight,
                                              deflected=deflected)
        self.kv_used += input_len

    def enqueue_migration(self, rid: int, kv_tokens: int, remaining_out: int) -> None:
        self.migration_queue.append((rid, kv_tokens, remaining_out))

    def admit_migrated(self, rid: int, kv_tokens: int, remaining_out: int) -> None:
        """Migration finished: request joins the decode set."""
        self.decode_running[rid] = DecodeWork(rid, kv_tokens, remaining_out)
        self.kv_used += kv_tokens

    def start_local_decode(self, rid: int, kv_tokens: int, remaining_out: int) -> None:
        """Decode stays on the prefill instance (no transfer): KV already here."""
        self.decode_running[rid] = DecodeWork(rid, kv_tokens, remaining_out)

    # ------------------------------------------------------------- queries
    def has_pending_prefill(self) -> bool:
        return bool(self.prefill_queue)

    def has_pending_decode(self) -> bool:
        return bool(self.decode_running) or bool(self.migration_queue)

    @property
    def running_tokens(self) -> int:
        return sum(w.context_len for w in self.decode_running.values())

    @property
    def prefill_backlog_tokens(self) -> int:
        return sum(w.remaining for w in self.prefill_queue.values())

    def can_accept_migration(self, kv_tokens: int) -> bool:
        return self.kv_used + kv_tokens <= self.kv_capacity

    # ------------------------------------------------------ iteration plan
    def next_migration(self) -> Optional[Tuple[int, int, int]]:
        """FCFS migration admission (§5.4), gated on free KV memory."""
        if not self.migration_queue:
            return None
        rid, kv, rem = self.migration_queue[0]
        if self.kv_used + kv > self.kv_capacity:
            return None               # q2: blocked on memory — unpredictable
        self.migration_queue.popleft()
        return rid, kv, rem

    def plan_iteration(self) -> IterationPlan:
        """Chunked-prefill continuous batching: decode first, then prefill
        chunks up to the token budget (Sarathi-style stall-free batching).

        When requests of more than one tenant share the prefill queue, the
        chunk order runs weighted deficit round-robin across per-tenant
        FIFO groups (§10) — each round a tenant's deficit grows by
        ``mixed_chunk_budget × weight`` and its head-of-line chunks are
        served while the deficit covers them, so a starved tenant's
        head-of-line beats a flooder's backlog at exactly its share ratio.
        With zero or one tenant present the plan is the plain FIFO scan
        (identical to the pre-tenancy scheduler).

        Deflected prefill (§11) never competes with native work: it is
        planned last, from whatever budget remains, and rate-limited to
        ``deflect_ratio × mixed_chunk_budget`` tokens per step through its
        own deficit counter — so deflection composes with (and cannot
        starve) the WDRR tenant queues above."""
        plan = IterationPlan()
        budget = self.token_budget
        slots = self.max_batch
        for rid in self.decode_running:
            if slots == 0 or budget == 0:
                break
            plan.decode_rids.append(rid)
            slots -= 1
            budget -= 1
        if plan.decode_rids:
            budget = min(budget, self.mixed_chunk_budget)

        native = [w for w in self.prefill_queue.values() if not w.deflected]
        deflected = [w for w in self.prefill_queue.values() if w.deflected]

        groups: "OrderedDict[Optional[str], List[PrefillWork]]" = OrderedDict()
        for w in native:
            groups.setdefault(w.tenant, []).append(w)
        if len(groups) <= 1:
            self._drr_deficit.clear()
            for w in native:
                if slots == 0 or budget <= 0:
                    break
                chunk = min(w.remaining, budget)
                if chunk <= 0:
                    continue
                plan.prefill_chunks.append((w.rid, w.done, chunk))
                budget -= chunk
                slots -= 1
            return self._plan_deflected(plan, deflected, budget, slots)

        # ---- WDRR across per-tenant groups (one chunk per rid per plan)
        for t in list(self._drr_deficit):
            if t not in groups:
                del self._drr_deficit[t]       # departed tenant: reset
        heads = {t: 0 for t in groups}
        active = list(groups)
        quantum = self.mixed_chunk_budget
        rounds = 0
        while budget > 0 and slots > 0 and active and rounds < 64:
            rounds += 1
            for t in list(active):
                if budget <= 0 or slots <= 0:
                    break
                wl = groups[t]
                weight = max(wl[0].weight, 1e-3)
                # accrue, capped so an absent-then-returning tenant cannot
                # hoard more than one full iteration's worth of allowance
                self._drr_deficit[t] = min(
                    self._drr_deficit.get(t, 0.0) + quantum * weight,
                    float(max(self.token_budget, quantum)))
                while heads[t] < len(wl) and budget > 0 and slots > 0:
                    w = wl[heads[t]]
                    chunk = min(w.remaining, budget)
                    if chunk <= 0:
                        heads[t] += 1
                        continue
                    if self._drr_deficit[t] < chunk:
                        break              # wait for the next round's quantum
                    plan.prefill_chunks.append((w.rid, w.done, chunk))
                    self._drr_deficit[t] -= chunk
                    budget -= chunk
                    slots -= 1
                    heads[t] += 1
                if heads[t] >= len(wl):
                    active.remove(t)
        return self._plan_deflected(plan, deflected, budget, slots)

    def _plan_deflected(self, plan: IterationPlan,
                        deflected: List[PrefillWork],
                        budget: int, slots: int) -> IterationPlan:
        """§11: fill leftover budget with deflected chunks, at most
        ``deflect_ratio × mixed_chunk_budget`` tokens per step (deficit-
        tracked across steps so a big deflected prefill drains steadily)."""
        if not deflected:
            self._deflect_deficit = 0.0
            return plan
        if self.deflect_ratio <= 0:
            # Deflected work on an unarmed instance (knob lowered after
            # placement): serve it like native work so it cannot hang.
            for w in deflected:
                if slots == 0 or budget <= 0:
                    break
                chunk = min(w.remaining, budget)
                if chunk <= 0:
                    continue
                plan.prefill_chunks.append((w.rid, w.done, chunk))
                budget -= chunk
                slots -= 1
            return plan
        # allowance floor of one token per step: progress is guaranteed even
        # at tiny ratios (an empty plan would never be re-kicked by the sim)
        self._deflect_deficit = min(
            self._deflect_deficit
            + max(1.0, self.deflect_ratio * self.mixed_chunk_budget),
            float(self.mixed_chunk_budget))
        for w in deflected:
            if slots == 0 or budget <= 0:
                break
            chunk = min(w.remaining, budget, int(self._deflect_deficit))
            if chunk <= 0:
                break                  # deficit spent: wait for next step
            plan.prefill_chunks.append((w.rid, w.done, chunk))
            self._deflect_deficit -= chunk
            budget -= chunk
            slots -= 1
        return plan

    # ------------------------------------------------------ state advance
    def complete_prefill_chunk(self, rid: int, chunk_len: int) -> bool:
        """Returns True when the request's prefill is now complete."""
        w = self.prefill_queue[rid]
        w.done += chunk_len
        if w.deflected:
            # counted at completion, not plan time: the engine may plan a
            # chunk, fail slot allocation, and replan — completion is the
            # only point each executed chunk passes exactly once.
            self.deflected_chunks += 1
            self.deflected_chunk_tokens += chunk_len
        if w.remaining <= 0:
            del self.prefill_queue[rid]
            return True
        return False

    def complete_decode_iteration(self, rid: int) -> bool:
        """One token produced. Returns True when the request finished."""
        w = self.decode_running[rid]
        w.context_len += 1
        self.kv_used += 1             # decode grows the KV cache one token/iter
        w.remaining_out -= 1
        if w.remaining_out <= 0:
            self.kv_used -= w.context_len
            del self.decode_running[rid]
            return True
        return False

    def release_prefill_kv(self, rid: int, kv_tokens: int) -> None:
        """KV handed off to another instance (after migration completes)."""
        self.kv_used = max(0, self.kv_used - kv_tokens)

    # ----------------------------------------------- retained prefixes (§7)
    def retain_kv(self, rid: int, kv_tokens: int) -> None:
        """A finished request's KV stays resident as a reusable prefix. The
        decode path already released its tokens from ``kv_used``; re-add
        them under the retained account."""
        self.retained[rid] = kv_tokens
        self.kv_used += kv_tokens

    def release_retained(self, rid: int) -> int:
        """Evict/invalidate a retained prefix; returns the tokens freed."""
        kv = self.retained.pop(rid, 0)
        self.kv_used = max(0, self.kv_used - kv)
        return kv
