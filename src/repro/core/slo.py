"""SLO settings and scheduler tunables."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SLO:
    ttft: float      # seconds
    tpot: float      # seconds per output token


@dataclass(frozen=True)
class SchedulerConfig:
    """Arrow scheduler knobs (§5). Thresholds are expressed against the SLO."""
    ttft_threshold_frac: float = 0.9   # schedule against 0.9×TTFT SLO (headroom)
    tpot_threshold_frac: float = 0.9
    max_running_tokens: int = 65536    # profiled at startup (Max Running Tokens)
    decode_low_load_frac: float = 0.5  # "decode load is low" test in Alg. 1
    monitor_interval: float = 1.0      # seconds between monitor scrapes
    token_interval_window: int = 32    # recent intervals averaged per instance
    idle_prefill_flip: bool = True     # §5.5(3): idle prefill joins decode
    min_prefill_instances: int = 1
    min_decode_instances: int = 1
    # ---- beyond-paper extension (EXPERIMENTS.md §Perf): proactive flipping.
    # The paper flips reactively when a *predicted TTFT violation* already
    # exists (Alg. 1). With burst detection on the arrival process itself
    # (short-window vs long-window request-token rate), capacity moves to
    # prefill one monitor period earlier, before the queue builds.
    proactive: bool = False
    proactive_ratio: float = 2.5       # short-rate > ratio x long-rate => burst
    proactive_window_s: float = 3.0    # short window (long = 10x)
