"""Shared serving runtime both ``ServingSystem`` backends are rebuilt on.

Everything the discrete-event simulator and the real JAX engine used to
duplicate lives here once:

  * policy wiring — pools / monitor / ``POLICIES`` registry / flip counters,
    including the colocated-deployment convention (all instances serve both
    phases, so the prefill pool spans the cluster);
  * request lifecycle glue — prefill dispatch (Algorithm 1), the post-prefill
    decode-placement decision (Algorithm 2) with its local-decode vs
    KV-migration outcome, streaming token delivery, finish accounting;
  * the migration manager — FCFS, memory-gated admission at the destination
    (§5.4), source-side KV release once the transfer lands;
  * monitor-tick stat collection — one ``InstanceStats`` snapshot per
    instance per tick, then the policy's instance-scheduling triggers.

Backends supply the physical substrate through four hooks: ``local_of``
(their per-instance ``LocalScheduler``), ``_begin_transfer`` (async DMA with
a modeled delay in the sim; real array export/import on the engine),
``_release_source_kv`` and ``_decode_started`` (post-migration nudges).

Elastic scaling (DESIGN.md §6) adds the instance lifecycle: ``scale_up``
provisions a new instance (backend hook ``_create_instance`` builds the
substrate and returns its warm-up delay), ``begin_retire`` drains one —
re-dispatching its queued migrations and migrating its KV-resident decode
requests away through the *same* FCFS migration manager — and
``_maybe_finalize_retires`` removes it once drained. An ``AutoScaler``
(core/autoscaler.py) drives these from the monitor tick when the policy is
elastic (``arrow_elastic``).
"""
from __future__ import annotations

import enum
from collections import Counter, deque
from typing import Dict, Optional, Tuple

from repro.core.autoscaler import AutoScaler, AutoScalerConfig
from repro.core.clock import Clock
from repro.core.global_scheduler import NoSchedulableInstance
from repro.core.local_scheduler import LocalScheduler
from repro.core.monitor import InstanceMonitor, InstanceStats
from repro.core.policies import POLICIES
from repro.core.pools import InstancePools, Lifecycle, Pool
from repro.core.prefix_index import (DEFAULT_BLOCK, PrefixCacheManager,
                                     PrefixHit, lineage_keys)
from repro.core.request import Request, RequestState
from repro.core.serving import (FinishCallback, RequestHandle, ServeReport,
                                ServingSystem, TIERS, TokenCallback)
from repro.core.slo import SLO, SchedulerConfig
from repro.core.ttft_predictor import TTFTPredictor


class DecodePlacement(enum.Enum):
    FINISHED = "finished"      # output_len <= 1: request ends at o_1
    LOCAL = "local"            # decode continues on the prefill instance
    MIGRATE = "migrate"        # KV must move to another instance


class RuntimeCore(ServingSystem):
    """Scheduling machinery shared by the simulator and the engine cluster."""

    # ------------------------------------------------------------- wiring
    def _init_runtime(self, ids, *, n_prefill: int, policy: str, slo: SLO,
                      sched_cfg: SchedulerConfig, predictor: TTFTPredictor,
                      clock: Clock,
                      autoscaler_cfg: Optional[AutoScalerConfig] = None,
                      prefix_cache: bool = False,
                      prefix_block: int = DEFAULT_BLOCK,
                      ) -> None:
        ids = list(ids)
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {sorted(POLICIES)}")
        if policy == "colocated":
            n_prefill = len(ids)           # pools unused; all serve both
        self.slo = slo
        self.sched_cfg = sched_cfg
        self.predictor = predictor
        self.clock = clock
        self.pools = InstancePools(ids, n_prefill=n_prefill)
        self.monitor = InstanceMonitor(
            ids, window=sched_cfg.token_interval_window)
        self.policy = POLICIES[policy](self.pools, self.monitor, predictor,
                                       slo, sched_cfg, self)
        self.policy_name = policy
        self.handles: Dict[int, RequestHandle] = {}
        # decision counters: deterministic across backends for a given trace
        # (one prefill dispatch per request, one decode dispatch per request
        # with output_len > 1); migrations additionally depend on timing.
        self.decisions: Dict[str, int] = {
            "prefill": 0, "decode": 0, "migrations": 0}
        # ---- elastic lifecycle state (DESIGN.md §6)
        self._next_iid = max(ids) + 1 if ids else 0
        self._spawned_at: Dict[int, float] = {i: 0.0 for i in ids}
        self._instance_seconds_closed = 0.0
        self._retire_started: Dict[int, float] = {}
        self._migrating_from: Dict[int, int] = {}   # rid -> current KV holder
        self._kv_outbound = Counter()   # iid -> in-flight outbound transfers
        self._kv_inbound = Counter()    # iid -> admitted, not-yet-landed
        self._recent_finish: deque = deque(maxlen=128)  # SLO window
        # ---- deferred dispatch: multi-turn parent gating + the no-ACTIVE-
        # instance queue (both retried through the backend's _arrival_due)
        self._gated: Dict[int, list] = {}       # parent rid -> waiting rids
        self._unplaced: deque = deque()         # rids awaiting any ACTIVE
        # ---- prefix-aware KV reuse (DESIGN.md §7)
        self.prefix_mgr: Optional[PrefixCacheManager] = None
        self._prefix_src: Dict[int, tuple] = {}  # rid -> (iid, src_rid, len)
        # predictor-derived timing totals (the manager owns the token/hit
        # counters — keep each statistic in exactly one place)
        self._prefix_timing = {"saved_prefill_s": 0.0, "full_prefill_s": 0.0,
                               "prefill_tokens": 0.0}
        if prefix_cache:
            self.prefix_mgr = PrefixCacheManager(
                block=prefix_block, release=self._on_prefix_release)
            # a role change drops the instance's cached prefixes (§7):
            # memory belongs to the new duty, and correctness stays trivial
            self.pools.on_flip = \
                lambda iid, frm, to: self.prefix_mgr.invalidate_instance(iid)
        self.autoscaler: Optional[AutoScaler] = None
        if getattr(self.policy, "elastic", False):
            self.autoscaler = AutoScaler(
                self, autoscaler_cfg or AutoScalerConfig())
        elif autoscaler_cfg is not None:
            raise ValueError(
                f"policy {policy!r} is not elastic; autoscaler_cfg requires "
                f"an elastic policy (e.g. 'arrow_elastic')")

    # ------------------------------------------------------ backend hooks
    def local_of(self, iid: int) -> LocalScheduler:
        raise NotImplementedError

    def _begin_transfer(self, rid: int, dst: int, kv: int, rem: int) -> bool:
        """Start moving ``rid``'s KV to ``dst``. Return False when the
        destination cannot take it right now (the item is requeued at the
        front and admission stops — FCFS order is preserved)."""
        raise NotImplementedError

    def _release_source_kv(self, src: int, rid: int, kv: int) -> None:
        raise NotImplementedError

    def _decode_started(self, iid: int) -> None:
        """A request joined ``iid``'s decode set (event-driven backends kick
        the instance; polling backends need nothing)."""

    def _arrival_due(self, rid: int) -> None:
        """Re-deliver a deferred request (gated on its parent, or unplaced
        while no instance was ACTIVE) into the backend's arrival path."""
        raise NotImplementedError

    def _prepare_dispatch(self, handle: RequestHandle, now: float) -> None:
        """Called once per request right before placement, after any parent
        gating has cleared (the engine materializes session prompts here —
        the transcript is only complete once the parent finished)."""

    # ---------------------------------------- prefix-cache backend hooks (§7)
    def _retain_kv(self, iid: int, rid: int, kv_tokens: int) -> bool:
        """Keep ``rid``'s finished KV resident on ``iid`` as a reusable
        prefix. Default: LocalScheduler bookkeeping only (the sim models no
        content); the engine additionally keeps the real slot."""
        self.local_of(iid).retain_kv(rid, kv_tokens)
        return True

    def _release_retained(self, iid: int, rid: int) -> None:
        """Free a retained prefix KV (eviction/invalidation)."""
        self.local_of(iid).release_retained(rid)

    def _on_prefix_release(self, iid: int, rid: int, kv_tokens: int) -> None:
        if iid in self.pools.all_ids():       # instance may be long gone
            self._release_retained(iid, rid)

    # -------------------------------------------------- prefix-key schemes
    def _lookup_keys(self, req: Request):
        """Block keys of ``req``'s prompt for the index lookup, capped so at
        least one token is always recomputed (the last position's logits
        produce o_1). Backends with real prompts override to add content
        keys for session-less requests."""
        if req.session_id is None:
            return None
        return lineage_keys(self._lineage_namespace(req),
                            req.input_len - 1, self.prefix_mgr.block)

    def _retention_keys(self, handle: RequestHandle):
        """Block keys of the *resident* context at finish: the prompt plus
        the generated tokens that entered the KV (the final token never
        does, hence input_len + decoded_tokens)."""
        req = handle.req
        if req.session_id is None:
            return None
        return lineage_keys(self._lineage_namespace(req),
                            req.input_len + req.decoded_tokens,
                            self.prefix_mgr.block)

    def _lineage_namespace(self, req: Request):
        """Namespace for lineage keys; backends that can fork a session
        (engine prompt truncation) override with (session_id, epoch)."""
        return req.session_id

    def _session_note_finish(self, handle: RequestHandle) -> None:
        """Called on every finish, cache on or off (the engine appends the
        generated tokens to the session transcript here)."""

    # ------------------------------------------ elastic backend hooks (§6)
    def _create_instance(self, iid: int) -> float:
        """Provision the physical substrate for a new instance (cost model +
        LocalScheduler on the sim; a real ``EngineInstance`` on the engine).
        Returns the warm-up delay in clock seconds (0 = ready now)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support elastic scaling")

    def _schedule_activation(self, iid: int, delay: float) -> None:
        """Arrange for ``activate_instance(iid)`` after ``delay`` seconds."""
        raise NotImplementedError

    def _destroy_instance(self, iid: int) -> None:
        """Release the substrate of a drained, removed instance."""

    def _instance_ready(self, iid: int) -> None:
        """An instance just became ACTIVE (event-driven backends kick it)."""

    def _instance_quiesced(self, iid: int) -> bool:
        """True when the backend has no in-flight work for ``iid`` beyond
        what the LocalScheduler queues show (sim: no running iteration)."""
        return True

    # --------------------------------------------------------- ClusterView
    def has_pending_prefill(self, iid: int) -> bool:
        return self.local_of(iid).has_pending_prefill()

    def has_pending_decode(self, iid: int) -> bool:
        return self.local_of(iid).has_pending_decode()

    # ---------------------------------------------------- request tracking
    def _register(self, req: Request, tier: str,
                  on_token: Optional[TokenCallback],
                  on_finish: Optional[FinishCallback]) -> RequestHandle:
        if tier not in TIERS:
            raise ValueError(f"unknown SLO tier {tier!r}; "
                             f"choose from {sorted(TIERS)}")
        if req.rid in self.handles:
            raise ValueError(f"rid {req.rid} already submitted")
        handle = RequestHandle(req=req, slo=TIERS[tier].apply(self.slo),
                               tier=tier, on_token=on_token,
                               on_finish=on_finish)
        self.handles[req.rid] = handle
        return handle

    # ----------------------------------------------------- lifecycle glue
    def dispatch_prefill(self, handle: RequestHandle,
                         now: float) -> Optional[int]:
        """Place ``handle``'s prefill (Algorithm 1 + §7 prefix affinity).
        Returns the instance, or None when the request was deferred: a
        multi-turn follow-up whose parent has not finished yet (released in
        ``finish``), or no ACTIVE instance exists (released on the next
        ``activate_instance``)."""
        req = handle.req
        if req.parent_rid is not None:
            parent = self.handles.get(req.parent_rid)
            if parent is not None and not parent.done:
                self._gated.setdefault(req.parent_rid, []).append(req.rid)
                return None
        self._prepare_dispatch(handle, now)
        hits = None
        if self.prefix_mgr is not None:
            hits = self.prefix_mgr.lookup(self._lookup_keys(req))
        try:
            iid, hit = self.policy.place_prefill(req, now, prefix_hits=hits)
        except NoSchedulableInstance:
            self._unplaced.append(req.rid)
            return None
        cached = 0
        if hit is not None and self.prefix_mgr is not None:
            cached = min(hit.cached_len, req.input_len - 1)
            if cached > 0 and iid == hit.iid:
                self.prefix_mgr.record_hit(PrefixHit(hit.iid, hit.rid,
                                                     cached))
                self.prefix_mgr.pin(hit.iid, hit.rid)
                self._prefix_src[req.rid] = (hit.iid, hit.rid, cached)
                req.cached_len = cached
            else:
                cached = 0
        if self.prefix_mgr is not None:
            p = self.predictor
            full = p.predict(req.input_len)
            t = self._prefix_timing
            t["full_prefill_s"] += full
            t["prefill_tokens"] += req.input_len
            if cached:
                t["saved_prefill_s"] += full - p.predict_chunk(
                    cached, req.input_len - cached)
        req.prefill_instance = iid
        req.state = RequestState.PREFILLING
        self.local_of(iid).enqueue_prefill(req.rid, req.input_len,
                                           cached=cached)
        self.decisions["prefill"] += 1
        return iid

    def emit_token(self, handle: RequestHandle, now: float,
                   token: Optional[int] = None, *, first: bool = False) -> None:
        req = handle.req
        if first:
            req.first_token_time = now       # o_1 returned to user
        else:
            req.token_times.append(now)
            req.decoded_tokens += 1
        handle.tokens.append(token)
        if handle.on_token is not None:
            handle.on_token(handle, token, now)

    def finish(self, handle: RequestHandle, now: float) -> None:
        handle.req.finish_time = now
        handle.req.state = RequestState.FINISHED
        self._recent_finish.append(handle.meets_slo())
        self._session_note_finish(handle)
        if self.prefix_mgr is not None:
            self._maybe_retain(handle)
        # release follow-up turns gated on this request (multi-turn): the
        # user cannot send a follow-up before seeing the answer, so the
        # effective arrival is no earlier than the parent's finish.
        for rid in self._gated.pop(handle.req.rid, []):
            child = self.handles[rid]
            child.req.arrival = max(child.req.arrival, now)
            self._arrival_due(rid)
        if handle.on_finish is not None:
            handle.on_finish(handle)

    def _maybe_retain(self, handle: RequestHandle) -> None:
        """Retain the finished request's KV as a reusable prefix (§7) on the
        instance where it is resident — unless that instance is retiring
        (its memory is on the way out) or already gone."""
        req = handle.req
        iid = req.decode_instance if req.decode_instance is not None \
            else req.prefill_instance
        if iid is None or iid not in self.pools.all_ids() or \
                self.pools.lifecycle_of(iid) is Lifecycle.RETIRING:
            return
        keys = self._retention_keys(handle)
        if not keys:
            return
        kv = req.input_len + req.decoded_tokens
        if self._retain_kv(iid, req.rid, kv):
            self.prefix_mgr.retain(iid, req.rid, keys, kv)

    def recent_attainment(self, min_samples: int = 16) -> Optional[float]:
        """SLO attainment over the sliding window of recent finishes; None
        until ``min_samples`` finishes have been observed."""
        if len(self._recent_finish) < min_samples:
            return None
        return sum(self._recent_finish) / len(self._recent_finish)

    def after_prefill(self, handle: RequestHandle, iid: int, now: float,
                      token: Optional[int] = None,
                      ) -> Tuple[DecodePlacement, Optional[int]]:
        """Prefill finished on ``iid``: stream o_1, then place the decode
        phase (Algorithm 2). Returns the placement and, for MIGRATE, the
        target instance whose admission queue now holds the request."""
        req = handle.req
        src = self._prefix_src.pop(req.rid, None)
        if src is not None and self.prefix_mgr is not None:
            # copy-on-extend done (the suffix is computed): unpin the source
            self.prefix_mgr.unpin(src[0], src[1])
        self.emit_token(handle, now, token, first=True)
        if req.output_len <= 1:
            self.finish(handle, now)
            return DecodePlacement.FINISHED, None
        target = self.policy.schedule_decode_req(req, now)
        self.decisions["decode"] += 1
        req.decode_instance = target
        remaining = req.output_len - 1
        if target == iid:
            req.state = RequestState.DECODING
            self.local_of(iid).start_local_decode(
                req.rid, req.input_len, remaining)
            return DecodePlacement.LOCAL, iid
        req.state = RequestState.MIGRATING
        self._kv_outbound[iid] += 1
        self.local_of(target).enqueue_migration(
            req.rid, req.input_len, remaining)
        self.decisions["migrations"] += 1
        return DecodePlacement.MIGRATE, target

    # -------------------------------------------------- migration manager
    def admit_migrations(self, iid: int) -> None:
        """FCFS, memory-gated admission (§5.4) at destination ``iid``; the
        backend's ``_begin_transfer`` performs/schedules the data movement."""
        loc = self.local_of(iid)
        while True:
            item = loc.next_migration()
            if item is None:
                # memory-blocked head: cached prefixes are the first thing
                # to go (§7 — reclaimable capacity, LRU, unpinned only)
                if self.prefix_mgr is not None and loc.migration_queue:
                    need = loc.kv_used + loc.migration_queue[0][1] \
                        - loc.kv_capacity
                    if need > 0 and \
                            self.prefix_mgr.make_room(iid, need) > 0:
                        continue
                return
            rid, kv, rem = item
            if rid not in self.handles:        # stale entry: drop it
                continue
            # count the transfer as inbound before starting it: async
            # backends land it later, and a retiring destination must not
            # finalize while data is in the air (the engine's synchronous
            # path completes inside _begin_transfer, netting back to zero).
            self._kv_inbound[iid] += 1
            if not self._begin_transfer(rid, iid, kv, rem):
                self._kv_inbound[iid] -= 1
                loc.migration_queue.appendleft((rid, kv, rem))
                return

    def _kv_source(self, rid: int) -> Optional[int]:
        """Instance currently holding ``rid``'s KV: its prefill instance, or
        — for retire-triggered re-migrations — the retiring decode holder."""
        return self._migrating_from.get(
            rid, self.handles[rid].req.prefill_instance)

    def complete_migration(self, rid: int, dst: int, kv: int, rem: int,
                           now: float) -> None:
        """KV landed on ``dst``: release it at the source, join the decode
        set. (``now`` kept for symmetry/overrides; completion itself is not a
        scheduling decision.)"""
        req = self.handles[rid].req
        src = self._kv_source(rid)
        self._migrating_from.pop(rid, None)
        if src is not None and src != dst:
            self._release_source_kv(src, rid, kv)
        if src is not None and self._kv_outbound[src] > 0:
            self._kv_outbound[src] -= 1
        if self._kv_inbound[dst] > 0:
            self._kv_inbound[dst] -= 1
        self.local_of(dst).admit_migrated(rid, kv, rem)
        req.state = RequestState.DECODING
        req.decode_instance = dst
        self._decode_started(dst)

    # ----------------------------------- instance lifecycle (DESIGN.md §6)
    def scale_up(self, pool: Pool, now: float) -> int:
        """Provision one new instance into ``pool``. It joins WARMING when the
        backend models a spawn delay, ACTIVE immediately otherwise."""
        iid = self._next_iid
        self._next_iid += 1
        delay = self._create_instance(iid)
        self.pools.add_instance(iid, pool, warming=delay > 0)
        self.monitor.add_instance(iid)
        self.policy.on_instance_added(iid)
        self._spawned_at[iid] = now
        if delay > 0:
            self._schedule_activation(iid, delay)
        else:
            self._instance_ready(iid)
        return iid

    def activate_instance(self, iid: int) -> None:
        """Warm-up finished: the instance becomes schedulable. Requests that
        found no ACTIVE instance at dispatch time retry now."""
        self.pools.activate(iid)
        self._instance_ready(iid)
        while self._unplaced:
            self._arrival_due(self._unplaced.popleft())

    def begin_retire(self, iid: int, now: float) -> None:
        """ACTIVE → RETIRING: the instance accepts no new work. Its queued
        inbound migrations are re-dispatched and its KV-resident decode
        requests are migrated away through the existing FCFS migration
        manager; prefill work it already holds drains in place. Removal
        happens in ``_maybe_finalize_retires`` once everything left."""
        self.pools.begin_retire(iid)
        self._retire_started[iid] = now
        if self.prefix_mgr is not None:
            # cached prefixes are disposable state: invalidate (free) rather
            # than migrate — pinned entries (a copy-on-extend in flight on
            # this very instance) are doomed and freed on the last unpin
            self.prefix_mgr.invalidate_instance(iid)
        loc = self.local_of(iid)
        # queued (never-admitted) inbound migrations: KV is still elsewhere,
        # only the queue entry moves to a new destination.
        redispatch = []
        while loc.migration_queue:
            redispatch.append(loc.migration_queue.popleft())
        # KV-resident decode requests: migrate away (source KV stays resident
        # until the transfer lands, exactly like a post-prefill migration).
        for rid in list(loc.decode_running):
            w = loc.decode_running.pop(rid)
            req = self.handles[rid].req
            req.state = RequestState.MIGRATING
            self._migrating_from[rid] = iid
            self._kv_outbound[iid] += 1
            self.decisions["migrations"] += 1
            redispatch.append((rid, w.context_len, w.remaining_out))
        targets = set()
        evac_load = Counter()      # tentative KV per target within this batch
        for rid, kv, rem in redispatch:
            req = self.handles[rid].req
            dst = self._evacuation_target(kv, evac_load)
            src = self._kv_source(rid)
            if dst == src:
                # the chosen destination already holds the KV (a queued-at-
                # `iid` migration whose source is now the best target): no
                # transfer — resume decode in place, like a LOCAL placement.
                if self._kv_outbound[src] > 0:
                    self._kv_outbound[src] -= 1
                req.decode_instance = src
                req.state = RequestState.DECODING
                self.local_of(src).start_local_decode(rid, kv, rem)
                self._decode_started(src)
                continue
            req.decode_instance = dst
            self.local_of(dst).enqueue_migration(rid, kv, rem)
            targets.add(dst)
        for dst in targets:
            self.admit_migrations(dst)

    def _evacuation_target(self, kv: int, evac_load: Counter) -> int:
        """Destination for work leaving a retiring instance: the least-loaded
        ACTIVE decode-capable instance (any active instance as last resort).
        ``evac_load`` holds KV already routed within the current evacuation
        batch — monitor stats are tick-stale, so without it every request
        would pile onto the same pre-batch minimum."""
        ids = self.pools.decode_capable() or self.pools.active_ids()
        if not ids:
            raise RuntimeError("no active instance to evacuate to")
        dst = min(ids, key=lambda i: (self.monitor.get(i).running_tokens
                                      + evac_load[i]))
        evac_load[dst] += kv
        return dst

    def _retire_drained(self, iid: int) -> bool:
        loc = self.local_of(iid)
        return (not loc.has_pending_prefill()
                and not loc.has_pending_decode()
                and self._kv_outbound[iid] == 0
                and self._kv_inbound[iid] == 0
                and self._instance_quiesced(iid))

    def _maybe_finalize_retires(self, now: float) -> None:
        for iid in list(self._retire_started):
            if not self._retire_drained(iid):
                continue
            self._retire_started.pop(iid)
            self.pools.remove_instance(iid)
            self.monitor.remove_instance(iid)
            self.policy.on_instance_removed(iid)
            self._instance_seconds_closed += now - self._spawned_at.pop(iid)
            self._kv_outbound.pop(iid, None)
            self._kv_inbound.pop(iid, None)
            self._destroy_instance(iid)

    def instance_seconds(self, now: float) -> float:
        """Σ per-instance alive time — the provisioning cost a static
        deployment pays for its full duration."""
        return self._instance_seconds_closed + \
            sum(now - t for t in self._spawned_at.values())

    # ------------------------------------------------ monitor-tick scrape
    def collect_stats(self, now: float) -> None:
        ready = getattr(self.policy, "prefill_ready_at", {})
        for iid in self.pools.all_ids():
            loc = self.local_of(iid)
            self.monitor.update_stats(InstanceStats(
                instance_id=iid,
                prefill_queue_len=len(loc.prefill_queue),
                prefill_backlog_tokens=loc.prefill_backlog_tokens,
                prefill_ready_at=ready.get(iid, 0.0),
                running_tokens=loc.running_tokens,
                n_decode_running=len(loc.decode_running),
                kv_tokens_used=loc.kv_used,
                kv_tokens_capacity=loc.kv_capacity,
            ))
        self.policy.on_monitor_tick(now)
        if self.autoscaler is not None:
            self.autoscaler.on_monitor_tick(now)
        self._maybe_finalize_retires(now)

    # ------------------------------------------------ pool-flip accounting
    def flip_counts(self) -> Dict[str, int]:
        return {
            "total": self.pools.flips,
            "d2p": getattr(self.policy, "n_d2p_flips", 0),
            "p2d": getattr(self.policy, "n_p2d_flips", 0),
            "proactive": getattr(self.policy, "n_proactive_flips", 0),
        }

    # ----------------------------------------------------------- reporting
    def scaling_detail(self) -> Dict[str, float]:
        now = self.clock.now()
        out = {"instance_seconds": self.instance_seconds(now),
               "n_instances": len(self.pools.all_ids())}
        if self.autoscaler is not None:
            out["scale_ups"] = self.autoscaler.n_scale_ups
            out["scale_downs"] = self.autoscaler.n_scale_downs
        return out

    def prefix_detail(self) -> Dict[str, float]:
        """Prefix-cache effectiveness (§7); empty when the cache is off."""
        if self.prefix_mgr is None:
            return {}
        out = dict(self.prefix_mgr.stats)
        out.update(self._prefix_timing)
        full = out["full_prefill_s"]
        out["saved_prefill_frac"] = \
            out["saved_prefill_s"] / full if full > 0 else 0.0
        return out

    def report(self) -> ServeReport:
        return ServeReport(handles=list(self.handles.values()),
                           flip_detail=self.flip_counts(),
                           decisions=dict(self.decisions),
                           duration=self.clock.now(),
                           scaling=self.scaling_detail(),
                           prefix=self.prefix_detail())
