"""Shared serving runtime both ``ServingSystem`` backends are rebuilt on.

Everything the discrete-event simulator and the real JAX engine used to
duplicate lives here once:

  * policy wiring — pools / monitor / ``POLICIES`` registry / flip counters,
    including the colocated-deployment convention (all instances serve both
    phases, so the prefill pool spans the cluster);
  * request lifecycle glue — prefill dispatch (Algorithm 1), the post-prefill
    decode-placement decision (Algorithm 2) with its local-decode vs
    KV-migration outcome, streaming token delivery, finish accounting;
  * the migration manager — FCFS, memory-gated admission at the destination
    (§5.4), source-side KV release once the transfer lands;
  * monitor-tick stat collection — one ``InstanceStats`` snapshot per
    instance per tick, then the policy's instance-scheduling triggers.

Backends supply the physical substrate through four hooks: ``local_of``
(their per-instance ``LocalScheduler``), ``_begin_transfer`` (async DMA with
a modeled delay in the sim; real array export/import on the engine),
``_release_source_kv`` and ``_decode_started`` (post-migration nudges).
"""
from __future__ import annotations

import enum
from typing import Dict, Optional, Tuple

from repro.core.clock import Clock
from repro.core.local_scheduler import LocalScheduler
from repro.core.monitor import InstanceMonitor, InstanceStats
from repro.core.policies import POLICIES
from repro.core.pools import InstancePools
from repro.core.request import Request, RequestState
from repro.core.serving import (FinishCallback, RequestHandle, ServeReport,
                                ServingSystem, TIERS, TokenCallback)
from repro.core.slo import SLO, SchedulerConfig
from repro.core.ttft_predictor import TTFTPredictor


class DecodePlacement(enum.Enum):
    FINISHED = "finished"      # output_len <= 1: request ends at o_1
    LOCAL = "local"            # decode continues on the prefill instance
    MIGRATE = "migrate"        # KV must move to another instance


class RuntimeCore(ServingSystem):
    """Scheduling machinery shared by the simulator and the engine cluster."""

    # ------------------------------------------------------------- wiring
    def _init_runtime(self, ids, *, n_prefill: int, policy: str, slo: SLO,
                      sched_cfg: SchedulerConfig, predictor: TTFTPredictor,
                      clock: Clock) -> None:
        ids = list(ids)
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {sorted(POLICIES)}")
        if policy == "colocated":
            n_prefill = len(ids)           # pools unused; all serve both
        self.slo = slo
        self.sched_cfg = sched_cfg
        self.predictor = predictor
        self.clock = clock
        self.pools = InstancePools(ids, n_prefill=n_prefill)
        self.monitor = InstanceMonitor(
            ids, window=sched_cfg.token_interval_window)
        self.policy = POLICIES[policy](self.pools, self.monitor, predictor,
                                       slo, sched_cfg, self)
        self.policy_name = policy
        self.handles: Dict[int, RequestHandle] = {}
        # decision counters: deterministic across backends for a given trace
        # (one prefill dispatch per request, one decode dispatch per request
        # with output_len > 1); migrations additionally depend on timing.
        self.decisions: Dict[str, int] = {
            "prefill": 0, "decode": 0, "migrations": 0}

    # ------------------------------------------------------ backend hooks
    def local_of(self, iid: int) -> LocalScheduler:
        raise NotImplementedError

    def _begin_transfer(self, rid: int, dst: int, kv: int, rem: int) -> bool:
        """Start moving ``rid``'s KV to ``dst``. Return False when the
        destination cannot take it right now (the item is requeued at the
        front and admission stops — FCFS order is preserved)."""
        raise NotImplementedError

    def _release_source_kv(self, src: int, rid: int, kv: int) -> None:
        raise NotImplementedError

    def _decode_started(self, iid: int) -> None:
        """A request joined ``iid``'s decode set (event-driven backends kick
        the instance; polling backends need nothing)."""

    # --------------------------------------------------------- ClusterView
    def has_pending_prefill(self, iid: int) -> bool:
        return self.local_of(iid).has_pending_prefill()

    def has_pending_decode(self, iid: int) -> bool:
        return self.local_of(iid).has_pending_decode()

    # ---------------------------------------------------- request tracking
    def _register(self, req: Request, tier: str,
                  on_token: Optional[TokenCallback],
                  on_finish: Optional[FinishCallback]) -> RequestHandle:
        if tier not in TIERS:
            raise ValueError(f"unknown SLO tier {tier!r}; "
                             f"choose from {sorted(TIERS)}")
        if req.rid in self.handles:
            raise ValueError(f"rid {req.rid} already submitted")
        handle = RequestHandle(req=req, slo=TIERS[tier].apply(self.slo),
                               tier=tier, on_token=on_token,
                               on_finish=on_finish)
        self.handles[req.rid] = handle
        return handle

    # ----------------------------------------------------- lifecycle glue
    def dispatch_prefill(self, handle: RequestHandle, now: float) -> int:
        req = handle.req
        iid = self.policy.schedule_prefill_req(req, now)
        req.prefill_instance = iid
        req.state = RequestState.PREFILLING
        self.local_of(iid).enqueue_prefill(req.rid, req.input_len)
        self.decisions["prefill"] += 1
        return iid

    def emit_token(self, handle: RequestHandle, now: float,
                   token: Optional[int] = None, *, first: bool = False) -> None:
        req = handle.req
        if first:
            req.first_token_time = now       # o_1 returned to user
        else:
            req.token_times.append(now)
            req.decoded_tokens += 1
        handle.tokens.append(token)
        if handle.on_token is not None:
            handle.on_token(handle, token, now)

    def finish(self, handle: RequestHandle, now: float) -> None:
        handle.req.finish_time = now
        handle.req.state = RequestState.FINISHED
        if handle.on_finish is not None:
            handle.on_finish(handle)

    def after_prefill(self, handle: RequestHandle, iid: int, now: float,
                      token: Optional[int] = None,
                      ) -> Tuple[DecodePlacement, Optional[int]]:
        """Prefill finished on ``iid``: stream o_1, then place the decode
        phase (Algorithm 2). Returns the placement and, for MIGRATE, the
        target instance whose admission queue now holds the request."""
        req = handle.req
        self.emit_token(handle, now, token, first=True)
        if req.output_len <= 1:
            self.finish(handle, now)
            return DecodePlacement.FINISHED, None
        target = self.policy.schedule_decode_req(req, now)
        self.decisions["decode"] += 1
        req.decode_instance = target
        remaining = req.output_len - 1
        if target == iid:
            req.state = RequestState.DECODING
            self.local_of(iid).start_local_decode(
                req.rid, req.input_len, remaining)
            return DecodePlacement.LOCAL, iid
        req.state = RequestState.MIGRATING
        self.local_of(target).enqueue_migration(
            req.rid, req.input_len, remaining)
        self.decisions["migrations"] += 1
        return DecodePlacement.MIGRATE, target

    # -------------------------------------------------- migration manager
    def admit_migrations(self, iid: int) -> None:
        """FCFS, memory-gated admission (§5.4) at destination ``iid``; the
        backend's ``_begin_transfer`` performs/schedules the data movement."""
        loc = self.local_of(iid)
        while True:
            item = loc.next_migration()
            if item is None:
                return
            rid, kv, rem = item
            if rid not in self.handles:        # stale entry: drop it
                continue
            if not self._begin_transfer(rid, iid, kv, rem):
                loc.migration_queue.appendleft((rid, kv, rem))
                return

    def complete_migration(self, rid: int, dst: int, kv: int, rem: int,
                           now: float) -> None:
        """KV landed on ``dst``: release it at the source, join the decode
        set. (``now`` kept for symmetry/overrides; completion itself is not a
        scheduling decision.)"""
        req = self.handles[rid].req
        src = req.prefill_instance
        if src is not None and src != dst:
            self._release_source_kv(src, rid, kv)
        self.local_of(dst).admit_migrated(rid, kv, rem)
        req.state = RequestState.DECODING
        self._decode_started(dst)

    # ------------------------------------------------ monitor-tick scrape
    def collect_stats(self, now: float) -> None:
        ready = getattr(self.policy, "prefill_ready_at", {})
        for iid in self.pools.all_ids():
            loc = self.local_of(iid)
            self.monitor.update_stats(InstanceStats(
                instance_id=iid,
                prefill_queue_len=len(loc.prefill_queue),
                prefill_backlog_tokens=loc.prefill_backlog_tokens,
                prefill_ready_at=ready.get(iid, 0.0),
                running_tokens=loc.running_tokens,
                n_decode_running=len(loc.decode_running),
                kv_tokens_used=loc.kv_used,
                kv_tokens_capacity=loc.kv_capacity,
            ))
        self.policy.on_monitor_tick(now)

    # ------------------------------------------------ pool-flip accounting
    def flip_counts(self) -> Dict[str, int]:
        return {
            "total": self.pools.flips,
            "d2p": getattr(self.policy, "n_d2p_flips", 0),
            "p2d": getattr(self.policy, "n_p2d_flips", 0),
            "proactive": getattr(self.policy, "n_proactive_flips", 0),
        }

    # ----------------------------------------------------------- reporting
    def report(self) -> ServeReport:
        return ServeReport(handles=list(self.handles.values()),
                           flip_detail=self.flip_counts(),
                           decisions=dict(self.decisions),
                           duration=self.clock.now())
