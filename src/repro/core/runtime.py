"""Shared serving runtime both ``ServingSystem`` backends are rebuilt on.

Everything the discrete-event simulator and the real JAX engine used to
duplicate lives here once:

  * policy wiring — pools / monitor / ``POLICIES`` registry / flip counters,
    including the colocated-deployment convention (all instances serve both
    phases, so the prefill pool spans the cluster);
  * request lifecycle glue — prefill dispatch (Algorithm 1), the post-prefill
    decode-placement decision (Algorithm 2) with its local-decode vs
    KV-migration outcome, streaming token delivery, finish accounting;
  * the migration manager — FCFS, memory-gated admission at the destination
    (§5.4), source-side KV release once the transfer lands;
  * monitor-tick stat collection — one ``InstanceStats`` snapshot per
    instance per tick, then the policy's instance-scheduling triggers.

Backends supply the physical substrate through four hooks: ``local_of``
(their per-instance ``LocalScheduler``), ``_begin_transfer`` (async DMA with
a modeled delay in the sim; real array export/import on the engine),
``_release_source_kv`` and ``_decode_started`` (post-migration nudges).

Elastic scaling (DESIGN.md §6) adds the instance lifecycle: ``scale_up``
provisions a new instance (backend hook ``_create_instance`` builds the
substrate and returns its warm-up delay), ``begin_retire`` drains one —
re-dispatching its queued migrations and migrating its KV-resident decode
requests away through the *same* FCFS migration manager — and
``_maybe_finalize_retires`` removes it once drained. An ``AutoScaler``
(core/autoscaler.py) drives these from the monitor tick when the policy is
elastic (``arrow_elastic``).

Fault tolerance (DESIGN.md §8) adds the crash path: ``fail_instance``
tears an instance down *without* a drain — its resident KV is gone, so the
runtime invalidates its prefix-index entries, aborts every migration
touching it, re-routes migrations whose KV survives elsewhere, and
re-dispatches the lost requests (decode-phase victims re-prefill prompt +
already-streamed tokens so recovered greedy streams stay token-identical).
A ``FaultInjector`` (core/faults.py) fires scripted crash/slowdown events;
the AutoScaler spawns replacements when the policy is elastic.
"""
from __future__ import annotations

import enum
import random
from collections import Counter, deque
from typing import Dict, List, Optional, Tuple

from repro.core.autoscaler import AutoScaler, AutoScalerConfig
from repro.core.clock import Clock
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.health import HealthConfig, HealthMonitor
from repro.core.global_scheduler import (DeflectionConfig, DeflectionPolicy,
                                         NoSchedulableInstance)
from repro.core.local_scheduler import LocalScheduler
from repro.core.monitor import InstanceMonitor, InstanceStats
from repro.core.policies import POLICIES
from repro.core.pools import InstancePools, Lifecycle, Pool
from repro.core.prefix_index import (DEFAULT_BLOCK, PrefixCacheManager,
                                     PrefixHit, lineage_keys)
from repro.core.request import Request, RequestState
from repro.core.serving import (FinishCallback, RequestHandle, ServeReport,
                                ServingSystem, TIERS, TokenCallback,
                                UndispatchableError)
from repro.core.slo import SLO, SchedulerConfig
from repro.core.tenants import (DEFAULT_TENANT, AdmissionConfig,
                                AdmissionController, Deferred, Rejected,
                                TenantRegistry)
from repro.core.ttft_predictor import TTFTPredictor


class DecodePlacement(enum.Enum):
    FINISHED = "finished"      # output_len <= 1: request ends at o_1
    LOCAL = "local"            # decode continues on the prefill instance
    MIGRATE = "migrate"        # KV must move to another instance


class RuntimeCore(ServingSystem):
    """Scheduling machinery shared by the simulator and the engine cluster."""

    # ------------------------------------------------------------- wiring
    def _init_runtime(self, ids, *, n_prefill: int, policy: str, slo: SLO,
                      sched_cfg: SchedulerConfig, predictor: TTFTPredictor,
                      clock: Clock,
                      autoscaler_cfg: Optional[AutoScalerConfig] = None,
                      prefix_cache: bool = False,
                      prefix_block: int = DEFAULT_BLOCK,
                      fault_plan: Optional[FaultPlan] = None,
                      tenants: Optional[TenantRegistry] = None,
                      admission=False,
                      deflection: Optional[DeflectionConfig] = None,
                      run_seed: int = 0,
                      prefix_reuse: str = "block",
                      health=False,
                      ) -> None:
        ids = list(ids)
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {sorted(POLICIES)}")
        if policy == "colocated":
            n_prefill = len(ids)           # pools unused; all serve both
        self.slo = slo
        self.sched_cfg = sched_cfg
        self.predictor = predictor
        self.clock = clock
        self.pools = InstancePools(ids, n_prefill=n_prefill)
        self.monitor = InstanceMonitor(
            ids, window=sched_cfg.token_interval_window)
        self.policy = POLICIES[policy](self.pools, self.monitor, predictor,
                                       slo, sched_cfg, self)
        self.policy_name = policy
        self.handles: Dict[int, RequestHandle] = {}
        # decision counters: deterministic across backends for a given trace
        # (one prefill dispatch per request, one decode dispatch per request
        # with output_len > 1); migrations additionally depend on timing.
        self.decisions: Dict[str, int] = {
            "prefill": 0, "decode": 0, "migrations": 0}
        # ---- elastic lifecycle state (DESIGN.md §6)
        self._next_iid = max(ids) + 1 if ids else 0
        self._spawned_at: Dict[int, float] = {i: 0.0 for i in ids}
        self._instance_seconds_closed = 0.0
        self._retire_started: Dict[int, float] = {}
        self._migrating_from: Dict[int, int] = {}   # rid -> current KV holder
        self._kv_outbound = Counter()   # iid -> in-flight outbound transfers
        self._kv_inbound = Counter()    # iid -> admitted, not-yet-landed
        # per-rid migration bookkeeping so a crash can find and abort every
        # transfer touching the dead instance (DESIGN.md §8):
        self._transfers: Dict[int, Tuple[int, int, int]] = {}  # rid->(s,d,kv)
        self._migration_kv: Dict[int, int] = {}     # rid -> kv while MIGRATING
        # completed transfers with their real wire size (DESIGN.md §13):
        # dense KV grows with context; constant-state families move O(1)
        # bytes regardless of context length. Diagnostic only — not a
        # ServeReport summary field.
        self.migration_log: List[Dict[str, int]] = []
        self._recent_finish: deque = deque(maxlen=128)  # SLO window
        # ---- replayable sampling + self-speculative decoding (§12)
        self.run_seed = run_seed
        self._sampling_stats: Dict[str, float] = {"sampled_requests": 0}
        self._spec_stats: Dict[str, float] = {
            "rounds": 0, "drafted": 0, "accepted": 0, "emitted": 0}
        # ---- fault domain (DESIGN.md §8)
        self.fault_stats: Dict[str, float] = {
            "crashes": 0, "slowdowns": 0, "skipped_events": 0,
            "requests_recovered": 0, "requests_lost": 0,
            "kv_tokens_lost": 0, "re_prefill_tokens": 0,
            "migrations_aborted": 0, "replacements": 0}
        self._slowdowns: Dict[int, Tuple[float, float]] = {}  # iid->(f,until)
        self._failed_pending: Dict[int, float] = {}  # iid -> crash time
        self.fault_injector: Optional[FaultInjector] = None
        if fault_plan is not None:
            # backends arm the firing (sim: exact virtual-clock events;
            # engine: polled every cooperative pass)
            self.fault_injector = FaultInjector(fault_plan, self)
        # ---- self-healing layer (DESIGN.md §14)
        self.health_cfg: Optional[HealthConfig] = None
        self.health_monitor: Optional[HealthMonitor] = None
        if health:
            self.health_cfg = health if isinstance(health, HealthConfig) \
                else HealthConfig()
            self.health_monitor = HealthMonitor(self, self.health_cfg)
        self.health_stats: Dict[str, float] = {
            "quarantines": 0, "restores": 0, "escalations": 0,
            "xfer_retries": 0, "xfer_drops": 0, "xfer_corrupt": 0,
            "xfer_failures": 0, "preemptions": 0, "preempt_refused": 0}
        # transient transfer-fault windows (droptransfer/netslow, §14) —
        # cluster-wide, self-expiring like _slowdowns
        self._xfer_drop: Optional[Tuple[float, float]] = None  # (p, until)
        self._netslow: Optional[Tuple[float, float]] = None    # (f, until)
        # dedicated RNG for drop decisions: drawn only while a window is
        # active, so fault-free runs never consume it (replayability)
        self._xfer_rng = random.Random(run_seed + 0x7EA1)
        self._xfer_attempts: Dict[int, int] = {}   # rid -> failed attempts
        self._preempt_log: Dict[int, deque] = {}   # iid -> recent preempt ts
        # ---- deferred dispatch: multi-turn parent gating + the no-ACTIVE-
        # instance queue (both retried through the backend's _arrival_due)
        self._gated: Dict[int, list] = {}       # parent rid -> waiting rids
        self._unplaced: deque = deque()         # rids awaiting any ACTIVE
        # ---- prefix-aware KV reuse (DESIGN.md §7; §13 for the capability)
        # "block": per-token KV — any block-aligned prefix is reusable.
        # "exact": constant-size recurrent state — only a full-stream match
        # (the state is a lossy summary with no per-position truncation).
        self.prefix_reuse = prefix_reuse
        self.prefix_mgr: Optional[PrefixCacheManager] = None
        self._prefix_src: Dict[int, tuple] = {}  # rid -> (iid, src_rid, len)
        # predictor-derived timing totals (the manager owns the token/hit
        # counters — keep each statistic in exactly one place)
        self._prefix_timing = {"saved_prefill_s": 0.0, "full_prefill_s": 0.0,
                               "prefill_tokens": 0.0}
        if prefix_cache:
            self.prefix_mgr = PrefixCacheManager(
                block=prefix_block, release=self._on_prefix_release)
            # a role change drops the instance's cached prefixes (§7):
            # memory belongs to the new duty, and correctness stays trivial
            self.pools.on_flip = \
                lambda iid, frm, to: self.prefix_mgr.invalidate_instance(iid)
        # ---- multi-tenancy + admission control (DESIGN.md §10)
        self.tenants: Optional[TenantRegistry] = tenants
        self.admission_ctl: Optional[AdmissionController] = None
        if admission:
            if self.tenants is None:
                self.tenants = TenantRegistry()   # auto-registering roster
            cfg = admission if isinstance(admission, AdmissionConfig) \
                else AdmissionConfig()
            self.admission_ctl = AdmissionController(self, self.tenants, cfg)
        self.autoscaler: Optional[AutoScaler] = None
        if getattr(self.policy, "elastic", False):
            self.autoscaler = AutoScaler(
                self, autoscaler_cfg or AutoScalerConfig())
        elif autoscaler_cfg is not None:
            raise ValueError(
                f"policy {policy!r} is not elastic; autoscaler_cfg requires "
                f"an elastic policy (e.g. 'arrow_elastic')")
        # ---- cross-pool prefill deflection (DESIGN.md §11)
        self.deflection_cfg: Optional[DeflectionConfig] = None
        self._deflect_closed = {"chunks": 0, "tokens": 0}  # dead instances
        if getattr(self.policy, "deflective", False):
            self.deflection_cfg = deflection or DeflectionConfig()
            self.policy.deflection = DeflectionPolicy(self.deflection_cfg)
        elif deflection is not None:
            raise ValueError(
                f"policy {policy!r} is not deflective; deflection requires "
                f"a deflective policy (e.g. 'arrow_deflect')")

    # ------------------------------------------------------ backend hooks
    def local_of(self, iid: int) -> LocalScheduler:
        raise NotImplementedError

    def _begin_transfer(self, rid: int, dst: int, kv: int, rem: int) -> bool:
        """Start moving ``rid``'s KV to ``dst``. Return False when the
        destination cannot take it right now (the item is requeued at the
        front and admission stops — FCFS order is preserved)."""
        raise NotImplementedError

    def _release_source_kv(self, src: int, rid: int, kv: int) -> None:
        raise NotImplementedError

    def _decode_started(self, iid: int) -> None:
        """A request joined ``iid``'s decode set (event-driven backends kick
        the instance; polling backends need nothing)."""

    def _arrival_due(self, rid: int) -> None:
        """Re-deliver a deferred request (gated on its parent, or unplaced
        while no instance was ACTIVE) into the backend's arrival path."""
        raise NotImplementedError

    def _schedule_retry(self, rid: int, at: float) -> None:
        """Admission deferred ``rid`` (§10): re-deliver it into the arrival
        path at system-clock time ``at`` — strictly later than now, unlike
        ``_arrival_due`` which re-delivers immediately."""
        raise NotImplementedError

    def _request_rejected(self, rid: int) -> None:
        """Admission rejected ``rid`` for good (§10): drop any backend-side
        bookkeeping (the engine pops its synthesized prompt; the sim holds
        nothing). The request never entered scheduling or KV accounting."""

    def _prepare_dispatch(self, handle: RequestHandle, now: float) -> None:
        """Called once per request right before placement, after any parent
        gating has cleared (the engine materializes session prompts here —
        the transcript is only complete once the parent finished)."""

    # ------------------------------------------------ fault backend hooks (§8)
    def _abort_transfer(self, rid: int, dst: int, kv: int) -> None:
        """A migration in flight toward ``dst`` was aborted by a crash: undo
        whatever the backend reserved in ``_begin_transfer`` and drop the
        pending completion (sim: stale-token the heap event; engine:
        transfers are synchronous, nothing is ever in flight)."""

    def _on_instance_failed(self, iid: int) -> None:
        """Crash teardown of the physical substrate (sim: cancel the running
        iteration; engine: drop the real ``EngineInstance`` and its slots).
        Called after the runtime inventoried the lost work."""

    def _prepare_recovery(self, handle: RequestHandle) -> None:
        """A decode-phase request lost its KV: extend the backend's notion of
        its prompt with the already-streamed tokens minus the last (the
        engine rebuilds the actual token array; the sim models no content).
        Called before the runtime updates the request's bookkeeping."""

    def _request_lost(self, rid: int) -> None:
        """No-recovery strawman: the request is stranded for good — drop it
        from the backend's live set so ``drain()`` terminates."""

    # ---------------------------------------- prefix-cache backend hooks (§7)
    def _retain_kv(self, iid: int, rid: int, kv_tokens: int) -> bool:
        """Keep ``rid``'s finished KV resident on ``iid`` as a reusable
        prefix. Default: LocalScheduler bookkeeping only (the sim models no
        content); the engine additionally keeps the real slot."""
        self.local_of(iid).retain_kv(rid, kv_tokens)
        return True

    def _release_retained(self, iid: int, rid: int) -> None:
        """Free a retained prefix KV (eviction/invalidation)."""
        self.local_of(iid).release_retained(rid)

    def _on_prefix_release(self, iid: int, rid: int, kv_tokens: int) -> None:
        # the instance may be long gone, or a FAILED corpse whose substrate
        # (and with it the retained KV) no longer exists (§8)
        if iid in self.pools.all_ids() and \
                self.pools.lifecycle_of(iid) is not Lifecycle.FAILED:
            self._release_retained(iid, rid)

    # -------------------------------------------------- prefix-key schemes
    def _lookup_keys(self, req: Request):
        """Block keys of ``req``'s prompt for the index lookup, capped so at
        least one token is always recomputed (the last position's logits
        produce o_1). Backends with real prompts override to add content
        keys for session-less requests."""
        if req.session_id is None:
            return None
        return lineage_keys(self._lineage_namespace(req),
                            req.input_len - 1, self.prefix_mgr.block)

    def _retention_keys(self, handle: RequestHandle):
        """Block keys of the *resident* context at finish: the prompt plus
        the generated tokens that entered the KV (the final token never
        does, hence input_len + decoded_tokens)."""
        req = handle.req
        if req.session_id is None:
            return None
        return lineage_keys(self._lineage_namespace(req),
                            req.input_len + req.decoded_tokens,
                            self.prefix_mgr.block)

    def _lineage_namespace(self, req: Request):
        """Namespace for lineage keys; backends that can fork a session
        (engine prompt truncation) override with (session_id, epoch)."""
        return req.session_id

    def _session_note_finish(self, handle: RequestHandle) -> None:
        """Called on every finish, cache on or off (the engine appends the
        generated tokens to the session transcript here)."""

    # ------------------------------------------ elastic backend hooks (§6)
    def _create_instance(self, iid: int) -> float:
        """Provision the physical substrate for a new instance (cost model +
        LocalScheduler on the sim; a real ``EngineInstance`` on the engine).
        Returns the warm-up delay in clock seconds (0 = ready now)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support elastic scaling")

    def _schedule_activation(self, iid: int, delay: float) -> None:
        """Arrange for ``activate_instance(iid)`` after ``delay`` seconds."""
        raise NotImplementedError

    def _destroy_instance(self, iid: int) -> None:
        """Release the substrate of a drained, removed instance."""

    def _instance_ready(self, iid: int) -> None:
        """An instance just became ACTIVE (event-driven backends kick it)."""

    def _instance_quiesced(self, iid: int) -> bool:
        """True when the backend has no in-flight work for ``iid`` beyond
        what the LocalScheduler queues show (sim: no running iteration)."""
        return True

    # --------------------------------------------------------- ClusterView
    def has_pending_prefill(self, iid: int) -> bool:
        return self.local_of(iid).has_pending_prefill()

    def has_pending_decode(self, iid: int) -> bool:
        return self.local_of(iid).has_pending_decode()

    # ---------------------------------------------------- request tracking
    def _register(self, req: Request, tier: str,
                  on_token: Optional[TokenCallback],
                  on_finish: Optional[FinishCallback],
                  tenant_id: Optional[str] = None) -> RequestHandle:
        if req.rid in self.handles:
            raise ValueError(f"rid {req.rid} already submitted")
        if tenant_id is not None:
            req.tenant_id = tenant_id
        if self.tenants is not None:
            if req.tenant_id is not None:
                # a registered tenant's declared tier overrides the
                # call-site default; unknown tenants auto-register as
                # standard/1.0
                tier = self.tenants.ensure(req.tenant_id).tier
            else:
                # untagged requests in a tenanted run share the anonymous
                # bucket so admission charges, WDRR labels, and per-tenant
                # report rows all agree; the call-site tier is kept
                req.tenant_id = DEFAULT_TENANT
                self.tenants.ensure(DEFAULT_TENANT)
            self.tenants.note_submit(req.tenant_id)
        if tier not in TIERS:
            raise ValueError(f"unknown SLO tier {tier!r}; "
                             f"choose from {sorted(TIERS)}")
        handle = RequestHandle(req=req, slo=TIERS[tier].apply(self.slo),
                               tier=tier, on_token=on_token,
                               on_finish=on_finish)
        if req.sampling is not None and not req.sampling.greedy:
            self._sampling_stats["sampled_requests"] += 1
        self.handles[req.rid] = handle
        return handle

    # ----------------------------------------------------- lifecycle glue
    def dispatch_prefill(self, handle: RequestHandle,
                         now: float) -> Optional[int]:
        """Place ``handle``'s prefill (Algorithm 1 + §7 prefix affinity).
        Returns the instance, or None when the request was deferred: a
        multi-turn follow-up whose parent has not finished yet (released in
        ``finish``), admission parked it in the RetryQueue or rejected it
        outright (§10 — before placement, so rejected requests never touch
        KV accounting), or no ACTIVE instance exists (released on the next
        ``activate_instance``)."""
        req = handle.req
        if req.parent_rid is not None:
            parent = self.handles.get(req.parent_rid)
            if parent is not None and not parent.done:
                if parent.rejected:
                    # the conversation cannot continue without the parent's
                    # answer: cascade the typed rejection to the follow-up
                    self._reject(handle,
                                 self.admission_ctl.cascade(handle, now),
                                 now)
                    return None
                self._gated.setdefault(req.parent_rid, []).append(req.rid)
                return None
        if self.admission_ctl is not None:
            decision = self.admission_ctl.consider(handle, now)
            if isinstance(decision, Rejected):
                self._reject(handle, decision, now)
                return None
            if isinstance(decision, Deferred):
                self._schedule_retry(req.rid, decision.retry_at)
                return None
        self._prepare_dispatch(handle, now)
        hits = None
        if self.prefix_mgr is not None:
            hits = self.prefix_mgr.lookup(self._lookup_keys(req))
        try:
            iid, hit, deflected = self.policy.place_prefill(
                req, now, prefix_hits=hits)
        except NoSchedulableInstance:
            self._unplaced.append(req.rid)
            return None
        cached = 0
        if hit is not None and self.prefix_mgr is not None:
            cached = min(hit.cached_len, req.input_len - 1)
            if cached > 0 and self.prefix_reuse == "exact":
                # Constant-state architectures (§13): the recurrent state
                # summarizes the source's *whole* stream — there is no
                # per-position KV to truncate, so reuse degrades to exact
                # full-stream matches. The hit must cover the entry's entire
                # key chain (a partial match is useless), and the query must
                # strictly extend the full resident stream (lineage chains
                # guarantee the sub-block tail: a follow-up turn literally
                # extends the session stream).
                ent = self.prefix_mgr.index.entries.get((hit.iid, hit.rid))
                if (ent is None
                        or hit.cached_len < len(ent.keys) * self.prefix_mgr.block
                        or ent.kv_tokens > req.input_len - 1):
                    cached = 0
                else:
                    cached = ent.kv_tokens
            if cached > 0 and iid == hit.iid:
                self.prefix_mgr.record_hit(PrefixHit(hit.iid, hit.rid,
                                                     cached))
                self.prefix_mgr.pin(hit.iid, hit.rid)
                self._prefix_src[req.rid] = (hit.iid, hit.rid, cached)
                req.cached_len = cached
            else:
                cached = 0
        if self.prefix_mgr is not None:
            p = self.predictor
            full = p.predict(req.input_len)
            t = self._prefix_timing
            t["full_prefill_s"] += full
            t["prefill_tokens"] += req.input_len
            if cached:
                t["saved_prefill_s"] += full - p.predict_chunk(
                    cached, req.input_len - cached)
        req.prefill_instance = iid
        req.state = RequestState.PREFILLING
        # tenant labels reach the scheduler only when a registry is armed:
        # a registry-less run stays exact legacy FIFO even on a
        # tenant-labelled trace (WDRR is part of the tenancy subsystem, §10)
        tenant = weight = None
        if self.tenants is not None and req.tenant_id is not None:
            tenant = req.tenant_id
            t = self.tenants.get(req.tenant_id)
            weight = t.weight if t is not None else 1.0
        self.local_of(iid).enqueue_prefill(req.rid, req.input_len,
                                           cached=cached,
                                           tenant=tenant,
                                           weight=weight or 1.0,
                                           deflected=deflected)
        self.decisions["prefill"] += 1
        if req.recoveries:
            # recovery recompute (§8): tokens prefilled again because a
            # crash lost the KV — a surviving prefix holder shrinks this
            self.fault_stats["re_prefill_tokens"] += \
                max(req.input_len - cached, 0)
        return iid

    def emit_token(self, handle: RequestHandle, now: float,
                   token: Optional[int] = None, *, first: bool = False) -> None:
        req = handle.req
        if first:
            req.first_token_time = now       # o_1 returned to user
        else:
            req.token_times.append(now)
            req.decoded_tokens += 1
        handle.tokens.append(token)
        if handle.on_token is not None:
            handle.on_token(handle, token, now)

    def _reject(self, handle: RequestHandle, decision, now: float) -> None:
        """Admission turned ``handle`` away (§10): terminal, typed, and
        outside every scheduling/KV structure — the request was never
        placed, so there is nothing to unwind. Children gated on it are
        released (they cascade through ``dispatch_prefill``), and
        ``on_finish`` fires so callers waiting on the handle observe the
        terminal state (check ``handle.rejected``)."""
        req = handle.req
        req.state = RequestState.REJECTED
        handle.rejection = decision
        self._request_rejected(req.rid)
        for rid in self._gated.pop(req.rid, []):
            child = self.handles[rid]
            child.req.arrival = max(child.req.arrival, now)
            self._arrival_due(rid)
        if handle.on_finish is not None:
            handle.on_finish(handle)

    def finish(self, handle: RequestHandle, now: float) -> None:
        handle.req.finish_time = now
        handle.req.state = RequestState.FINISHED
        self._recent_finish.append(handle.meets_slo())
        if self.tenants is not None and handle.req.tenant_id is not None:
            self.tenants.note_finish(handle.req.tenant_id, handle.meets_slo())
        self._session_note_finish(handle)
        if self.prefix_mgr is not None:
            self._maybe_retain(handle)
        # release follow-up turns gated on this request (multi-turn): the
        # user cannot send a follow-up before seeing the answer, so the
        # effective arrival is no earlier than the parent's finish.
        for rid in self._gated.pop(handle.req.rid, []):
            child = self.handles[rid]
            child.req.arrival = max(child.req.arrival, now)
            self._arrival_due(rid)
        if handle.on_finish is not None:
            handle.on_finish(handle)

    def _maybe_retain(self, handle: RequestHandle) -> None:
        """Retain the finished request's KV as a reusable prefix (§7) on the
        instance where it is resident — unless that instance is retiring
        (its memory is on the way out) or already gone."""
        req = handle.req
        iid = req.decode_instance if req.decode_instance is not None \
            else req.prefill_instance
        if iid is None or iid not in self.pools.all_ids() or \
                self.pools.lifecycle_of(iid) in (Lifecycle.RETIRING,
                                                 Lifecycle.FAILED):
            return
        keys = self._retention_keys(handle)
        if not keys:
            return
        kv = req.input_len + req.decoded_tokens
        if self._retain_kv(iid, req.rid, kv):
            self.prefix_mgr.retain(iid, req.rid, keys, kv)

    def recent_attainment(self, min_samples: int = 16) -> Optional[float]:
        """SLO attainment over the sliding window of recent finishes; None
        until ``min_samples`` finishes have been observed."""
        if len(self._recent_finish) < min_samples:
            return None
        return sum(self._recent_finish) / len(self._recent_finish)

    def after_prefill(self, handle: RequestHandle, iid: int, now: float,
                      token: Optional[int] = None,
                      ) -> Tuple[DecodePlacement, Optional[int]]:
        """Prefill finished on ``iid``: stream o_1, then place the decode
        phase (Algorithm 2). Returns the placement and, for MIGRATE, the
        target instance whose admission queue now holds the request.

        A crash-recovery prefill (§8) re-computed the already-streamed
        context: nothing new is emitted — the computed token is the last
        one the user already saw (it seeds the next decode step) — and
        decode resumes with the post-crash remainder."""
        req = handle.req
        src = self._prefix_src.pop(req.rid, None)
        if src is not None and self.prefix_mgr is not None:
            # copy-on-extend done (the suffix is computed): unpin the source
            self.prefix_mgr.unpin(src[0], src[1])
        resumed = req.resumed_tokens > 0 and \
            req.resumed_tokens == len(handle.tokens)
        if not resumed:
            self.emit_token(handle, now, token, first=True)
            if req.output_len <= 1:
                self.finish(handle, now)
                return DecodePlacement.FINISHED, None
        try:
            target = self.policy.schedule_decode_req(req, now)
        except NoSchedulableInstance:
            # nothing ACTIVE (e.g. a crash took the last one while this
            # prefill drained on a retiring instance): decode in place —
            # the KV is already here, and a retiring holder draining extra
            # decode work is the same situation as a migration landing on
            # it mid-retire. A crash of ``iid`` recovers it like any other
            # resident decode.
            target = iid
        self.decisions["decode"] += 1
        req.decode_instance = target
        remaining = req.output_len - len(handle.tokens)
        if target == iid:
            req.state = RequestState.DECODING
            self.local_of(iid).start_local_decode(
                req.rid, req.input_len, remaining)
            return DecodePlacement.LOCAL, iid
        req.state = RequestState.MIGRATING
        self._kv_outbound[iid] += 1
        self._migration_kv[req.rid] = req.input_len
        self.local_of(target).enqueue_migration(
            req.rid, req.input_len, remaining)
        self.decisions["migrations"] += 1
        return DecodePlacement.MIGRATE, target

    # -------------------------------------------------- migration manager
    def admit_migrations(self, iid: int) -> None:
        """FCFS, memory-gated admission (§5.4) at destination ``iid``; the
        backend's ``_begin_transfer`` performs/schedules the data movement."""
        loc = self.local_of(iid)
        while True:
            item = loc.next_migration()
            if item is None:
                # memory-blocked head: cached prefixes are the first thing
                # to go (§7 — reclaimable capacity, LRU, unpinned only)
                if self.prefix_mgr is not None and loc.migration_queue:
                    need = loc.kv_used + loc.migration_queue[0][1] \
                        - loc.kv_capacity
                    if need > 0 and \
                            self.prefix_mgr.make_room(iid, need) > 0:
                        continue
                # still blocked and no eviction helped: SLO-aware preemption
                # (§14) releases the lowest-value decode resident
                if loc.migration_queue and self._maybe_preempt(iid, loc):
                    continue
                return
            rid, kv, rem = item
            if rid not in self.handles:        # stale entry: drop it
                continue
            # count the transfer as inbound before starting it: async
            # backends land it later, and a retiring destination must not
            # finalize while data is in the air (the engine's synchronous
            # path completes inside _begin_transfer, netting back to zero).
            # _transfers keys the in-flight set a crash must abort (§8).
            self._kv_inbound[iid] += 1
            self._transfers[rid] = (self._kv_source(rid), iid, kv)
            if not self._begin_transfer(rid, iid, kv, rem):
                self._kv_inbound[iid] -= 1
                self._transfers.pop(rid, None)
                loc.migration_queue.appendleft((rid, kv, rem))
                return

    def _kv_source(self, rid: int) -> Optional[int]:
        """Instance currently holding ``rid``'s KV: its prefill instance, or
        — for retire-triggered re-migrations — the retiring decode holder."""
        return self._migrating_from.get(
            rid, self.handles[rid].req.prefill_instance)

    def _record_migration(self, rid: int, ctx_tokens: int,
                          nbytes: int) -> None:
        """Log a state transfer's real wire size (§13). Backends call this
        with the actual payload bytes — the engine sums the exported arrays'
        ``nbytes``, the simulator asks ``CostModel.migration_bytes``."""
        self.migration_log.append(
            {"rid": rid, "ctx_tokens": int(ctx_tokens), "bytes": int(nbytes)})

    def complete_migration(self, rid: int, dst: int, kv: int, rem: int,
                           now: float) -> None:
        """KV landed on ``dst``: release it at the source, join the decode
        set. (``now`` kept for symmetry/overrides; completion itself is not a
        scheduling decision.)"""
        req = self.handles[rid].req
        src = self._kv_source(rid)
        self._migrating_from.pop(rid, None)
        self._transfers.pop(rid, None)
        self._migration_kv.pop(rid, None)
        self._xfer_attempts.pop(rid, None)
        if src is not None and src != dst:
            self._release_source_kv(src, rid, kv)
        if src is not None and self._kv_outbound[src] > 0:
            self._kv_outbound[src] -= 1
        if self._kv_inbound[dst] > 0:
            self._kv_inbound[dst] -= 1
        self.local_of(dst).admit_migrated(rid, kv, rem)
        req.state = RequestState.DECODING
        req.decode_instance = dst
        self._decode_started(dst)

    # ----------------------------------- instance lifecycle (DESIGN.md §6)
    def scale_up(self, pool: Pool, now: float) -> int:
        """Provision one new instance into ``pool``. It joins WARMING when the
        backend models a spawn delay, ACTIVE immediately otherwise."""
        iid = self._next_iid
        self._next_iid += 1
        delay = self._create_instance(iid)
        self._arm_deflect(iid)
        self.pools.add_instance(iid, pool, warming=delay > 0)
        self.monitor.add_instance(iid)
        self.policy.on_instance_added(iid)
        self._spawned_at[iid] = now
        if delay > 0:
            self._schedule_activation(iid, delay)
        else:
            self._instance_ready(iid)
        return iid

    def activate_instance(self, iid: int) -> None:
        """Warm-up finished: the instance becomes schedulable. Requests that
        found no ACTIVE instance at dispatch time retry now. A stale
        activation (the instance crashed while warming, §8) is a no-op."""
        if iid not in self.pools.all_ids() or \
                self.pools.lifecycle_of(iid) is not Lifecycle.WARMING:
            return
        self.pools.activate(iid)
        self._instance_ready(iid)
        while self._unplaced:
            self._arrival_due(self._unplaced.popleft())

    def begin_retire(self, iid: int, now: float) -> None:
        """ACTIVE → RETIRING: the instance accepts no new work. Its queued
        inbound migrations are re-dispatched and its KV-resident decode
        requests are migrated away through the existing FCFS migration
        manager; prefill work it already holds drains in place. Removal
        happens in ``_maybe_finalize_retires`` once everything left."""
        self.pools.begin_retire(iid)
        self._retire_started[iid] = now
        if self.prefix_mgr is not None:
            # cached prefixes are disposable state: invalidate (free) rather
            # than migrate — pinned entries (a copy-on-extend in flight on
            # this very instance) are doomed and freed on the last unpin
            self.prefix_mgr.invalidate_instance(iid)
        self._evacuate_residents(iid)

    def _evacuate_residents(self, iid: int) -> None:
        """Drain ``iid``'s migratable state through the FCFS migration
        manager: re-dispatch its queued (never-admitted) inbound migrations —
        their KV is still elsewhere, only the queue entry moves — and migrate
        its KV-resident decode requests away (source KV stays resident until
        the transfer lands, exactly like a post-prefill migration). Shared by
        retirement (§6) and straggler quarantine (§14); prefill work drains
        in place either way."""
        self._quiesce_for_evacuation(iid)
        loc = self.local_of(iid)
        redispatch = []
        while loc.migration_queue:
            redispatch.append(loc.migration_queue.popleft())
        for rid in list(loc.decode_running):
            w = loc.decode_running.pop(rid)
            req = self.handles[rid].req
            req.state = RequestState.MIGRATING
            self._migrating_from[rid] = iid
            self._kv_outbound[iid] += 1
            self._migration_kv[rid] = w.context_len
            self.decisions["migrations"] += 1
            redispatch.append((rid, w.context_len, w.remaining_out))
        targets = set()
        evac_load = Counter()      # tentative KV per target within this batch
        for rid, kv, rem in redispatch:
            self._route_evacuation(rid, kv, rem, evac_load, targets)
        for dst in targets:
            self.admit_migrations(dst)

    def _quiesce_for_evacuation(self, iid: int) -> None:
        """Backend hook: settle any in-flight iteration on ``iid`` before its
        decode set is popped for evacuation (the engine force-finalizes the
        pending fused step; the sim's event loop needs nothing)."""

    def _route_evacuation(self, rid: int, kv: int, rem: int,
                          evac_load: Counter, targets: set) -> None:
        """Route one KV-holding migration item away from a retiring or
        failed instance: pick a destination, or resume decode in place when
        the chosen destination already holds the KV."""
        req = self.handles[rid].req
        dst = self._evacuation_target(kv, evac_load)
        src = self._kv_source(rid)
        if dst == src:
            # the chosen destination already holds the KV (a queued-away
            # migration whose source is now the best target): no transfer —
            # resume decode in place, like a LOCAL placement.
            if self._kv_outbound[src] > 0:
                self._kv_outbound[src] -= 1
            self._migrating_from.pop(rid, None)
            self._migration_kv.pop(rid, None)
            req.decode_instance = src
            req.state = RequestState.DECODING
            self.local_of(src).start_local_decode(rid, kv, rem)
            self._decode_started(src)
            return
        req.decode_instance = dst
        self.local_of(dst).enqueue_migration(rid, kv, rem)
        targets.add(dst)

    def _evacuation_target(self, kv: int, evac_load: Counter) -> int:
        """Destination for work leaving a retiring instance: the least-loaded
        ACTIVE decode-capable instance (any active instance as last resort).
        ``evac_load`` holds KV already routed within the current evacuation
        batch — monitor stats are tick-stale, so without it every request
        would pile onto the same pre-batch minimum."""
        ids = self.pools.decode_capable() or self.pools.active_ids()
        if not ids:
            raise RuntimeError("no active instance to evacuate to")
        dst = min(ids, key=lambda i: (self.monitor.get(i).running_tokens
                                      + evac_load[i]))
        evac_load[dst] += kv
        return dst

    def _retire_drained(self, iid: int) -> bool:
        loc = self.local_of(iid)
        return (not loc.has_pending_prefill()
                and not loc.has_pending_decode()
                and self._kv_outbound[iid] == 0
                and self._kv_inbound[iid] == 0
                and self._instance_quiesced(iid))

    def _maybe_finalize_retires(self, now: float) -> None:
        for iid in list(self._retire_started):
            if not self._retire_drained(iid):
                continue
            self._retire_started.pop(iid)
            self._finalize_instance(iid, now)
        # failed corpses (§8) have nothing to drain — the substrate is gone
        # and fail_instance already recovered the work; remove on sight
        for iid in list(self._failed_pending):
            self._failed_pending.pop(iid)
            self._finalize_instance(iid, now)

    def _finalize_instance(self, iid: int, now: float) -> None:
        if self.pools.lifecycle_of(iid) is not Lifecycle.FAILED:
            # a crashed instance's counters were banked in fail_instance
            # (its substrate — and local_of — may already be gone)
            self._harvest_deflect(self.local_of(iid))
        self.pools.remove_instance(iid)
        self.monitor.remove_instance(iid)
        self.policy.on_instance_removed(iid)
        self._instance_seconds_closed += now - self._spawned_at.pop(iid)
        self._kv_outbound.pop(iid, None)
        self._kv_inbound.pop(iid, None)
        self._preempt_log.pop(iid, None)
        if self.health_monitor is not None:
            self.health_monitor.forget(iid)
        self._destroy_instance(iid)

    def _arm_deflect(self, iid: int) -> None:
        """Set the §11 micro-batch ratio knob on ``iid``'s LocalScheduler.
        No-op when deflection is unarmed (the default ratio 0.0 stays, so
        non-deflective runs are byte-identical to pre-§11 builds)."""
        if self.deflection_cfg is not None:
            self.local_of(iid).deflect_ratio = self.deflection_cfg.ratio

    def _harvest_deflect(self, loc: LocalScheduler) -> None:
        """Bank a departing instance's executed-deflection counters so
        ``deflection_detail`` survives retirement and crashes."""
        self._deflect_closed["chunks"] += loc.deflected_chunks
        self._deflect_closed["tokens"] += loc.deflected_chunk_tokens
        loc.deflected_chunks = 0
        loc.deflected_chunk_tokens = 0

    def instance_seconds(self, now: float) -> float:
        """Σ per-instance alive time — the provisioning cost a static
        deployment pays for its full duration."""
        return self._instance_seconds_closed + \
            sum(now - t for t in self._spawned_at.values())

    # --------------------------------------------- fault domain (DESIGN.md §8)
    def fail_instance(self, iid: int, now: float, *,
                      recover: bool = True) -> Dict[str, int]:
        """Fail-stop crash of ``iid``: the substrate and every resident KV
        token are lost *instantly* — nothing drains. The runtime

          1. moves the instance to FAILED (never schedulable again),
          2. invalidates its prefix-index entries (pinned ones are doomed),
          3. aborts every migration touching it: transfers in flight *from*
             it lose their data (the request is recovered); transfers in
             flight or queued *toward* it still have live KV at the source
             and are re-routed to a surviving destination,
          4. re-dispatches its lost prefill- and decode-phase requests —
             decode victims re-prefill prompt + already-streamed tokens so
             recovered greedy streams stay token-identical (§8.2) — and
          5. asks the AutoScaler (elastic policies) for a replacement.

        ``recover=False`` is the no-recovery strawman: lost requests are
        stranded (``benchmarks/bench_faults.py`` quantifies the difference).
        Returns a per-crash summary for tests/benchmarks."""
        if iid not in self.pools.all_ids():
            raise ValueError(f"unknown instance {iid}")
        pool = self.pools.pool_of(iid)
        self.pools.fail(iid)                   # raises if already failed
        self.fault_stats["crashes"] += 1
        self._retire_started.pop(iid, None)    # a retiring instance may crash
        self._slowdowns.pop(iid, None)
        self._preempt_log.pop(iid, None)
        if self.health_monitor is not None:    # quarantine state dies with it
            self.health_monitor.forget(iid)
        loc = self.local_of(iid)
        self._harvest_deflect(loc)   # bank before the substrate is torn down
        # ---- 0. sever historical prefill pointers: a request whose KV
        # already moved on (decoding elsewhere, or re-migrating from a
        # different holder) keeps ``prefill_instance`` as attribution only —
        # left dangling it would make a live rid point at the corpse until
        # the next tick finalizes it (found by the property harness:
        # tests/corpus "max-ratio-crash-mid-deflect")
        for handle in self.handles.values():
            r = handle.req
            if r.prefill_instance != iid or r.state in (
                    RequestState.FINISHED, RequestState.REJECTED):
                continue
            if r.state is RequestState.DECODING and r.decode_instance != iid:
                r.prefill_instance = None
            elif r.state is RequestState.MIGRATING and \
                    self._migrating_from.get(r.rid) not in (None, iid):
                r.prefill_instance = None
        # ---- 1. inventory the lost work before any teardown
        lost_prefill = list(loc.prefill_queue)
        lost_decode = list(loc.decode_running)
        queued_inbound = list(loc.migration_queue)   # KV lives elsewhere
        outbound_flying, inbound_flying = [], []
        for rid, (src, dst, kv) in list(self._transfers.items()):
            if src == iid:
                outbound_flying.append((rid, dst, kv))   # data lost mid-air
            elif dst == iid:
                inbound_flying.append((rid, src, kv))    # destination gone
        queued_out = []          # queued at a live dst, KV source was iid
        inbound_rids = {q[0] for q in queued_inbound}
        for rid, kv in list(self._migration_kv.items()):
            if rid in self._transfers or rid in inbound_rids:
                continue
            req = self.handles[rid].req
            if req.state is RequestState.MIGRATING and \
                    self._kv_source(rid) == iid:
                queued_out.append((rid, req.decode_instance))
        # resident KV minus reservations for transfers still in the air
        # toward us — that data is intact at its source and gets rerouted,
        # so it was never lost
        self.fault_stats["kv_tokens_lost"] += max(
            loc.kv_used - sum(kv for _, _, kv in inbound_flying), 0)
        # ---- 2. cached prefixes are gone with the memory
        if self.prefix_mgr is not None:
            self.prefix_mgr.invalidate_instance(iid)
        # ---- 3. abort migrations touching iid
        for rid, dst, kv in outbound_flying:            # data lost mid-air
            self._abort_transfer(rid, dst, kv)
            self._transfers.pop(rid, None)
            self._migration_kv.pop(rid, None)
            if self._kv_inbound[dst] > 0:
                self._kv_inbound[dst] -= 1
            self.fault_stats["migrations_aborted"] += 1
        for rid, src, kv in inbound_flying:             # KV intact at src
            self._abort_transfer(rid, iid, kv)
            self._transfers.pop(rid, None)
            self.fault_stats["migrations_aborted"] += 1
        for rid, dst in queued_out:                     # data never moved
            q = self.local_of(dst).migration_queue
            for item in [it for it in q if it[0] == rid]:
                q.remove(item)
            self._migration_kv.pop(rid, None)
            self.fault_stats["migrations_aborted"] += 1
        self._kv_outbound.pop(iid, None)
        self._kv_inbound.pop(iid, None)
        # ---- 4. substrate teardown; the corpse is removed next tick
        self._failed_pending[iid] = now
        self._on_instance_failed(iid)
        loc.prefill_queue.clear()
        loc.decode_running.clear()
        loc.migration_queue.clear()
        loc.retained.clear()
        loc.kv_used = 0
        # ---- 5. replacement before recovery, so that when the crash took
        # the last ACTIVE instance the recovered requests have a WARMING
        # one to wait for instead of being undispatchable
        if self.autoscaler is not None:
            if self.autoscaler.on_instance_failed(iid, pool, now) is not None:
                self.fault_stats["replacements"] += 1
        # ---- 6. recovery: KV-intact migrations re-route; KV-lost requests
        # re-dispatch (scratch, or a surviving prefix holder via the normal
        # §7 affinity path)
        evac_load, targets = Counter(), set()
        kv_lost_rids = lost_prefill + lost_decode + \
            [rid for rid, _, _ in outbound_flying] + \
            [rid for rid, _ in queued_out]
        reroutes = [(rid, kv,
                     self.handles[rid].req.output_len
                     - len(self.handles[rid].tokens))
                    for rid, _, kv in inbound_flying]
        reroutes += queued_inbound
        for rid, kv, rem in reroutes:
            if self.pools.active_ids():
                self._route_evacuation(rid, kv, rem, evac_load, targets)
            else:
                # no destination anywhere: give up the surviving copy and
                # recover by re-prefill like a KV-lost request
                src = self._kv_source(rid)
                if src is not None:
                    self._release_source_kv(src, rid, kv)
                    if self._kv_outbound[src] > 0:
                        self._kv_outbound[src] -= 1
                self._migration_kv.pop(rid, None)
                kv_lost_rids.append(rid)
        for dst in targets:
            self.admit_migrations(dst)
        for rid in kv_lost_rids:
            if recover:
                self._recover_request(rid, now)
            else:
                self._migrating_from.pop(rid, None)
                self._migration_kv.pop(rid, None)
                src = self._prefix_src.pop(rid, None)
                if src is not None and self.prefix_mgr is not None:
                    self.prefix_mgr.unpin(src[0], src[1])
                self.fault_stats["requests_lost"] += 1
                self._request_lost(rid)
        return {"lost_prefill": len(lost_prefill),
                "lost_decode": len(lost_decode),
                "rerouted": len(inbound_flying) + len(queued_inbound),
                "recovered": len(kv_lost_rids) if recover else 0}

    def _recover_request(self, rid: int, now: float) -> None:
        """Re-dispatch a request whose KV was lost in a crash. Prefill-phase
        victims simply restart. Decode-phase victims must not re-emit what
        the user already saw: the context to rebuild is the prompt plus all
        streamed tokens except the last (whose logits seed the next decode
        step), so ``input_len`` absorbs those tokens — the recovery prefill
        is then costed, prefix-matched and placed like any other request,
        and ``after_prefill`` suppresses the duplicate emission."""
        handle = self.handles[rid]
        req = handle.req
        self._prepare_recovery(handle)        # engine extends the real prompt
        emitted = len(handle.tokens)
        if emitted:
            # tokens newly absorbed into the context since the last recovery
            delta = emitted - max(req.resumed_tokens, 1)
            req.input_len += delta
            req.decoded_tokens -= delta       # they are prompt now (§7 keys)
            req.resumed_tokens = emitted
        req.recoveries += 1
        req.state = RequestState.QUEUED
        req.prefill_instance = None
        req.decode_instance = None
        req.cached_len = 0
        req.prefill_done_tokens = 0
        self._migrating_from.pop(rid, None)
        self._xfer_attempts.pop(rid, None)
        src = self._prefix_src.pop(rid, None)
        if src is not None and self.prefix_mgr is not None:
            self.prefix_mgr.unpin(src[0], src[1])   # frees a doomed source
        self.fault_stats["requests_recovered"] += 1
        self._arrival_due(rid)

    def apply_slowdown(self, iid: int, factor: float, until: float) -> None:
        """A lagging instance (§3.2): iterations run ``factor`` slower until
        the system clock passes ``until``."""
        self._slowdowns[iid] = (factor, until)
        self.fault_stats["slowdowns"] += 1

    def slow_factor(self, iid: int, now: float) -> float:
        ent = self._slowdowns.get(iid)
        if ent is None:
            return 1.0
        factor, until = ent
        if now >= until:
            del self._slowdowns[iid]
            return 1.0
        return factor

    # ------------------------------------- self-healing layer (DESIGN.md §14)
    def quarantine_instance(self, iid: int, now: float) -> None:
        """ACTIVE → DEGRADED: the HealthMonitor flagged ``iid`` as a
        sustained straggler. No new work lands on it; its decode residents
        are drained away through the FCFS migration manager (their KV is
        intact — this is a planned move, not a crash); prefill it already
        holds drains in place. The interval window is cleared so the stale
        slow samples cannot re-trip detection right after probation."""
        self.pools.degrade(iid)
        self.health_stats["quarantines"] += 1
        self.monitor.reset_intervals(iid)
        self._evacuate_residents(iid)

    def restore_instance(self, iid: int, now: float) -> None:
        """DEGRADED → ACTIVE (probation passed): back in the schedulable
        set. Requests parked while nothing was ACTIVE retry now, mirroring
        ``activate_instance``."""
        self.pools.restore(iid)
        self.health_stats["restores"] += 1
        self.monitor.reset_intervals(iid)
        self._instance_ready(iid)
        while self._unplaced:
            self._arrival_due(self._unplaced.popleft())

    def escalate_unhealthy(self, iid: int, now: float) -> None:
        """Quarantine deadline expired — the instance kept relapsing: treat
        it as a hard fault (teardown + recovery + replacement, §8)."""
        self.health_stats["escalations"] += 1
        if self.health_monitor is not None:
            self.health_monitor.forget(iid)
        self.fail_instance(iid, now)

    def apply_transfer_drop(self, p: float, until: float) -> None:
        """Transient network fault window (§14): each migration transfer
        attempt started before ``until`` fails with probability ``p``."""
        self._xfer_drop = (p, until)

    def apply_netslow(self, factor: float, until: float) -> None:
        """Degraded interconnect window (§14): transfer durations are
        multiplied by ``factor`` until the clock passes ``until``."""
        self._netslow = (factor, until)

    def xfer_should_drop(self, now: float) -> bool:
        """Decide one transfer attempt's fate under the drop window. The RNG
        is only consumed while a window is active and 0 < p < 1, so runs
        without droptransfer events never draw from it."""
        if self._xfer_drop is None:
            return False
        p, until = self._xfer_drop
        if now >= until:
            self._xfer_drop = None
            return False
        if p >= 1.0:
            return True
        return self._xfer_rng.random() < p

    def netslow_factor(self, now: float) -> float:
        if self._netslow is None:
            return 1.0
        factor, until = self._netslow
        if now >= until:
            self._netslow = None
            return 1.0
        return factor

    def xfer_retry_budget(self) -> int:
        """Bounded retry attempts per transfer; 0 without ``--health`` (a
        dropped transfer then falls straight through to re-prefill recovery —
        the detection-off baseline bench_chaos measures against)."""
        return self.health_cfg.xfer_retries if self.health_cfg else 0

    def xfer_backoff(self, attempt: int) -> float:
        """Exponential backoff before retry ``attempt`` (1-based)."""
        base = self.health_cfg.xfer_backoff_s if self.health_cfg else 0.25
        return base * (2.0 ** (attempt - 1))

    def note_xfer_drop(self, rid: int) -> int:
        """One transfer attempt failed (dropped/timed out/corrupt): returns
        the attempt count so far for backoff computation."""
        self.health_stats["xfer_drops"] += 1
        self._xfer_attempts[rid] = self._xfer_attempts.get(rid, 0) + 1
        return self._xfer_attempts[rid]

    def fail_transfer(self, rid: int, dst: int, kv: int, now: float) -> None:
        """Retry budget exhausted for ``rid``'s transfer toward ``dst``: give
        up the move. The surviving source copy is released and the request
        falls through to the §8 re-prefill recovery path (streams stay
        token-identical — recovery re-computes prompt ‖ streamed[:-1])."""
        self._transfers.pop(rid, None)
        self._xfer_attempts.pop(rid, None)
        if self._kv_inbound[dst] > 0:
            self._kv_inbound[dst] -= 1
        src = self._kv_source(rid)
        if src is not None:
            self._release_source_kv(src, rid, kv)
            if self._kv_outbound[src] > 0:
                self._kv_outbound[src] -= 1
        self._migration_kv.pop(rid, None)
        self.health_stats["xfer_failures"] += 1
        self._recover_request(rid, now)

    def _maybe_preempt(self, iid: int, loc: LocalScheduler) -> bool:
        """SLO-aware preemption at the §5.4 memory gate: the head migration
        is blocked and eviction freed nothing, so release the lowest-value
        decode resident — ordered by tenant credit balance, SLO tier (batch
        first), then remaining-length estimate (longest remaining = least
        sunk progress) — and re-dispatch it through the §8 recovery path
        (streams stay bit-identical). A per-instance rate limiter keeps
        degradation graceful rather than thrashing."""
        cfg = self.health_cfg
        if cfg is None or not cfg.preemption or not loc.decode_running:
            return False
        now = self.clock.now()
        log = self._preempt_log.setdefault(iid, deque())
        while log and now - log[0] > cfg.preempt_window_s:
            log.popleft()
        if len(log) >= cfg.preempt_limit:
            self.health_stats["preempt_refused"] += 1
            return False
        victim = min(loc.decode_running,
                     key=lambda rid: self._preemption_key(rid, loc))
        self._quiesce_for_evacuation(iid)
        if victim not in loc.decode_running:
            # the settling step just finished it — its KV is free, retry
            # the gate without charging the limiter
            return True
        w = loc.decode_running.pop(victim)
        loc.kv_used -= w.context_len
        self._preempt_release(iid, victim)
        log.append(now)
        self.health_stats["preemptions"] += 1
        self._recover_request(victim, now)
        return True

    def _preemption_key(self, rid: int, loc: LocalScheduler):
        handle = self.handles[rid]
        credits = 0.0
        if self.tenants is not None and handle.req.tenant_id is not None:
            credits = self.tenants.credits(handle.req.tenant_id)
        tier_rank = {"batch": 0, "standard": 1, "interactive": 2}[handle.tier]
        remaining = loc.decode_running[rid].remaining_out
        return (credits, tier_rank, -remaining, rid)

    def _preempt_release(self, iid: int, rid: int) -> None:
        """Backend hook: free the physical decode state of a preempted
        resident (the engine drops the real slot; the sim holds nothing
        beyond the LocalScheduler bookkeeping already undone)."""

    def health_detail(self) -> Dict[str, float]:
        """Self-healing accounting (§14); empty when the layer never acted
        (so health-off reports stay byte-identical to pre-§14 builds)."""
        if not any(self.health_stats.values()):
            return {}
        return dict(self.health_stats)

    def _check_undispatchable(self) -> None:
        """Raise UndispatchableError when queued requests can never dispatch:
        nothing ACTIVE, nothing WARMING, nothing DEGRADED awaiting probation
        (drain would otherwise hang)."""
        if not self._unplaced:
            return
        if self.pools.active_ids() or self.pools.warming_ids() or \
                self.pools.degraded_ids():
            return
        raise UndispatchableError(self._unplaced, self.pools)

    # ------------------------------------------------ monitor-tick scrape
    def collect_stats(self, now: float) -> None:
        ready = getattr(self.policy, "prefill_ready_at", {})
        for iid in self.pools.all_ids():
            if self.pools.lifecycle_of(iid) is Lifecycle.FAILED:
                continue               # corpse (§8): substrate gone
            loc = self.local_of(iid)
            self.monitor.update_stats(InstanceStats(
                instance_id=iid,
                prefill_queue_len=len(loc.prefill_queue),
                prefill_backlog_tokens=loc.prefill_backlog_tokens,
                prefill_ready_at=ready.get(iid, 0.0),
                running_tokens=loc.running_tokens,
                n_decode_running=len(loc.decode_running),
                kv_tokens_used=loc.kv_used,
                kv_tokens_capacity=loc.kv_capacity,
            ))
        if self.health_monitor is not None:
            # right after the scrape, before scheduling reacts: both
            # backends see identical post-scrape signals at a barrier (§14)
            self.health_monitor.tick(now)
        self.policy.on_monitor_tick(now)
        if self.tenants is not None:
            self.tenants.on_tick(now)        # credit accrual (§10)
        if self.autoscaler is not None:
            self.autoscaler.on_monitor_tick(now)
        self._maybe_finalize_retires(now)

    # ------------------------------------------------ pool-flip accounting
    def flip_counts(self) -> Dict[str, int]:
        return {
            "total": self.pools.flips,
            "d2p": getattr(self.policy, "n_d2p_flips", 0),
            "p2d": getattr(self.policy, "n_p2d_flips", 0),
            "proactive": getattr(self.policy, "n_proactive_flips", 0),
        }

    # ----------------------------------------------------------- reporting
    def scaling_detail(self) -> Dict[str, float]:
        now = self.clock.now()
        out = {"instance_seconds": self.instance_seconds(now),
               "n_instances": len(self.pools.all_ids())}
        if self.autoscaler is not None:
            out["scale_ups"] = self.autoscaler.n_scale_ups
            out["scale_downs"] = self.autoscaler.n_scale_downs
        return out

    def prefix_detail(self) -> Dict[str, float]:
        """Prefix-cache effectiveness (§7); empty when the cache is off."""
        if self.prefix_mgr is None:
            return {}
        out = dict(self.prefix_mgr.stats)
        out.update(self._prefix_timing)
        full = out["full_prefill_s"]
        out["saved_prefill_frac"] = \
            out["saved_prefill_s"] / full if full > 0 else 0.0
        return out

    def fault_detail(self) -> Dict[str, float]:
        """Fault/recovery accounting (§8); empty when no fault ever fired
        (so fault-free reports stay byte-identical to pre-fault builds)."""
        if not any(self.fault_stats.values()):
            return {}
        return dict(self.fault_stats)

    def deflection_detail(self) -> Dict[str, float]:
        """Cross-pool deflection accounting (§11); empty when deflection is
        unarmed or never acted (so ratio=0 / non-deflective reports stay
        byte-identical to pre-deflection builds)."""
        if self.deflection_cfg is None or self.policy.deflection is None:
            return {}
        out = dict(self.policy.deflection.stats)
        chunks = self._deflect_closed["chunks"]
        tokens = self._deflect_closed["tokens"]
        for iid in self.pools.all_ids():
            if self.pools.lifecycle_of(iid) is Lifecycle.FAILED:
                continue
            loc = self.local_of(iid)
            chunks += loc.deflected_chunks
            tokens += loc.deflected_chunk_tokens
        out["chunks_executed"] = chunks
        out["chunk_tokens_executed"] = tokens
        if not any(out.values()):
            return {}
        return out

    def admission_detail(self) -> Dict[str, float]:
        """Admission-control accounting (§10); empty when admission is off
        (so tenant-less reports stay byte-identical to pre-tenancy builds)."""
        if self.admission_ctl is None:
            return {}
        return dict(self.admission_ctl.stats)

    def tenant_detail(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant report rows (§10); empty without a tenant registry.
        A tenant with zero finished requests gets ``None`` metrics (callers
        render 'n/a', never divide by zero)."""
        if self.tenants is None:
            return {}
        by_tenant: Dict[str, list] = {}
        for h in self.handles.values():
            if h.req.tenant_id is not None:
                by_tenant.setdefault(h.req.tenant_id, []).append(h)
        out: Dict[str, Dict[str, float]] = {}
        for tid in self.tenants.ids():
            hs = by_tenant.get(tid, [])
            sub = ServeReport(handles=hs)      # reuse percentile machinery
            tenant = self.tenants.get(tid)
            row = {
                "tier": tenant.tier,
                "weight": tenant.weight,
                "attainment": (sum(1 for h in hs if h.meets_slo()) / len(hs)
                               if hs else None),
                "p99_ttft": sub.percentile("ttft", 0.99),
                "p99_tpot": sub.percentile("tpot", 0.99),
                "credits": self.tenants.credits(tid),
                "violation_ewma": self.tenants.violation_ewma(tid),
            }
            row.update(self.tenants.counters.get(tid, {}))
            out[tid] = row
        return out

    def sampling_detail(self) -> Dict[str, float]:
        """Replayable-sampling accounting (§12); empty when every request
        decoded greedily (so greedy reports stay byte-identical to
        pre-sampling builds). ``seed`` is the run seed each slot's key
        stream is folded from — the replay handle."""
        if not self._sampling_stats["sampled_requests"]:
            return {}
        return {"seed": self.run_seed, **self._sampling_stats}

    def speculation_detail(self) -> Dict[str, float]:
        """Self-speculative decoding accounting (§12); empty when
        speculation is off or never ran a round."""
        if not self._spec_stats["rounds"]:
            return {}
        out = dict(self._spec_stats)
        out["acceptance"] = (out["accepted"] / out["drafted"]
                             if out["drafted"] else 0.0)
        return out

    def report(self) -> ServeReport:
        return ServeReport(handles=list(self.handles.values()),
                           flip_detail=self.flip_counts(),
                           decisions=dict(self.decisions),
                           duration=self.clock.now(),
                           scaling=self.scaling_detail(),
                           prefix=self.prefix_detail(),
                           faults=self.fault_detail(),
                           health=self.health_detail(),
                           admission=self.admission_detail(),
                           deflection=self.deflection_detail(),
                           per_tenant=self.tenant_detail(),
                           sampling=self.sampling_detail(),
                           speculation=self.speculation_detail())
