"""Elastic instance pools (§5.2): PREFILL, DECODE, P→D, D→P with the Fig. 5
transition diagram. Flipping = pool-membership move, zero wait/restart."""
from __future__ import annotations

import enum
from typing import Dict, List, Set


class Pool(enum.Enum):
    PREFILL = "P"
    DECODE = "D"
    P2D = "P->D"      # scheduled for decode; still draining prefill work
    D2P = "D->P"      # scheduled for prefill; still draining decode work


class InstancePools:
    def __init__(self, instance_ids, n_prefill: int):
        """First ``n_prefill`` ids start in PREFILL, the rest in DECODE."""
        ids = list(instance_ids)
        self._pool: Dict[int, Pool] = {}
        for i, iid in enumerate(ids):
            self._pool[iid] = Pool.PREFILL if i < n_prefill else Pool.DECODE
        self.flips = 0               # observability: pool moves performed

    # ------------------------------------------------------------- queries
    def pool_of(self, iid: int) -> Pool:
        return self._pool[iid]

    def members(self, pool: Pool) -> List[int]:
        return [i for i, p in self._pool.items() if p is pool]

    def all_ids(self) -> List[int]:
        return list(self._pool)

    def prefill_capable(self) -> List[int]:
        """Instances currently accepting prefill requests: P ∪ D→P."""
        return [i for i, p in self._pool.items() if p in (Pool.PREFILL, Pool.D2P)]

    def decode_capable(self) -> List[int]:
        return [i for i, p in self._pool.items() if p in (Pool.DECODE, Pool.P2D)]

    def count(self, *pools: Pool) -> int:
        return sum(1 for p in self._pool.values() if p in pools)

    # --------------------------------------------------------- transitions
    def move(self, iid: int, to: Pool) -> None:
        if self._pool[iid] is not to:
            self.flips += 1
        self._pool[iid] = to

    def flip_to_decode(self, iid: int, has_pending_prefill: bool) -> Pool:
        """PREFILL/D→P instance is reassigned to decode duty."""
        to = Pool.P2D if has_pending_prefill else Pool.DECODE
        self.move(iid, to)
        return to

    def flip_to_prefill(self, iid: int, has_pending_decode: bool) -> Pool:
        to = Pool.D2P if has_pending_decode else Pool.PREFILL
        self.move(iid, to)
        return to

    def on_prefill_drained(self, iid: int) -> None:
        """Black transition edge: P→D pool member finished its prefill queue."""
        if self._pool[iid] is Pool.P2D:
            self.move(iid, Pool.DECODE)

    def on_decode_drained(self, iid: int) -> None:
        if self._pool[iid] is Pool.D2P:
            self.move(iid, Pool.PREFILL)
