"""Elastic instance pools (§5.2): PREFILL, DECODE, P→D, D→P with the Fig. 5
transition diagram. Flipping = pool-membership move, zero wait/restart.

Beyond the paper (DESIGN.md §6): the instance *set* itself is elastic. Each
instance carries a lifecycle state

    WARMING ──activate──▶ ACTIVE ──begin_retire──▶ RETIRING ──remove──▶ (gone)
       │                    │  ▲                      │
       │                    │  └─restore─ DEGRADED    │
       │                    │  ──degrade──▶ │         │
       └────────────────────┴───────fail────┴─────────┘──remove──▶ (gone)

Only ACTIVE instances are schedulable: ``members``/``prefill_capable``/
``decode_capable``/``count`` all restrict themselves to ACTIVE, so the
global scheduler and the flip algorithms (Alg. 1–4) never place work on — or
flip — a warming or retiring instance. RETIRING instances keep draining the
work they already hold (``all_ids`` still includes them for stat scraping and
iteration driving); the runtime removes them once drained (core/runtime.py).

FAILED (DESIGN.md §8) is the fail-stop crash state: reachable from any live
state, never schedulable, never flippable, skipped by stat scraping and the
AutoScaler's pool accounting. Unlike RETIRING nothing drains — the substrate
and its resident KV are already gone; the runtime recovers the lost work
(core/runtime.py ``fail_instance``) and removes the corpse on the next
monitor tick.

DEGRADED (DESIGN.md §14) is the straggler-quarantine state: the substrate is
alive but sustained-slow, so it takes no new placements while its decode
residents are drained away through the migration manager. Unlike RETIRING it
is reversible — ``restore`` puts a recovered instance back in service — and
unlike FAILED its KV is intact, so nothing is lost while it sits in
quarantine. The HealthMonitor (core/health.py) drives both transitions and
escalates to ``fail`` when quarantine exceeds its deadline.
"""
from __future__ import annotations

import enum
from typing import Dict, List


class Pool(enum.Enum):
    PREFILL = "P"
    DECODE = "D"
    P2D = "P->D"      # scheduled for decode; still draining prefill work
    D2P = "D->P"      # scheduled for prefill; still draining decode work


class Lifecycle(enum.Enum):
    WARMING = "warming"    # provisioning/loading weights; not schedulable yet
    ACTIVE = "active"      # schedulable member of its pool
    RETIRING = "retiring"  # draining; accepts no new work, no flips
    DEGRADED = "degraded"  # quarantined straggler; reversible (§14)
    FAILED = "failed"      # crashed: substrate + resident KV gone (§8)


class InstancePools:
    def __init__(self, instance_ids, n_prefill: int):
        """First ``n_prefill`` ids start in PREFILL, the rest in DECODE."""
        ids = list(instance_ids)
        self._pool: Dict[int, Pool] = {}
        self._life: Dict[int, Lifecycle] = {}
        for i, iid in enumerate(ids):
            self._pool[iid] = Pool.PREFILL if i < n_prefill else Pool.DECODE
            self._life[iid] = Lifecycle.ACTIVE
        self.flips = 0               # observability: pool moves performed
        # observer invoked on every actual pool move (iid, frm, to): the
        # runtime uses it to invalidate the prefix cache on a role change
        # (DESIGN.md §7) without the scheduler knowing about caching.
        self.on_flip = None

    # ------------------------------------------------------------- queries
    def pool_of(self, iid: int) -> Pool:
        return self._pool[iid]

    def lifecycle_of(self, iid: int) -> Lifecycle:
        return self._life[iid]

    def is_schedulable(self, iid: int) -> bool:
        """True when ``iid`` is a live ACTIVE member (new work may land)."""
        return self._life.get(iid) is Lifecycle.ACTIVE

    def members(self, pool: Pool) -> List[int]:
        """ACTIVE members of ``pool`` — the schedulable set."""
        return [i for i, p in self._pool.items()
                if p is pool and self._life[i] is Lifecycle.ACTIVE]

    def all_ids(self) -> List[int]:
        """Every live instance: warming + active + retiring."""
        return list(self._pool)

    def active_ids(self) -> List[int]:
        return [i for i, s in self._life.items() if s is Lifecycle.ACTIVE]

    def warming_ids(self) -> List[int]:
        return [i for i, s in self._life.items() if s is Lifecycle.WARMING]

    def retiring_ids(self) -> List[int]:
        return [i for i, s in self._life.items() if s is Lifecycle.RETIRING]

    def degraded_ids(self) -> List[int]:
        return [i for i, s in self._life.items() if s is Lifecycle.DEGRADED]

    def failed_ids(self) -> List[int]:
        return [i for i, s in self._life.items() if s is Lifecycle.FAILED]

    def prefill_capable(self) -> List[int]:
        """Instances currently accepting prefill requests: P ∪ D→P."""
        return [i for i, p in self._pool.items()
                if p in (Pool.PREFILL, Pool.D2P)
                and self._life[i] is Lifecycle.ACTIVE]

    def decode_capable(self) -> List[int]:
        return [i for i, p in self._pool.items()
                if p in (Pool.DECODE, Pool.P2D)
                and self._life[i] is Lifecycle.ACTIVE]

    def count(self, *pools: Pool) -> int:
        return sum(1 for i, p in self._pool.items()
                   if p in pools and self._life[i] is Lifecycle.ACTIVE)

    # --------------------------------------------------------- transitions
    def move(self, iid: int, to: Pool) -> None:
        frm = self._pool[iid]
        if frm is not to:
            self.flips += 1
        self._pool[iid] = to
        if frm is not to and self.on_flip is not None:
            self.on_flip(iid, frm, to)

    def flip_to_decode(self, iid: int, has_pending_prefill: bool) -> Pool:
        """PREFILL/D→P instance is reassigned to decode duty."""
        if self._life[iid] is not Lifecycle.ACTIVE:
            raise ValueError(f"cannot flip instance {iid}: "
                             f"{self._life[iid].value}")
        to = Pool.P2D if has_pending_prefill else Pool.DECODE
        self.move(iid, to)
        return to

    def flip_to_prefill(self, iid: int, has_pending_decode: bool) -> Pool:
        if self._life[iid] is not Lifecycle.ACTIVE:
            raise ValueError(f"cannot flip instance {iid}: "
                             f"{self._life[iid].value}")
        to = Pool.D2P if has_pending_decode else Pool.PREFILL
        self.move(iid, to)
        return to

    def on_prefill_drained(self, iid: int) -> None:
        """Black transition edge: P→D pool member finished its prefill queue.
        A no-op for warming/retiring instances (their pool no longer matters)."""
        if self._pool[iid] is Pool.P2D and \
                self._life[iid] is Lifecycle.ACTIVE:
            self.move(iid, Pool.DECODE)

    def on_decode_drained(self, iid: int) -> None:
        if self._pool[iid] is Pool.D2P and \
                self._life[iid] is Lifecycle.ACTIVE:
            self.move(iid, Pool.PREFILL)

    # ---------------------------------------------- lifecycle (DESIGN.md §6)
    def add_instance(self, iid: int, pool: Pool, *,
                     warming: bool = False) -> None:
        """Register a freshly provisioned instance. ``warming=True`` keeps it
        out of the schedulable set until ``activate``."""
        if iid in self._pool:
            raise ValueError(f"instance {iid} already exists")
        self._pool[iid] = pool
        self._life[iid] = Lifecycle.WARMING if warming else Lifecycle.ACTIVE

    def activate(self, iid: int) -> None:
        if self._life[iid] is not Lifecycle.WARMING:
            raise ValueError(f"instance {iid} is {self._life[iid].value}, "
                             "not warming")
        self._life[iid] = Lifecycle.ACTIVE

    def begin_retire(self, iid: int) -> None:
        """ACTIVE → RETIRING: no new work, no flips; existing work drains."""
        if self._life[iid] is not Lifecycle.ACTIVE:
            raise ValueError(f"cannot retire instance {iid}: "
                             f"{self._life[iid].value}")
        self._life[iid] = Lifecycle.RETIRING

    def degrade(self, iid: int) -> None:
        """ACTIVE → DEGRADED (quarantine, DESIGN.md §14): the instance stops
        being schedulable while its decode residents drain; reversible via
        ``restore`` once the straggler signal clears."""
        if self._life[iid] is not Lifecycle.ACTIVE:
            raise ValueError(f"cannot quarantine instance {iid}: "
                             f"{self._life[iid].value}")
        self._life[iid] = Lifecycle.DEGRADED

    def restore(self, iid: int) -> None:
        """DEGRADED → ACTIVE: probation passed, back in service."""
        if self._life[iid] is not Lifecycle.DEGRADED:
            raise ValueError(f"cannot restore instance {iid}: "
                             f"{self._life[iid].value}")
        self._life[iid] = Lifecycle.ACTIVE

    def fail(self, iid: int) -> None:
        """Fail-stop crash (DESIGN.md §8): reachable from any live state.
        The instance is instantly unschedulable and unflippable; the runtime
        recovers its lost work and removes the corpse."""
        if iid not in self._life:
            raise ValueError(f"unknown instance {iid}")
        if self._life[iid] is Lifecycle.FAILED:
            raise ValueError(f"instance {iid} already failed")
        self._life[iid] = Lifecycle.FAILED

    def remove_instance(self, iid: int) -> None:
        """Final removal of a drained RETIRING or crashed FAILED instance."""
        if self._life[iid] not in (Lifecycle.RETIRING, Lifecycle.FAILED):
            raise ValueError(f"cannot remove instance {iid}: "
                             f"{self._life[iid].value} (retire first)")
        del self._pool[iid]
        del self._life[iid]
