"""Scheduling policies: Arrow (the paper) + the evaluation baselines
(§7: vLLM-colocated, static PD-disaggregation, and the §7.3 ablations
Minimal-Load and Round-Robin).

Backend-agnostic: policies see only pools/monitor/predictor/ClusterView, so
the same ``POLICIES`` registry drives the discrete-event simulator and the
real JAX engine through the shared runtime (core/runtime.py).
"""
from __future__ import annotations

from typing import Dict

from repro.core.global_scheduler import (GlobalScheduler,  # noqa: F401
                                         NoSchedulableInstance,
                                         ScheduleOutcome)
from repro.core.monitor import InstanceMonitor
from repro.core.pools import InstancePools, Pool
from repro.core.request import Request
from repro.core.slo import SLO, SchedulerConfig
from repro.core.ttft_predictor import TTFTPredictor


class BasePolicy:
    """Shared Eq.(1)/(2) prefill-queue bookkeeping."""

    name = "base"
    adaptive = False
    elastic = False          # True: RuntimeCore attaches an AutoScaler (§6)
    deflective = False       # True: RuntimeCore arms DeflectionPolicy (§11)

    def __init__(self, pools: InstancePools, monitor: InstanceMonitor,
                 predictor: TTFTPredictor, slo: SLO, cfg: SchedulerConfig,
                 cluster):
        self.pools = pools
        self.monitor = monitor
        self.predictor = predictor
        self.slo = slo
        self.cfg = cfg
        self.cluster = cluster
        self.prefill_ready_at: Dict[int, float] = {
            i: 0.0 for i in pools.all_ids()}

    # elastic lifecycle (DESIGN.md §6): keep per-instance bookkeeping in sync
    def on_instance_added(self, iid: int) -> None:
        self.prefill_ready_at.setdefault(iid, 0.0)

    def on_instance_removed(self, iid: int) -> None:
        self.prefill_ready_at.pop(iid, None)

    def _account(self, iid: int, now: float, input_len: int) -> None:
        start = max(self.prefill_ready_at[iid], now)
        self.prefill_ready_at[iid] = start + self.predictor.predict(input_len)

    def _require(self, ids, phase: str):
        """Baselines share arrow's contract under elasticity/faults: an
        empty candidate set raises NoSchedulableInstance (the runtime queues
        and retries on activation) instead of a bare min()/index crash."""
        if not ids:
            raise NoSchedulableInstance(phase, self.pools)
        return ids

    def _min_ready(self, ids, now):
        return min(ids, key=lambda i: max(self.prefill_ready_at[i] - now, 0.0))

    def _min_tokens(self, ids):
        return min(ids, key=lambda i: self.monitor.get(i).running_tokens)

    def place_prefill(self, req: Request, now: float, prefix_hits=None):
        """Prefill placement entry point used by the runtime. Baselines do
        not route by prefix affinity, but when their own choice happens to
        land on an instance that already caches a prefix of ``req`` the
        reuse is still taken (the KV is right there). Returns
        ``(iid, PrefixHit | None, deflected)``."""
        iid = self.schedule_prefill_req(req, now)
        hit = next((h for h in (prefix_hits or []) if h.iid == iid), None)
        return iid, hit, False

    def on_monitor_tick(self, now: float) -> None:
        pass


class ArrowPolicy(GlobalScheduler):
    """The paper's SLO-aware adaptive policy (GlobalScheduler as-is)."""

    name = "arrow"
    adaptive = True

    def schedule_prefill_req(self, req: Request, now: float) -> int:
        return self.schedule_prefill(req, now).instance

    def schedule_decode_req(self, req: Request, now: float) -> int:
        return self.schedule_decode(req, now).instance

    def place_prefill(self, req: Request, now: float, prefix_hits=None):
        """Arrow routes by prefix affinity (§7): Algorithm 1 considers the
        cached-prefix holder first and charges Eq. (2) only the suffix.
        Reuse is taken *only* when the affinity shortcut chose it — when
        the normal path happens to land on a holder it was charged the
        full prefill, and taking the reuse anyway would leave
        ``prefill_ready_at`` overestimating by the cached-prefix time."""
        out = self.schedule_prefill(req, now, prefix_hits=prefix_hits)
        return out.instance, out.prefix_hit, out.deflected


class ArrowElasticPolicy(ArrowPolicy):
    """Arrow request/instance scheduling + AutoScaler-driven cluster sizing:
    the instance *set* grows under sustained pressure and shrinks when slack
    (DESIGN.md §6). Request-level decisions are identical to ``arrow``."""

    name = "arrow_elastic"
    elastic = True


class ArrowDeflectPolicy(ArrowElasticPolicy):
    """arrow_elastic + cross-pool prefill deflection (DESIGN.md §11): under
    Eq.(1) prefill-pool pressure, decode instances absorb bounded prefill
    chunks in-step (and idle prefill instances pick up decode slack) while
    the autoscaler still converges pool counts for sustained shifts. The
    runtime arms ``GlobalScheduler.deflection`` with a DeflectionConfig."""

    name = "arrow_deflect"
    deflective = True


class MinimalLoadPolicy(BasePolicy):
    """§7.3 'Minimal Load': min-load request scheduling, static pools.
    Also stands in for vLLM-disaggregated / DistServe-style static PD
    deployments (configure the PD ratio via InstancePools)."""

    name = "minimal_load"

    def schedule_prefill_req(self, req: Request, now: float) -> int:
        ids = self._require(self.pools.members(Pool.PREFILL)
                            or self.pools.prefill_capable()
                            or self.pools.active_ids(), "prefill")
        iid = self._min_ready(ids, now)
        self._account(iid, now, req.input_len)
        return iid

    def schedule_decode_req(self, req: Request, now: float) -> int:
        ids = self._require(self.pools.members(Pool.DECODE)
                            or self.pools.decode_capable()
                            or self.pools.active_ids(), "decode")
        return self._min_tokens(ids)


class RoundRobinPolicy(BasePolicy):
    """§7.3 'Round Robin'."""

    name = "round_robin"

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._p_idx = 0
        self._d_idx = 0

    def schedule_prefill_req(self, req: Request, now: float) -> int:
        ids = sorted(self._require(self.pools.members(Pool.PREFILL)
                                   or self.pools.active_ids(), "prefill"))
        iid = ids[self._p_idx % len(ids)]
        self._p_idx += 1
        self._account(iid, now, req.input_len)
        return iid

    def schedule_decode_req(self, req: Request, now: float) -> int:
        ids = sorted(self._require(self.pools.members(Pool.DECODE)
                                   or self.pools.active_ids(), "decode"))
        iid = ids[self._d_idx % len(ids)]
        self._d_idx += 1
        return iid


class ColocatedPolicy(BasePolicy):
    """vLLM-style PD-colocated serving: every instance runs chunked prefill +
    decode-prioritized continuous batching; a request decodes where it
    prefilled (no KV transfer ever)."""

    name = "colocated"

    def schedule_prefill_req(self, req: Request, now: float) -> int:
        # ACTIVE only: a colocated cluster under faults (§8) must not place
        # work on a crashed instance
        ids = self._require(self.pools.active_ids(), "prefill")
        # least-loaded by combined queue: predicted prefill drain + decode load
        def load(i):
            s = self.monitor.get(i)
            return (max(self.prefill_ready_at[i] - now, 0.0)
                    + s.running_tokens * self.slo.tpot / 4096.0)
        iid = min(ids, key=load)
        self._account(iid, now, req.input_len)
        return iid

    def schedule_decode_req(self, req: Request, now: float) -> int:
        pi = req.prefill_instance
        if self.pools.is_schedulable(pi):
            return pi
        # the prefill instance crashed between o_1 and placement: fall back
        # to the least-loaded live instance instead of decoding on a corpse
        return self._min_tokens(self._require(self.pools.active_ids(),
                                              "decode"))


POLICIES = {
    "arrow": ArrowPolicy,
    "arrow_proactive": ArrowPolicy,    # + SchedulerConfig.proactive=True
    "arrow_elastic": ArrowElasticPolicy,
    "arrow_deflect": ArrowDeflectPolicy,
    "minimal_load": MinimalLoadPolicy,
    "round_robin": RoundRobinPolicy,
    "colocated": ColocatedPolicy,
}
