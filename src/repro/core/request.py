"""Request model: a request is split into PREFILL and DECODE *sub-requests*
(the paper's key reframing — phase is a property of the request, §5.2)."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class Phase(enum.Enum):
    PREFILL = "prefill"
    DECODE = "decode"


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    MIGRATING = "migrating"          # waiting for / doing KV-cache transfer
    DECODING = "decoding"
    FINISHED = "finished"
    REJECTED = "rejected"            # admission turned it away (§10): the
    #                                  request never entered scheduling or
    #                                  KV accounting and never will


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding controls (DESIGN.md §12). ``temperature<=0`` is
    exact greedy argmax — provably the pre-sampling token path. ``seed``
    overrides the run seed recorded in ``ServeReport`` for this request
    only; the effective key stream is derived statelessly from
    ``(seed, rid, absolute position)``, which is what makes sampled streams
    replayable bit-for-bit across runs, migration and crash recovery."""

    temperature: float = 0.0
    top_p: float = 1.0
    seed: Optional[int] = None

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


@dataclass
class Request:
    rid: int
    arrival: float                   # seconds
    input_len: int
    output_len: int                  # trace ground truth (sim) / max tokens (engine)
    state: RequestState = RequestState.QUEUED

    # decoding controls (DESIGN.md §12); None ≡ greedy argmax (the pre-PR-8
    # behavior, byte-identical)
    sampling: Optional[SamplingParams] = None

    # multi-turn lineage (DESIGN.md §7): a follow-up turn extends its
    # session's token stream; dispatch is gated on the parent finishing and
    # the prefix cache can reuse the parent's retained KV.
    session_id: Optional[int] = None
    parent_rid: Optional[int] = None
    history_len: int = 0             # tokens shared with the parent's context

    # multi-tenancy (DESIGN.md §10): which client submitted this request;
    # None means the implicit single tenant (admission treats it as
    # "anonymous")
    tenant_id: Optional[str] = None

    # scheduling bookkeeping
    prefill_instance: Optional[int] = None
    decode_instance: Optional[int] = None
    cached_len: int = 0              # prefix tokens served from cache (§7)

    # crash recovery (DESIGN.md §8): a request whose KV was lost re-prefills
    # its prompt plus the already-streamed tokens (minus the last, which
    # seeds the next decode step) — ``input_len`` absorbs those tokens so
    # the recovery prefill is costed and scheduled like any other, and
    # ``resumed_tokens`` (the stream length at recovery) tells the runtime
    # not to re-emit anything the user already saw.
    resumed_tokens: int = 0
    recoveries: int = 0              # times this request was crash-recovered

    # measured outcomes
    first_token_time: Optional[float] = None      # absolute time of o_1
    finish_time: Optional[float] = None
    token_times: list = field(default_factory=list)  # absolute times of o_2..o_m

    # progress
    prefill_done_tokens: int = 0     # chunked-prefill progress
    decoded_tokens: int = 0          # output tokens produced so far (incl. o_1)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Eq. (3): decode-phase time / (m-1); 0 when m == 1."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        if self.output_len <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (self.output_len - 1)

    def meets_slo(self, slo) -> bool:
        t1 = self.ttft
        t2 = self.tpot
        if t1 is None or t2 is None:
            return False
        return t1 <= slo.ttft and t2 <= slo.tpot
