"""Unified online serving API: the ``ServingSystem`` protocol both the
discrete-event :class:`repro.sim.Simulator` and the real-compute
:class:`repro.engine.ArrowEngineCluster` implement.

Semantics are open-loop and streaming (DESIGN.md §1):

  * ``submit(request) -> RequestHandle`` registers a request that *arrives* at
    ``request.arrival`` on the system's clock; it does not block.
  * ``step()`` performs one unit of work (one event / one cooperative pass);
    ``run_until(t)`` advances the system's clock to ``t``; ``drain()`` runs
    until every submitted request finished (or a timeout expires).
  * Tokens are delivered as they land through per-request ``on_token``
    callbacks, so TTFT/TPOT are observable online rather than reconstructed
    from a batch result.
  * Each request carries an SLO tier (``interactive``/``standard``/``batch``)
    scaling the system's base SLO; attainment is reported per tier.

The batch entrypoints ``Simulator.run(trace)`` and
``ArrowEngineCluster.serve(reqs)`` remain as thin deprecation shims over this
API (DESIGN.md §1.3).
"""
from __future__ import annotations

import abc
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.clock import Clock
from repro.core.request import Request
from repro.core.slo import SLO


@dataclass(frozen=True)
class SLOTier:
    """Per-request SLO class: a multiplier over the system's base SLO."""

    name: str
    ttft_scale: float = 1.0
    tpot_scale: float = 1.0

    def apply(self, base: SLO) -> SLO:
        return SLO(base.ttft * self.ttft_scale, base.tpot * self.tpot_scale)


TIERS: Dict[str, SLOTier] = {
    "interactive": SLOTier("interactive", ttft_scale=0.5, tpot_scale=0.5),
    "standard": SLOTier("standard"),
    "batch": SLOTier("batch", ttft_scale=4.0, tpot_scale=4.0),
}


class UndispatchableError(RuntimeError):
    """``drain()`` can never complete: requests are waiting for an ACTIVE
    instance but every instance is FAILED or RETIRING and none is WARMING —
    nothing will ever accept them. Raised instead of hanging until the
    drain timeout (DESIGN.md §8). ``rids`` lists the stranded requests."""

    def __init__(self, rids, pools):
        self.rids = sorted(rids)
        super().__init__(
            f"drain() cannot complete: no ACTIVE or WARMING instance will "
            f"ever accept rids {self.rids} "
            f"({len(pools.retiring_ids())} retiring, "
            f"{len(pools.failed_ids())} failed); scale up first or use an "
            f"elastic policy")

# on_token(handle, token_id_or_None, t): token ids are real ints on the
# engine; the simulator streams ``None`` placeholders (it models timing, not
# content). ``t`` is the system-clock time the token landed.
TokenCallback = Callable[["RequestHandle", Optional[int], float], None]
FinishCallback = Callable[["RequestHandle"], None]


@dataclass
class RequestHandle:
    """Live view of one submitted request."""

    req: Request
    slo: SLO                               # tier-scaled effective SLO
    tier: str = "standard"
    on_token: Optional[TokenCallback] = None
    on_finish: Optional[FinishCallback] = None
    tokens: List[Optional[int]] = field(default_factory=list)
    # set iff admission turned the request away (a tenants.Rejected with
    # reason + retry_after); the request is terminal and never scheduled
    rejection: Optional[object] = None

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def tenant_id(self) -> Optional[str]:
        return self.req.tenant_id

    @property
    def rejected(self) -> bool:
        return self.rejection is not None

    @property
    def done(self) -> bool:
        return self.req.finish_time is not None

    @property
    def ttft(self) -> Optional[float]:
        return self.req.ttft

    @property
    def tpot(self) -> Optional[float]:
        return self.req.tpot

    def meets_slo(self) -> bool:
        return self.req.meets_slo(self.slo)


@dataclass
class ServeReport:
    """One reporting path shared by sim and engine runs."""

    handles: List[RequestHandle]
    flip_detail: Dict[str, int] = field(default_factory=dict)
    decisions: Dict[str, int] = field(default_factory=dict)
    duration: float = 0.0
    # elastic-scaling accounting (DESIGN.md §6): instance_seconds,
    # n_instances and — under an elastic policy — scale_ups/scale_downs.
    scaling: Dict[str, float] = field(default_factory=dict)
    # prefix-cache accounting (DESIGN.md §7): hits/lookups, cached_tokens,
    # saved_prefill_s/saved_prefill_frac, evictions, invalidations. Empty
    # when the cache is off.
    prefix: Dict[str, float] = field(default_factory=dict)
    # fault accounting (DESIGN.md §8): crashes, slowdowns, requests
    # recovered/lost, kv_tokens_lost, re_prefill_tokens, migrations_aborted,
    # replacements. Empty when no fault ever fired.
    faults: Dict[str, float] = field(default_factory=dict)
    # self-healing accounting (DESIGN.md §14): quarantines, restores,
    # escalations, xfer_retries/drops/corrupt/failures, preemptions,
    # preempt_refused. Empty when the health layer is off or never acted —
    # default reports stay byte-identical to pre-health builds.
    health: Dict[str, float] = field(default_factory=dict)
    # admission accounting (DESIGN.md §10): admitted, deferred, retries,
    # rejected, shed. Empty when admission control is off.
    admission: Dict[str, float] = field(default_factory=dict)
    # cross-pool deflection accounting (DESIGN.md §11): requests/tokens
    # deflected, chunks/chunk tokens executed, decode_pickups,
    # interference_s, refused_* by reason. Empty when deflection is unarmed
    # or never acted (ratio=0 control stays byte-identical).
    deflection: Dict[str, float] = field(default_factory=dict)
    # per-tenant surface (DESIGN.md §10): tenant_id -> {tier, weight,
    # submitted, admitted, deferred, rejected, shed, finished, attainment,
    # p99_ttft, p99_tpot, credits, violation_ewma}. Empty when no tenant
    # registry is attached.
    per_tenant: Dict[str, Dict[str, float]] = field(default_factory=dict)
    # replayable-sampling accounting (DESIGN.md §12): seed (the run seed
    # every slot key is folded from — replaying the same trace with this
    # seed reproduces every stream bit-for-bit), sampled_requests. Empty
    # when every request decoded greedily (so greedy reports stay
    # byte-identical to pre-sampling builds).
    sampling: Dict[str, float] = field(default_factory=dict)
    # self-speculative decoding accounting (DESIGN.md §12): rounds, drafted,
    # accepted, acceptance, emitted. Empty when speculation is off.
    speculation: Dict[str, float] = field(default_factory=dict)

    #: every field name ``summary()`` can emit, in emission order —
    #: tools/check_docs.py diffs this against DESIGN.md's report-schema
    #: table, so extending summary() without documenting it fails CI.
    SUMMARY_FIELDS = ("finished", "p50_ttft", "p90_ttft", "p90_tpot",
                      "attainment", "flips", "scale_ups", "scale_downs",
                      "instance_s", "prefix_hits", "saved_prefill",
                      "crashes", "recovered", "re_prefill_toks",
                      "quarantines", "restores", "xfer_retries", "preempted",
                      "admitted", "rejected", "shed", "deflected",
                      "refused", "seed", "sampled", "spec_emitted",
                      "spec_accept", "tenants")

    @property
    def flips(self) -> int:
        return self.flip_detail.get("total", 0)

    @property
    def n_total(self) -> int:
        return len(self.handles)

    @property
    def n_finished(self) -> int:
        return sum(1 for h in self.handles if h.done)

    @property
    def attainment(self) -> float:
        """Fraction of *all* submitted requests finishing inside their
        (tier-scaled) SLO — unfinished requests count as misses."""
        if not self.handles:
            return 1.0
        return sum(1 for h in self.handles if h.meets_slo()) / len(self.handles)

    def attainment_by_tier(self, tiers: Optional[List[str]] = None,
                           ) -> Dict[str, Optional[float]]:
        """Attainment per SLO tier. By default only tiers that actually
        received requests appear; pass ``tiers`` to force specific rows,
        where a tier with zero requests maps to ``None`` (rendered "n/a" by
        callers, never a ZeroDivisionError)."""
        out: Dict[str, Optional[float]] = {}
        names = (sorted({h.tier for h in self.handles}) if tiers is None
                 else list(tiers))
        for tier in names:
            hs = [h for h in self.handles if h.tier == tier]
            out[tier] = (sum(1 for h in hs if h.meets_slo()) / len(hs)
                         if hs else None)
        return out

    def percentile(self, metric: str, q: float) -> Optional[float]:
        """q-quantile of ``metric`` ('ttft'/'tpot') over the requests where
        it is already observable (TTFT exists once o_1 streamed, TPOT once
        finished), using standard nearest-rank (ceil(q·n), 1-based);
        ``None`` when no sample exists yet (callers print 'n/a', never
        crash)."""
        vals = sorted(v for h in self.handles
                      if (v := getattr(h, metric)) is not None)
        if not vals:
            return None
        rank = max(math.ceil(q * len(vals)), 1)       # 1-based nearest rank
        return vals[min(rank, len(vals)) - 1]

    def summary(self) -> str:
        def ms(v: Optional[float]) -> str:
            return "n/a" if v is None else f"{v * 1e3:.1f}ms"

        s = (f"finished {self.n_finished}/{self.n_total} "
             f"p50_ttft={ms(self.percentile('ttft', 0.5))} "
             f"p90_ttft={ms(self.percentile('ttft', 0.9))} "
             f"p90_tpot={ms(self.percentile('tpot', 0.9))} "
             f"attainment={self.attainment:.2f} flips={self.flips}")
        if "scale_ups" in self.scaling:
            s += (f" scale_ups={self.scaling['scale_ups']:.0f}"
                  f" scale_downs={self.scaling['scale_downs']:.0f}"
                  f" instance_s={self.scaling['instance_seconds']:.0f}")
        if self.prefix:
            s += (f" prefix_hits={self.prefix['hits']:.0f}"
                  f"/{self.prefix['lookups']:.0f}"
                  f" saved_prefill={self.prefix['saved_prefill_frac']:.0%}")
        if self.faults:
            s += (f" crashes={self.faults['crashes']:.0f}"
                  f" recovered={self.faults['requests_recovered']:.0f}"
                  f" re_prefill_toks={self.faults['re_prefill_tokens']:.0f}")
        if self.health:
            s += (f" quarantines={self.health.get('quarantines', 0):.0f}"
                  f" restores={self.health.get('restores', 0):.0f}"
                  f" xfer_retries={self.health.get('xfer_retries', 0):.0f}"
                  f" preempted={self.health.get('preemptions', 0):.0f}")
        if self.admission:
            s += (f" admitted={self.admission.get('admitted', 0):.0f}"
                  f" rejected={self.admission.get('rejected', 0):.0f}"
                  f" shed={self.admission.get('shed', 0):.0f}")
        if self.deflection:
            refused = sum(v for k, v in self.deflection.items()
                          if k.startswith("refused_"))
            s += (f" deflected="
                  f"{self.deflection.get('requests_deflected', 0):.0f}"
                  f" refused={refused:.0f}")
        if self.sampling:
            s += (f" seed={self.sampling.get('seed', 0):.0f}"
                  f" sampled={self.sampling.get('sampled_requests', 0):.0f}")
        if self.speculation:
            s += (f" spec_emitted={self.speculation.get('emitted', 0):.0f}"
                  f" spec_accept={self.speculation.get('acceptance', 0):.2f}")
        if self.per_tenant:
            s += f" tenants={len(self.per_tenant)}"
        return s

    def tenant_summary(self) -> str:
        """One line per tenant (DESIGN.md §10); tenants with zero finished
        requests render 'n/a' metrics, never crash."""
        def fmt(v, spec=".2f", scale=1.0, suffix=""):
            return "n/a" if v is None else f"{v * scale:{spec}}{suffix}"

        lines = []
        for tid in sorted(self.per_tenant):
            t = self.per_tenant[tid]
            lines.append(
                f"  {tid:<12} tier={t.get('tier', '?'):<11} "
                f"att={fmt(t.get('attainment'))} "
                f"p99_ttft={fmt(t.get('p99_ttft'), '.1f', 1e3, 'ms')} "
                f"p99_tpot={fmt(t.get('p99_tpot'), '.1f', 1e3, 'ms')} "
                f"adm={t.get('admitted', 0):.0f}/{t.get('submitted', 0):.0f} "
                f"rej={t.get('rejected', 0):.0f} "
                f"shed={t.get('shed', 0):.0f} "
                f"credits={t.get('credits', 0.0):.1f}")
        return "\n".join(lines)


class ServingSystem(abc.ABC):
    """Online, streaming serving front-end over a pool of stateless instances.

    Implementations: ``repro.sim.Simulator`` (VirtualClock) and
    ``repro.engine.ArrowEngineCluster`` (WallClock).
    """

    clock: Clock

    @abc.abstractmethod
    def submit(self, req: Request, *, prompt=None, tier: str = "standard",
               tenant_id: Optional[str] = None,
               on_token: Optional[TokenCallback] = None,
               on_finish: Optional[FinishCallback] = None) -> RequestHandle:
        """Register ``req`` to arrive at ``req.arrival`` (system-clock
        seconds). ``prompt`` is the token array for real-compute backends;
        backends that only model timing ignore it, and the engine synthesizes
        a deterministic prompt of ``req.input_len`` tokens when omitted.
        ``tenant_id`` attributes the request to a registered tenant (falls
        back to ``req.tenant_id``, then to the implicit single tenant); when
        the tenant declares an SLO tier it overrides the default ``tier``."""

    @abc.abstractmethod
    def step(self) -> bool:
        """Perform one unit of work. Returns False once fully idle (no queued
        events / no pending or live requests)."""

    @abc.abstractmethod
    def run_until(self, t: float) -> None:
        """Advance the system clock to ``t``, performing all due work."""

    @abc.abstractmethod
    def drain(self, *, timeout: Optional[float] = None) -> ServeReport:
        """Run until every submitted request finished, or ``timeout`` system-
        clock seconds elapsed. Returns the report either way."""

    @abc.abstractmethod
    def report(self) -> ServeReport:
        """Snapshot metrics over everything submitted so far."""


def replay_trace(system: ServingSystem, trace: List[Request], *,
                 tier: str = "standard", time_scale: float = 1.0,
                 on_token: Optional[TokenCallback] = None,
                 on_finish: Optional[FinishCallback] = None,
                 ) -> List[RequestHandle]:
    """Submit fresh copies of ``trace`` through the unified API, so the same
    trace object can replay through several systems (sim-vs-engine runs)
    without sharing mutable Request state. Returns handles in trace order."""
    handles = []
    for r in trace:
        req = Request(rid=r.rid, arrival=r.arrival * time_scale,
                      input_len=r.input_len, output_len=r.output_len,
                      session_id=r.session_id, parent_rid=r.parent_rid,
                      history_len=r.history_len, tenant_id=r.tenant_id,
                      sampling=r.sampling)
        handles.append(system.submit(req, tier=tier, on_token=on_token,
                                     on_finish=on_finish))
    return handles
