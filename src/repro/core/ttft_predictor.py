"""TTFT predictor (§5.3): profile each instance's prefill time as a quadratic
in input length (prefill compute is O(L²) attention + O(L) MLP), fit once at
cluster launch, then predict queueing + compute time for any queue state.

For SSM/hybrid architectures the quadratic coefficient fits ≈ 0 and the
predictor degrades gracefully to linear — no code change (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


class TTFTPredictor:
    def __init__(self, coeffs: Sequence[float] = (0.0, 0.0, 0.0)):
        self.coeffs = np.asarray(coeffs, np.float64)   # (a, b, c): a L² + b L + c

    @classmethod
    def fit(cls, samples: Sequence[Tuple[int, float]]) -> "TTFTPredictor":
        """samples: (input_len, measured prefill seconds)."""
        L = np.asarray([s[0] for s in samples], np.float64)
        t = np.asarray([s[1] for s in samples], np.float64)
        # least squares on [L², L, 1]; clip to non-negative prediction later
        A = np.stack([L * L, L, np.ones_like(L)], axis=1)
        coeffs, *_ = np.linalg.lstsq(A, t, rcond=None)
        if coeffs[0] < 0.0:
            # noisy / short-context samples can fit a < 0, which makes
            # predict_chunk non-monotone (suffix chunks silently clamp to 0
            # and corrupt prefix-affinity and deflection charging) — prefill
            # compute can only be superlinear, so refit linear instead
            lin, *_ = np.linalg.lstsq(A[:, 1:], t, rcond=None)
            coeffs = np.concatenate([[0.0], lin])
        return cls(coeffs)

    def predict(self, input_len: int) -> float:
        a, b, c = self.coeffs
        return float(max(a * input_len * input_len + b * input_len + c, 0.0))

    def predict_chunk(self, start: int, length: int) -> float:
        """Time for a chunked-prefill slice [start, start+length) of a longer
        prompt: the attention term is quadratic, so a chunk's cost is the
        difference of the cumulative quadratic."""
        return max(self.predict(start + length) - self.predict(start), 0.0)


class PerInstancePredictor:
    """Heterogeneous clusters (paper §8): one fitted quadratic per instance.
    Exposes the same ``predict`` API with an optional instance id; the global
    scheduler passes the candidate instance when available."""

    def __init__(self, default: TTFTPredictor):
        self.default = default
        self.per_instance = {}

    @classmethod
    def fit_per_instance(cls, samples_by_iid) -> "PerInstancePredictor":
        if not samples_by_iid:
            raise ValueError(
                "fit_per_instance needs profiling samples for at least one "
                "instance; got an empty samples_by_iid mapping")
        fitted = {iid: TTFTPredictor.fit(s) for iid, s in samples_by_iid.items()}
        any_pred = next(iter(fitted.values()))
        obj = cls(any_pred)
        obj.per_instance = fitted
        return obj

    def for_instance(self, iid) -> TTFTPredictor:
        return self.per_instance.get(iid, self.default)

    def predict(self, input_len: int, iid=None) -> float:
        return self.for_instance(iid).predict(input_len)

    def predict_chunk(self, start: int, length: int, iid=None) -> float:
        return self.for_instance(iid).predict_chunk(start, length)
