"""Self-healing layer (DESIGN.md §14): straggler detection + quarantine.

Arrow's scheduler assumes every ACTIVE instance decodes at roughly the fleet
rate; a lagging instance (§3.2 of the paper) silently burns the SLOs of every
resident it holds because nothing *detects* degradation — PR 4 only injects
it. The HealthMonitor closes that loop with a robust peer comparison over the
signal the InstanceMonitor already maintains:

  * **score** — each instance's ``avg_token_interval`` (sliding TPOT window)
    against the *peer median* across ACTIVE instances with data. Medians are
    robust to the straggler itself dragging the baseline, unlike means.
  * **quarantine** — sustained deviation (``straggler_factor``× median for
    ≥ ``sustain_s`` seconds, with hysteresis: the sustain clock only resets
    once the score drops below ``clear_factor``× median) moves the instance
    to the DEGRADED lifecycle state: never schedulable for new work, decode
    residents drained away through the FCFS migration manager
    (core/runtime.py ``quarantine_instance``).
  * **probation** — a drained DEGRADED instance produces no new samples, so
    the monitor re-admits it after ``probation_s`` with a cleared interval
    window and watches: if the slowdown persists it re-trips detection and
    returns to quarantine, all within the same *episode*.
  * **escalation** — an episode open for ≥ ``deadline_s`` (the instance kept
    relapsing) is treated as a hard fault: ``fail_instance`` tears it down
    and the autoscaler provisions a replacement. An episode closes once the
    instance stays clean for ``sustain_s`` after re-admission.

The same config also carries the transfer retry ladder and the SLO-aware
preemption knobs (both implemented in core/runtime.py) so one ``--health``
surface arms the whole self-healing layer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.pools import Lifecycle


def _median(values):
    xs = sorted(values)
    n = len(xs)
    mid = n // 2
    return xs[mid] if n % 2 else 0.5 * (xs[mid - 1] + xs[mid])


@dataclass(frozen=True)
class HealthConfig:
    """Knobs for the self-healing layer. Defaults favour acting only on
    unambiguous stragglers; see docs/OPERATOR.md §9 for tuning."""

    # --- straggler detection / quarantine (HealthMonitor) ---
    straggler_factor: float = 3.0   # k× peer median arms the sustain clock
    clear_factor: float = 1.5      # below this × median clears it (hysteresis)
    sustain_s: float = 2.0         # deviation must persist this long
    probation_s: float = 4.0       # quarantine dwell before re-admission
    deadline_s: float = 30.0       # episode older than this → fail_instance
    min_peers: int = 3             # baselines needed before trusting a median
    # --- transfer retry ladder (core/runtime.py) ---
    xfer_retries: int = 3          # bounded retry attempts per transfer
    xfer_backoff_s: float = 0.25   # first retry delay; doubles per attempt
    xfer_timeout_s: float = 30.0   # per-transfer timeout (async sim path)
    # --- SLO-aware preemption at the §5.4 memory gate ---
    preemption: bool = False       # arm victim preemption when the gate blocks
    preempt_limit: int = 2         # max preemptions per instance per window
    preempt_window_s: float = 10.0


class HealthMonitor:
    """Peer-median straggler detector driving quarantine/probation/escalation
    through the runtime. Ticks from ``collect_stats`` right after the scrape,
    so both backends see identical (post-scrape) signals at a barrier."""

    def __init__(self, runtime, cfg: HealthConfig):
        self.runtime = runtime
        self.cfg = cfg
        self._slow_since: Dict[int, float] = {}     # sustain clock per iid
        self._episode_start: Dict[int, float] = {}  # first quarantine of run
        self._probation_until: Dict[int, float] = {}
        self._restored_at: Dict[int, float] = {}

    # ------------------------------------------------------------ lifecycle
    def forget(self, iid: int) -> None:
        """Instance left the cluster (failed/removed): drop its state."""
        self._slow_since.pop(iid, None)
        self._episode_start.pop(iid, None)
        self._probation_until.pop(iid, None)
        self._restored_at.pop(iid, None)

    # ----------------------------------------------------------------- tick
    def tick(self, now: float) -> None:
        self._detect(now)
        self._probation(now)
        self._close_episodes(now)

    def _detect(self, now: float) -> None:
        rt = self.runtime
        cfg = self.cfg
        actives = rt.pools.active_ids()
        scores = {i: rt.monitor.avg_token_interval(i) for i in actives}
        scores = {i: v for i, v in scores.items() if v > 0.0}
        if len(scores) < cfg.min_peers:
            return
        med = _median(scores.values())
        if med <= 0.0:
            return
        for iid, iv in sorted(scores.items()):
            if iv >= cfg.straggler_factor * med:
                self._slow_since.setdefault(iid, now)
            elif iv < cfg.clear_factor * med:
                self._slow_since.pop(iid, None)
            # in the hysteresis band: keep the sustain clock running
            since = self._slow_since.get(iid)
            if since is None or now - since < cfg.sustain_s:
                continue
            # never quarantine the last evacuation target
            if len(rt.pools.active_ids()) <= 1:
                continue
            self._slow_since.pop(iid, None)
            self._episode_start.setdefault(iid, now)
            self._probation_until[iid] = now + cfg.probation_s
            rt.quarantine_instance(iid, now)

    def _probation(self, now: float) -> None:
        rt = self.runtime
        for iid in sorted(rt.pools.degraded_ids()):
            start = self._episode_start.get(iid, now)
            if now - start >= self.cfg.deadline_s:
                # kept relapsing past the deadline: hard-fail and replace
                rt.escalate_unhealthy(iid, now)
            elif now >= self._probation_until.get(iid, 0.0):
                self._probation_until.pop(iid, None)
                self._restored_at[iid] = now
                rt.restore_instance(iid, now)

    def _close_episodes(self, now: float) -> None:
        rt = self.runtime
        for iid in list(self._episode_start):
            if rt.pools.lifecycle_of(iid) is not Lifecycle.ACTIVE:
                continue
            if iid in self._slow_since:
                continue
            clean_since = self._restored_at.get(iid,
                                                self._episode_start[iid])
            if now - clean_since >= self.cfg.sustain_s:
                self._episode_start.pop(iid, None)
                self._restored_at.pop(iid, None)
