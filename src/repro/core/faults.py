"""Failure injection (DESIGN.md §8): scripted or seed-deterministic crash
and slowdown events driven through the shared runtime on both backends.

Arrow's goodput claims rest on stateless instances that can change roles at
any time (§4); a production cluster additionally loses instances outright.
A ``FaultPlan`` is a timed script of fault events; the ``FaultInjector``
fires each event when the system clock passes its time — the simulator arms
an exact virtual-clock event per fault, the engine polls at every
cooperative pass — and routes it to ``RuntimeCore.fail_instance`` (crash:
substrate and resident KV lost, lost requests recovered) or
``RuntimeCore.apply_slowdown`` (a lagging instance, §3.2).

Event grammar (``--fault-plan``, ``FaultPlan.parse``)::

    crash@20                    crash a seed-chosen ACTIVE instance at t=20
    crash@45:target=3           crash instance 3 at t=45
    slow@60:factor=4,duration=5 run 4x slower for 5 s from t=60
    droptransfer@30:p=0.5,duration=10   each transfer attempt started in
                                the window fails with probability p (§14)
    netslow@30:factor=8,duration=10     transfers run 8x slower (§14)

Events are separated by ``;``. Target selection without an explicit
``target=`` draws from the sorted ACTIVE set with the plan's seeded RNG, so
the same plan picks the same victims given the same membership history —
deterministic on the simulator, reproducible on the engine. The transfer
faults (droptransfer/netslow) are cluster-wide interconnect windows — no
victim is drawn, so adding them to a plan never perturbs the RNG stream of
its targeted events.

``recovery=False`` turns the plan into the no-recovery strawman
(``benchmarks/bench_faults.py``): crashed instances still tear down, but
their in-flight requests are stranded instead of re-dispatched.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.pools import Lifecycle

KINDS = ("crash", "slow", "droptransfer", "netslow")
CLUSTER_KINDS = ("droptransfer", "netslow")   # interconnect-wide: no victim


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault."""

    t: float                       # system-clock seconds
    kind: str = "crash"            # "crash"|"slow"|"droptransfer"|"netslow"
    target: Optional[int] = None   # iid; None = seed-deterministic pick
    factor: float = 4.0            # slow/netslow: time multiplier
    duration: float = 5.0          # slow/droptransfer/netslow: window length
    p: float = 0.5                 # droptransfer: per-attempt drop probability

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {KINDS}")
        if self.t < 0:
            raise ValueError(f"fault event at t={self.t}: time must be >= 0")
        for name, v in (("factor", self.factor), ("duration", self.duration),
                        ("p", self.p)):
            if v <= 0:
                raise ValueError(
                    f"fault event {self.kind}@{self.t:g}: {name}={v} must "
                    f"be > 0 (a non-positive {name} would never fire or "
                    f"divide by zero downstream)")


@dataclass(frozen=True)
class FaultPlan:
    """An immutable script of fault events plus the victim-selection seed."""

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0
    recovery: bool = True          # False: no-recovery strawman

    @classmethod
    def parse(cls, spec: str, *, seed: int = 0,
              recovery: bool = True) -> "FaultPlan":
        """Parse the ``--fault-plan`` grammar (module docstring)."""
        events = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            head, _, opts = part.partition(":")
            kind, _, t_str = head.partition("@")
            if not t_str:
                raise ValueError(f"fault event {part!r}: expected kind@time")
            kw = {}
            for opt in filter(None, (o.strip() for o in opts.split(","))):
                k, _, v = opt.partition("=")
                if k == "target":
                    kw["target"] = int(v)
                elif k in ("factor", "duration", "p"):
                    kw[k] = float(v)
                else:
                    raise ValueError(f"fault event {part!r}: unknown "
                                     f"option {k!r}")
            events.append(FaultEvent(t=float(t_str), kind=kind, **kw))
        return cls(events=tuple(events), seed=seed, recovery=recovery)

    @classmethod
    def random_crashes(cls, n: int, horizon: float, *, seed: int = 0,
                       recovery: bool = True) -> "FaultPlan":
        """``n`` crashes at seed-deterministic times inside the middle 80%
        of ``horizon`` (the edges are warm-up/drain-down)."""
        rng = np.random.default_rng(seed)
        times = sorted(rng.uniform(0.1 * horizon, 0.9 * horizon, size=n))
        return cls(events=tuple(FaultEvent(t=float(t)) for t in times),
                   seed=seed, recovery=recovery)


class FaultInjector:
    """Fires a ``FaultPlan``'s events against a ``RuntimeCore`` as the
    system clock passes them. Backends drive ``poll(now)``; the simulator
    additionally arms one exact virtual-clock event per fault time so a
    crash lands at precisely its scripted instant."""

    def __init__(self, plan: FaultPlan, runtime):
        self.plan = plan
        self.runtime = runtime
        self._events = sorted(plan.events, key=lambda e: e.t)
        self._idx = 0
        self._rng = np.random.default_rng(plan.seed)
        # (fire time, event, victim iid or None when skipped)
        self.fired: List[Tuple[float, FaultEvent, Optional[int]]] = []

    def event_times(self) -> List[float]:
        return [e.t for e in self._events]

    @property
    def exhausted(self) -> bool:
        return self._idx >= len(self._events)

    def poll(self, now: float) -> int:
        """Fire every not-yet-fired event with ``t <= now``; returns the
        number fired (skipped events count — they are consumed)."""
        n = 0
        while self._idx < len(self._events) and \
                self._events[self._idx].t <= now:
            ev = self._events[self._idx]
            self._idx += 1
            self._fire(ev, now)
            n += 1
        return n

    # ------------------------------------------------------------ internal
    def _pick_target(self, ev: FaultEvent) -> Optional[int]:
        rt = self.runtime
        if ev.target is not None:
            alive = ev.target in rt.pools.all_ids() and \
                rt.pools.lifecycle_of(ev.target) is not Lifecycle.FAILED
            return ev.target if alive else None
        eligible = sorted(rt.pools.active_ids())
        if not eligible:
            return None
        return int(eligible[int(self._rng.integers(len(eligible)))])

    def _fire(self, ev: FaultEvent, now: float) -> None:
        rt = self.runtime
        if ev.kind in CLUSTER_KINDS:          # interconnect-wide: no victim
            if ev.kind == "droptransfer":
                rt.apply_transfer_drop(ev.p, now + ev.duration)
            else:
                rt.apply_netslow(ev.factor, now + ev.duration)
            self.fired.append((now, ev, None))
            return
        iid = self._pick_target(ev)
        if iid is None:                       # victim gone / nothing ACTIVE
            rt.fault_stats["skipped_events"] += 1
            self.fired.append((now, ev, None))
            return
        if ev.kind == "crash":
            rt.fail_instance(iid, now, recover=self.plan.recovery)
        else:
            rt.apply_slowdown(iid, ev.factor, now + ev.duration)
        self.fired.append((now, ev, iid))
