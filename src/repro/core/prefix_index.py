"""Prefix-aware KV reuse (DESIGN.md §7): a token-block radix index over the
cluster's *retained* KV caches.

Multi-turn traffic re-prefills a growing shared history every turn — pure
recomputation. This module tracks which instance already holds the KV of a
prompt prefix so the global scheduler can route the follow-up turn there and
prefill only the uncached suffix (the Eq. (2) accounting then charges
``TTFTPredictor.predict_chunk(cached, L - cached)`` instead of the full
quadratic).

Structure
---------
Prompts are abstracted to chains of **block keys** (one key per
``block_size`` tokens). Two key schemes share the index:

* **lineage keys** — ``(namespace, block_idx)`` for requests that carry a
  ``session_id``: turn *N*'s prompt literally extends the session's token
  stream, so block *b* of any turn denotes the same content. The simulator
  (which models timing, not content) relies on these; the engine uses them
  too for session requests, after constructing the prompt from the real
  session transcript so the claim is true in compute.
* **content keys** — a rolling hash chain over real token blocks, for
  engine requests without a session (generic prefix caching: identical
  system prompts hit even across unrelated requests).

The index itself is a radix trie over block keys. Each node holds the set
of (instance, rid) entries whose retained KV covers the prefix ending at
that node; a lookup walks the query chain to the deepest non-empty node and
returns one candidate per instance there (all with the same cached depth).

Entries are **ref-count pinned** while a new request is copying/extending
from them (eviction and invalidation must not free KV mid-copy — an
invalidated-but-pinned entry is *doomed*: it leaves the trie immediately
and its KV is freed on the last unpin). Per-instance eviction is LRU over
unpinned entries, driven by the backends when memory pressure blocks
admission (sim: migration admission; engine: slot exhaustion).

The manager never touches KV itself: freeing goes through a release
callback the runtime supplies (sim: ``LocalScheduler.release_retained``;
engine: additionally drops the real slot).
"""
from __future__ import annotations

import zlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

DEFAULT_BLOCK = 32


# ------------------------------------------------------------------ keys
def lineage_keys(namespace, n_tokens: int, block: int = DEFAULT_BLOCK
                 ) -> Tuple:
    """Logical block keys for the first ``n_tokens`` of a session stream.
    ``namespace`` identifies the stream (``session_id``, or ``(session_id,
    epoch)`` when a backend forks a session, e.g. after truncation)."""
    return tuple((namespace, b) for b in range(n_tokens // block))


def content_keys(tokens: Sequence[int], block: int = DEFAULT_BLOCK) -> Tuple:
    """Rolling-hash chain over real token blocks: block b's key commits to
    the whole prefix [0, (b+1)·block) — every token's full 4-byte id feeds
    the hash, so distinct prefixes get distinct chains up to genuine crc32
    collisions (~2⁻³² per block pair; acceptable for a reproduction — a
    production engine would byte-compare the tokens on hit)."""
    keys = []
    h = 0
    n = len(tokens) // block
    for b in range(n):
        chunk = b"".join(int(t).to_bytes(4, "little", signed=True)
                         for t in tokens[b * block:(b + 1) * block])
        h = zlib.crc32(chunk, h)
        keys.append(("c", h, b))
    return tuple(keys)


# --------------------------------------------------------------- entries
@dataclass
class PrefixEntry:
    iid: int
    rid: int
    keys: Tuple                 # full chain this entry's KV covers
    kv_tokens: int              # resident KV size (for eviction accounting)
    pins: int = 0
    doomed: bool = False        # invalidated while pinned: free on last unpin
    last_used: int = 0          # logical LRU clock


@dataclass(frozen=True)
class PrefixHit:
    """One lookup candidate: ``iid`` holds ``cached_len`` prefix tokens of
    the query in ``rid``'s retained KV."""

    iid: int
    rid: int
    cached_len: int


class _Node:
    __slots__ = ("children", "entries")

    def __init__(self):
        self.children: Dict[object, _Node] = {}
        self.entries: set = set()        # (iid, rid) whose chain passes here


class PrefixIndex:
    """Radix trie over block keys; see module docstring."""

    def __init__(self, block: int = DEFAULT_BLOCK):
        self.block = block
        self.root = _Node()
        self.entries: Dict[Tuple[int, int], PrefixEntry] = {}  # (iid,rid)->

    def __len__(self) -> int:
        return len(self.entries)

    # ------------------------------------------------------------ insert
    def insert(self, entry: PrefixEntry) -> None:
        key = (entry.iid, entry.rid)
        if key in self.entries:            # re-insert: refresh in place
            self.remove(entry.iid, entry.rid)
        self.entries[key] = entry
        node = self.root
        for k in entry.keys:
            node = node.children.setdefault(k, _Node())
            node.entries.add(key)

    def remove(self, iid: int, rid: int) -> Optional[PrefixEntry]:
        entry = self.entries.pop((iid, rid), None)
        if entry is None:
            return None
        node, path = self.root, []
        for k in entry.keys:
            nxt = node.children.get(k)
            if nxt is None:
                break
            path.append((node, k, nxt))
            nxt.entries.discard((iid, rid))
            node = nxt
        for parent, k, child in reversed(path):   # prune empty branches
            if not child.entries and not child.children:
                del parent.children[k]
        return entry

    # ------------------------------------------------------------ lookup
    def lookup(self, keys: Sequence) -> List[PrefixHit]:
        """Walk ``keys`` to the deepest non-empty node; return one hit per
        instance there (deepest = longest cached prefix), longest first."""
        node, depth = self.root, 0
        best: Optional[Tuple[int, set]] = None
        for k in keys:
            node = node.children.get(k)
            if node is None:
                break
            depth += 1
            if node.entries:
                best = (depth, node.entries)
        if best is None:
            return []
        depth, members = best
        per_iid: Dict[int, int] = {}
        for iid, rid in members:
            e = self.entries[(iid, rid)]
            # prefer the most recently used rid per instance (ties broken
            # deterministically by rid)
            cur = per_iid.get(iid)
            if cur is None or (e.last_used, rid) > \
                    (self.entries[(iid, cur)].last_used, cur):
                per_iid[iid] = rid
        return [PrefixHit(iid, rid, depth * self.block)
                for iid, rid in sorted(per_iid.items())]


# ---------------------------------------------------------------- manager
class PrefixCacheManager:
    """Index + per-instance LRU + pin/doom lifecycle + stats.

    ``release`` is called exactly once per entry whose KV is actually freed
    (evicted, invalidated-unpinned, or doomed at last unpin) with
    ``(iid, rid, kv_tokens)``; the runtime routes it to the owning backend.
    """

    def __init__(self, block: int = DEFAULT_BLOCK,
                 release: Optional[Callable[[int, int, int], None]] = None):
        self.index = PrefixIndex(block)
        self.block = block
        self._release = release or (lambda iid, rid, kv: None)
        # per-instance LRU order: OrderedDict rid -> PrefixEntry
        self._lru: Dict[int, "OrderedDict[int, PrefixEntry]"] = {}
        self._clock = 0
        self.stats: Dict[str, float] = {
            "lookups": 0, "hits": 0, "cached_tokens": 0,
            "retained": 0, "evictions": 0, "invalidations": 0}

    # ------------------------------------------------------------ queries
    def lookup(self, keys: Optional[Sequence]) -> List[PrefixHit]:
        if not keys:
            return []
        self.stats["lookups"] += 1
        return self.index.lookup(keys)

    def entries_on(self, iid: int) -> List[PrefixEntry]:
        return list(self._lru.get(iid, {}).values())

    def retained_tokens(self, iid: int) -> int:
        return sum(e.kv_tokens for e in self._lru.get(iid, {}).values())

    # ---------------------------------------------------------- lifecycle
    def retain(self, iid: int, rid: int, keys: Sequence,
               kv_tokens: int) -> bool:
        """Register ``rid``'s resident KV on ``iid`` as a reusable prefix.
        Returns False (no-op) for empty chains — nothing to reuse."""
        keys = tuple(keys)
        if not keys:
            return False
        self._clock += 1
        entry = PrefixEntry(iid, rid, keys, kv_tokens, last_used=self._clock)
        self.index.insert(entry)
        self._lru.setdefault(iid, OrderedDict())[rid] = entry
        self._lru[iid].move_to_end(rid)
        self.stats["retained"] += 1
        return True

    def record_hit(self, hit: PrefixHit) -> None:
        self.stats["hits"] += 1
        self.stats["cached_tokens"] += hit.cached_len
        entry = self.index.entries.get((hit.iid, hit.rid))
        if entry is not None:
            self._clock += 1
            entry.last_used = self._clock
            lru = self._lru.get(hit.iid)
            if lru is not None and hit.rid in lru:
                lru.move_to_end(hit.rid)

    def pin(self, iid: int, rid: int) -> None:
        entry = self.index.entries.get((iid, rid))
        if entry is not None:
            entry.pins += 1

    def unpin(self, iid: int, rid: int) -> None:
        # the entry may already be doomed (removed from the trie); look in
        # the LRU map, which keeps doomed entries until their KV is freed
        entry = self.index.entries.get((iid, rid))
        if entry is None:
            lru = self._lru.get(iid, {})
            entry = lru.get(rid)
        if entry is None:
            return
        entry.pins = max(entry.pins - 1, 0)
        if entry.doomed and entry.pins == 0:
            self._drop(entry)

    # ---------------------------------------------------------- eviction
    def make_room(self, iid: int, tokens_needed: int) -> int:
        """Evict unpinned LRU entries on ``iid`` until ``tokens_needed``
        worth of KV has been freed (or nothing evictable remains). Returns
        the number of tokens actually freed."""
        freed = 0
        lru = self._lru.get(iid)
        if not lru:
            return 0
        for rid in list(lru):
            if freed >= tokens_needed:
                break
            entry = lru[rid]
            if entry.pins > 0 or entry.doomed:
                continue
            self.index.remove(iid, rid)
            freed += entry.kv_tokens
            self.stats["evictions"] += 1
            self._drop(entry)
        return freed

    def evict_one(self, iid: int) -> Optional[int]:
        """Evict the single LRU unpinned entry on ``iid`` (engine slot
        reclamation). Returns the evicted rid, or None."""
        lru = self._lru.get(iid)
        if not lru:
            return None
        for rid in list(lru):
            entry = lru[rid]
            if entry.pins > 0 or entry.doomed:
                continue
            self.index.remove(iid, rid)
            self.stats["evictions"] += 1
            self._drop(entry)
            return rid
        return None

    # ------------------------------------------------------- invalidation
    def invalidate_instance(self, iid: int) -> int:
        """Drop every entry on ``iid`` (pool flip / retirement — DESIGN.md
        §7). Pinned entries are doomed: out of the trie now, KV freed on the
        last unpin (a copy-on-extend may be mid-flight). Returns the number
        of entries invalidated."""
        lru = self._lru.get(iid)
        if not lru:
            return 0
        n = 0
        for rid in list(lru):
            entry = lru[rid]
            if entry.doomed:
                continue
            self.index.remove(iid, rid)
            n += 1
            if entry.pins > 0:
                entry.doomed = True
            else:
                self._drop(entry)
        if n:
            self.stats["invalidations"] += n
        return n

    # ------------------------------------------------------------ internal
    def _drop(self, entry: PrefixEntry) -> None:
        lru = self._lru.get(entry.iid)
        if lru is not None:
            lru.pop(entry.rid, None)
            if not lru:
                self._lru.pop(entry.iid, None)
        self._release(entry.iid, entry.rid, entry.kv_tokens)
