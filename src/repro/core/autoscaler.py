"""AutoScaler: elastic cluster sizing on top of Arrow's adaptive pools
(DESIGN.md §6).

Arrow's scheduler (core/global_scheduler.py) rebalances a *fixed* set of
stateless instances between the prefill and decode pools. Under diurnal load
or traffic spikes the right pool split still leaves the whole cluster either
over-provisioned or saturated, so this module closes the loop on the
instance *count*: every monitor tick it reads the same Eq. (1)/(2) signals
the scheduler already maintains and decides whether to spawn or retire an
instance, with hysteresis (patience + cooldown) and hard min/max bounds.

Signals (all dimensionless pressures in [0, ∞), 1.0 ≈ "at budget"):

  * prefill pressure — mean predicted prefill-queue drain delay (the
    scheduler's ``prefill_ready_at`` bookkeeping, Eq. 2) over the active
    prefill-capable instances, normalized by the TTFT scheduling budget
    (``ttft_threshold_frac × SLO.ttft`` — the same budget Algorithm 1
    schedules against).
  * decode pressure — total decode running-tokens over the active
    decode-capable instances, normalized by their aggregate Max Running
    Tokens (the §5.3 profiled decode capacity).
  * SLO attainment — fraction of recently finished requests that met their
    (tier-scaled) SLO, from the runtime's sliding finish window. A low
    value escalates scale-up even when instantaneous pressures look fine.

Scale-up picks the pool for the new instance by comparing the two pressures
(the Eq. (1)/(2) decision restated at cluster granularity); scale-down
retires the least-loaded instance of the slacker side and lets the runtime
drain/migrate its residual work (core/runtime.py ``begin_retire``).

The AutoScaler is backend-agnostic: it only talks to the runtime through
``scale_up(pool, now)`` / ``begin_retire(iid, now)`` and reads pools,
monitor and policy state — so the same controller drives the discrete-event
simulator and the real JAX engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.pools import Pool


def prefill_pressure(runtime, now: float) -> float:
    """Eq. (2) restated as a dimensionless pressure: mean predicted
    prefill-queue drain delay over the prefill-capable instances, normalized
    by the TTFT scheduling budget. ``inf`` when no instance can take
    prefills. Shared by the AutoScaler and the admission watermark guard
    (core/tenants.py)."""
    ids = runtime.pools.prefill_capable()
    if not ids:
        return float("inf")
    budget = max(runtime.sched_cfg.ttft_threshold_frac * runtime.slo.ttft,
                 1e-9)
    ready = getattr(runtime.policy, "prefill_ready_at", {})
    delays = [max(ready.get(i, 0.0) - now, 0.0) for i in ids]
    return (sum(delays) / len(delays)) / budget


def decode_pressure(runtime) -> float:
    """Eq. (1) restated: total decode running-tokens over the aggregate Max
    Running Tokens of the decode-capable instances. ``inf`` when no instance
    can decode."""
    ids = runtime.pools.decode_capable()
    if not ids:
        return float("inf")
    cap = len(ids) * max(runtime.sched_cfg.max_running_tokens, 1)
    running = sum(runtime.monitor.get(i).running_tokens for i in ids)
    return running / cap


@dataclass(frozen=True)
class AutoScalerConfig:
    """Elasticity knobs. Defaults favour stability over reaction speed; see
    docs/OPERATOR.md for tuning guidance."""

    min_instances: int = 2        # never retire below this many ACTIVE
    max_instances: int = 16       # never provision above this many live
    # thresholds on the normalized pressures
    prefill_up: float = 0.75      # prefill pressure triggering scale-up
    decode_up: float = 0.85       # decode utilization triggering scale-up
    down: float = 0.25            # both pressures below this → scale-down
    attainment_floor: float = 0.90   # recent SLO attainment escalating up
    # hysteresis
    up_patience: int = 2          # consecutive breach ticks before growing
    down_patience: int = 8        # consecutive slack ticks before shrinking
    cooldown_s: float = 10.0      # dead time after any scaling action
    # provisioning model
    warmup_s: float = 5.0         # modeled spawn→ready delay (simulator);
    #                               the engine's warm-up is real construction
    min_slo_samples: int = 16     # finishes needed before trusting attainment


@dataclass
class ScaleEvent:
    """One scaling action, for reports/benchmarks."""

    kind: str                     # "up" | "down"
    instance: int
    pool: Pool
    t: float
    reason: str = ""


@dataclass
class ScaleSignals:
    """One tick's observed pressures (kept for observability/tests)."""

    t: float
    prefill_pressure: float
    decode_pressure: float
    attainment: Optional[float]   # None until min_slo_samples finishes seen
    n_live: int
    n_active: int


class AutoScaler:
    """Hysteresis controller over the runtime's instance set."""

    def __init__(self, runtime, cfg: AutoScalerConfig):
        self.runtime = runtime        # RuntimeCore (pools/monitor/policy/...)
        self.cfg = cfg
        self.events: List[ScaleEvent] = []
        self.last_signals: Optional[ScaleSignals] = None
        self._up_streak = 0
        self._down_streak = 0
        self._cooldown_until = 0.0

    # ------------------------------------------------------------- signals
    def _prefill_pressure(self, now: float) -> float:
        return prefill_pressure(self.runtime, now)

    def _decode_pressure(self) -> float:
        return decode_pressure(self.runtime)

    def signals(self, now: float) -> ScaleSignals:
        rt = self.runtime
        return ScaleSignals(
            t=now,
            prefill_pressure=self._prefill_pressure(now),
            decode_pressure=self._decode_pressure(),
            attainment=rt.recent_attainment(self.cfg.min_slo_samples),
            # failed corpses awaiting removal are not capacity (§8)
            n_live=len(rt.pools.all_ids()) - len(rt.pools.failed_ids()),
            n_active=len(rt.pools.active_ids()),
        )

    # ------------------------------------------------------------ decision
    def on_monitor_tick(self, now: float) -> None:
        cfg = self.cfg
        sig = self.signals(now)
        self.last_signals = sig

        slo_bad = sig.attainment is not None and \
            sig.attainment < cfg.attainment_floor
        want_up = (sig.prefill_pressure > cfg.prefill_up
                   or sig.decode_pressure > cfg.decode_up
                   or slo_bad)
        want_down = (sig.prefill_pressure < cfg.down
                     and sig.decode_pressure < cfg.down
                     and not slo_bad)
        self._up_streak = self._up_streak + 1 if want_up else 0
        self._down_streak = self._down_streak + 1 if want_down else 0

        if now < self._cooldown_until:
            return
        # n_live counts warming instances: capacity already on its way up
        # must damp further scale-ups (classic thundering-herd guard).
        # Quarantined (DEGRADED, §14) instances are not serving capacity —
        # provisioning around a sick instance must not be blocked by its
        # headcount.
        if self._up_streak >= cfg.up_patience and \
                sig.n_live - len(self.runtime.pools.retiring_ids()) \
                - len(self.runtime.pools.degraded_ids()) < \
                cfg.max_instances:
            self._scale_up(now, sig)
        elif self._down_streak >= cfg.down_patience and \
                sig.n_active > cfg.min_instances:
            self._scale_down(now, sig)

    # ----------------------------------------------------- fault path (§8)
    def on_instance_failed(self, iid: int, pool: Pool,
                           now: float) -> Optional[int]:
        """A crash removed capacity outright: spawn a replacement into the
        dead instance's pool, bypassing patience (the signal is unambiguous)
        but respecting ``max_instances``. Returns the new iid, or None when
        the ceiling blocks the replacement."""
        rt = self.runtime
        live = len(rt.pools.all_ids()) - len(rt.pools.failed_ids()) \
            - len(rt.pools.retiring_ids())
        if live >= self.cfg.max_instances:
            return None
        new = rt.scale_up(pool, now)
        self.events.append(ScaleEvent("up", new, pool, now,
                                      reason=f"replace failed {iid}"))
        self._after_action(now)
        return new

    # ------------------------------------------------------------- actions
    def _scale_up(self, now: float, sig: ScaleSignals) -> None:
        # Eq. (1)/(2) at cluster granularity: grow the side whose normalized
        # pressure is higher (ties go to prefill — it leads decode, Insight 5).
        pp = sig.prefill_pressure / max(self.cfg.prefill_up, 1e-9)
        dp = sig.decode_pressure / max(self.cfg.decode_up, 1e-9)
        pool = Pool.PREFILL if pp >= dp else Pool.DECODE
        iid = self.runtime.scale_up(pool, now)
        self.events.append(ScaleEvent(
            "up", iid, pool, now,
            reason=f"pp={sig.prefill_pressure:.2f} "
                   f"dp={sig.decode_pressure:.2f} "
                   f"att={'n/a' if sig.attainment is None else f'{sig.attainment:.2f}'}"))
        self._after_action(now)

    def _pick_retire_candidate(self, sig: ScaleSignals) -> Optional[int]:
        """Least-loaded ACTIVE instance of the slacker side, respecting the
        policy's min pool sizes (never strand a side)."""
        rt = self.runtime
        cands = []
        if rt.pools.count(Pool.DECODE, Pool.P2D) > \
                max(1, rt.sched_cfg.min_decode_instances) and \
                sig.decode_pressure <= sig.prefill_pressure:
            ids = rt.pools.decode_capable()     # DECODE ∪ P2D, like the gate
            cands = [(rt.monitor.get(i).running_tokens, i) for i in ids]
        if not cands and rt.pools.count(Pool.PREFILL, Pool.D2P) > \
                max(1, rt.sched_cfg.min_prefill_instances):
            ids = rt.pools.prefill_capable()    # PREFILL ∪ D2P, like the gate
            cands = [(rt.monitor.get(i).prefill_backlog_tokens, i)
                     for i in ids]
        if not cands:
            return None
        return min(cands)[1]

    def _scale_down(self, now: float, sig: ScaleSignals) -> None:
        iid = self._pick_retire_candidate(sig)
        if iid is None:
            return
        pool = self.runtime.pools.pool_of(iid)
        self.runtime.begin_retire(iid, now)
        self.events.append(ScaleEvent(
            "down", iid, pool, now,
            reason=f"pp={sig.prefill_pressure:.2f} "
                   f"dp={sig.decode_pressure:.2f}"))
        self._after_action(now)

    def _after_action(self, now: float) -> None:
        self._up_streak = 0
        self._down_streak = 0
        self._cooldown_until = now + self.cfg.cooldown_s

    # ------------------------------------------------------------ reporting
    @property
    def n_scale_ups(self) -> int:
        return sum(1 for e in self.events if e.kind == "up")

    @property
    def n_scale_downs(self) -> int:
        return sum(1 for e in self.events if e.kind == "down")
