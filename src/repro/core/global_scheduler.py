"""Arrow global scheduler — Algorithms 1–4 of the paper plus the §5.5
SLO-aware instance-scheduling triggers and the overload (decode-priority)
guard. Engine-agnostic: drives any cluster exposing the ClusterView protocol
(the discrete-event simulator and the real JAX engine both do).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Protocol

from repro.core.monitor import InstanceMonitor
from repro.core.pools import InstancePools, Pool
from repro.core.prefix_index import PrefixHit
from repro.core.request import Request
from repro.core.slo import SLO, SchedulerConfig
from repro.core.ttft_predictor import TTFTPredictor


class NoSchedulableInstance(RuntimeError):
    """No ACTIVE instance can accept the request's phase right now (every
    instance is WARMING or RETIRING). The runtime queues the request and
    retries when an instance activates (core/runtime.py) instead of
    crashing."""

    def __init__(self, phase: str, pools: InstancePools):
        super().__init__(
            f"no ACTIVE instance to schedule {phase} on: "
            f"{len(pools.warming_ids())} warming, "
            f"{len(pools.retiring_ids())} retiring, "
            f"{len(pools.failed_ids())} failed, 0 active")


class ClusterView(Protocol):
    """What the global scheduler needs to see of the cluster."""

    def has_pending_prefill(self, iid: int) -> bool: ...
    def has_pending_decode(self, iid: int) -> bool: ...


@dataclass
class ScheduleOutcome:
    instance: int
    flipped: Optional[int] = None      # instance moved between pools, if any
    predicted_ttft: Optional[float] = None
    via_fallback: bool = False
    prefix_hit: Optional[PrefixHit] = None   # cached-prefix reuse chosen (§7)
    deflected: bool = False            # prefill routed onto a decode host (§11)


@dataclass(frozen=True)
class DeflectionConfig:
    """Cross-pool prefill deflection knobs (DESIGN.md §11).

    ratio        fraction of the victim's mixed-chunk budget a deflected
                 prefill may consume per fused step (0 disables deflection;
                 the local schedulers enforce it via a deficit counter).
    watermark    Eq.(1) normalized prefill-pool pressure above which
                 deflection activates. Kept below AutoScalerConfig.prefill_up
                 (0.75) so deflection soaks a spike in milliseconds while a
                 sustained breach still reaches the autoscaler.
    step_budget  assumed victim mixed-chunk budget for the interference
                 model (tokens per fused step).
    idle_pickup  symmetric direction: idle PREFILL-pool instances accept
                 decode work instead of forcing a P→D flip.
    """
    ratio: float = 0.25
    watermark: float = 0.60
    step_budget: int = 2048
    idle_pickup: bool = True


class DeflectionPolicy:
    """Interference-charged prefill deflection onto decode instances.

    When the prefill pool's Eq.(1) pressure exceeds the watermark, bounded
    prefill chunks are routed onto pure-DECODE instances. The victim is
    charged the predicted interference through the same Eq.(1)/(2)
    bookkeeping used for native prefill, and deflection is *refused*
    whenever the predictors say it would break either pool's SLO budget.
    Refusals are counted by reason so reports can explain why a spike was
    not absorbed.
    """

    REFUSALS = ("below_watermark", "no_victim", "tpot_budget",
                "kv_headroom", "victim_backlog")

    def __init__(self, cfg: DeflectionConfig):
        self.cfg = cfg
        self.stats: Dict[str, float] = {
            "requests_deflected": 0,
            "tokens_deflected": 0,
            "decode_pickups": 0,
            "interference_s": 0.0,
        }
        for r in self.REFUSALS:
            self.stats["refused_" + r] = 0

    def per_step_tokens(self) -> int:
        """Max deflected prefill tokens per fused step on the victim."""
        return max(1, int(self.cfg.ratio * self.cfg.step_budget))

    def _refuse(self, reason: str) -> None:
        self.stats["refused_" + reason] += 1

    # ------------------------------------------- prefill → decode victims
    def try_deflect(self, sched: "GlobalScheduler", req: Request, now: float,
                    ttft_budget: float) -> Optional[ScheduleOutcome]:
        """Place req's prefill on a pure-DECODE instance, or refuse."""
        if self.cfg.ratio <= 0:
            return None
        if sched.prefill_pool_pressure(now) <= self.cfg.watermark:
            self._refuse("below_watermark")
            return None
        victims = sched.pools.members(Pool.DECODE)
        if not victims:
            self._refuse("no_victim")
            return None
        per_step = self.per_step_tokens()
        n_steps = -(-req.input_len // per_step)      # ceil
        tpot_budget = sched.cfg.tpot_threshold_frac * sched.slo.tpot
        # Most-preferred victim first: least Eq.(2) backlog, then lightest.
        order = sorted(victims, key=lambda i: (
            sched._prefill_delay(i, now),
            sched.monitor.get(i).running_tokens))
        reason = None
        for v in order:
            s = sched.monitor.get(v)
            chunk_t = sched._predict_chunk(v, 0, per_step)
            # TPOT guard: every victim step stretches by one deflected
            # chunk; the stretched interval must stay inside the budget.
            if s.avg_token_interval + chunk_t > tpot_budget:
                reason = reason or "tpot_budget"
                continue
            if s.running_tokens + req.input_len > sched.cfg.max_running_tokens:
                reason = reason or "kv_headroom"
                continue
            # TTFT of the deflected request: one chunk lands per victim
            # step, so the drain takes n_steps stretched intervals on top
            # of any deflected backlog already charged to the victim.
            drain = n_steps * (s.avg_token_interval + chunk_t)
            if sched._prefill_delay(v, now) + drain > ttft_budget:
                reason = reason or "victim_backlog"
                continue
            ttft = sched.account_prefill_dispatch(v, now, drain)
            self.stats["requests_deflected"] += 1
            self.stats["tokens_deflected"] += req.input_len
            self.stats["interference_s"] += n_steps * chunk_t
            return ScheduleOutcome(v, predicted_ttft=ttft, deflected=True)
        self._refuse(reason or "no_victim")
        return None

    # ------------------------------------------- decode → idle prefillers
    def try_pickup(self, sched: "GlobalScheduler", req: Request,
                   now: float) -> Optional[int]:
        """Symmetric slack pickup: an idle PREFILL-pool instance hosts the
        decode phase instead of forcing a P→D flip. No pool state changes —
        decode work on an ACTIVE prefill instance is already legal (the
        Alg. 2 last-resort path does the same)."""
        if not self.cfg.idle_pickup or self.cfg.ratio <= 0:
            return None
        cands = [i for i in sched.pools.members(Pool.PREFILL)
                 if not sched.cluster.has_pending_prefill(i)
                 and sched._prefill_delay(i, now) <= 0.0
                 and sched.monitor.get(i).running_tokens + req.input_len
                 <= sched.cfg.max_running_tokens]
        if not cands:
            return None
        pick, _ = sched._min_running_tokens(cands)
        self.stats["decode_pickups"] += 1
        return pick


class GlobalScheduler:
    """SLO-aware request + instance scheduling over elastic pools."""

    def __init__(self, pools: InstancePools, monitor: InstanceMonitor,
                 predictor: TTFTPredictor, slo: SLO,
                 cfg: SchedulerConfig, cluster: ClusterView):
        self.pools = pools
        self.monitor = monitor
        self.predictor = predictor
        self.slo = slo
        self.cfg = cfg
        self.cluster = cluster
        # Eq. (1)/(2) bookkeeping: predicted prefill drain time per instance.
        # The global scheduler dispatches every prefill, so it can maintain
        # e_i exactly (Insight 1) instead of waiting for monitor scrapes.
        self.prefill_ready_at: Dict[int, float] = {
            iid: 0.0 for iid in pools.all_ids()}
        # counters for the ablation/e2e reports
        self.n_d2p_flips = 0
        self.n_p2d_flips = 0
        # cross-pool deflection (DESIGN.md §11); armed by the runtime when
        # the policy is deflective, None otherwise.
        self.deflection: Optional[DeflectionPolicy] = None
        # beyond-paper proactive burst detector state
        self._arrivals: list = []          # (t, input_len) ring
        self.n_proactive_flips = 0

    # ----------------------------------- elastic lifecycle (DESIGN.md §6)
    def on_instance_added(self, iid: int) -> None:
        """A new instance joined the cluster: start its Eq.(2) bookkeeping."""
        self.prefill_ready_at.setdefault(iid, 0.0)

    def on_instance_removed(self, iid: int) -> None:
        self.prefill_ready_at.pop(iid, None)

    # ------------------------------------------------------------- helpers
    def _predict(self, iid: int, input_len: int) -> float:
        """Instance-aware prefill-time prediction (heterogeneous clusters use
        PerInstancePredictor — paper §8; homogeneous predictors ignore iid)."""
        p = self.predictor
        if hasattr(p, "for_instance"):
            return p.for_instance(iid).predict(input_len)
        return p.predict(input_len)

    def _predict_chunk(self, iid: int, start: int, length: int) -> float:
        """Suffix-prefill prediction for prefix reuse (§7): the chunk cost is
        the difference of the cumulative quadratic."""
        p = self.predictor
        if hasattr(p, "for_instance"):
            return p.for_instance(iid).predict_chunk(start, length)
        return p.predict_chunk(start, length)

    def _prefill_delay(self, iid: int, now: float) -> float:
        return max(self.prefill_ready_at[iid] - now, 0.0)

    def _min_prefill_delay(self, ids, now):
        best, best_d = None, None
        for iid in ids:
            d = self._prefill_delay(iid, now)
            if best_d is None or d < best_d:
                best, best_d = iid, d
        return best, best_d

    def _min_running_tokens(self, ids):
        best, best_t = None, None
        for iid in ids:
            t = self.monitor.get(iid).running_tokens
            if best_t is None or t < best_t:
                best, best_t = iid, t
        return best, best_t

    def prefill_pool_pressure(self, now: float) -> float:
        """Eq.(1) pressure of the prefill pool, normalized by the TTFT
        budget: mean predicted queueing delay across prefill-capable
        instances over ttft_threshold_frac * SLO_ttft. Mirrors
        autoscaler.prefill_pressure but needs only the scheduler's own
        Eq.(2) state (usable from unit tests without a runtime)."""
        ids = self.pools.prefill_capable()
        if not ids:
            return float("inf")
        delay = sum(self._prefill_delay(i, now) for i in ids) / len(ids)
        return delay / (self.cfg.ttft_threshold_frac * self.slo.ttft)

    def _decode_load_low(self) -> bool:
        """Overload guard (§5.5): decode has priority; only pull decode
        instances into prefill when decode load is comfortably low."""
        ids = self.pools.decode_capable()
        if not ids:
            return True
        for iid in ids:
            s = self.monitor.get(iid)
            if s.running_tokens > self.cfg.decode_low_load_frac * self.cfg.max_running_tokens:
                return False
            if s.avg_token_interval > self.cfg.tpot_threshold_frac * self.slo.tpot:
                return False
        return True

    def account_prefill_dispatch(self, iid: int, now: float,
                                 prefill_time: float) -> float:
        """e_i = max(e_{i-1}, a_i) + p_i  (Eq. 2). Returns predicted TTFT."""
        start = max(self.prefill_ready_at[iid], now)
        self.prefill_ready_at[iid] = start + prefill_time
        return self.prefill_ready_at[iid] - now

    # ------------------------------------------------- Algorithm 3 (D → P)
    def try_move_decode_to_prefill(self) -> Optional[int]:
        n_decoders = self.pools.count(Pool.DECODE, Pool.P2D)
        if n_decoders <= max(1, self.cfg.min_decode_instances):
            return None
        p2d = self.pools.members(Pool.P2D)
        pick, _ = self._min_running_tokens(p2d if p2d else
                                           self.pools.members(Pool.DECODE))
        if pick is None:
            return None
        self.pools.flip_to_prefill(pick, self.cluster.has_pending_decode(pick))
        self.n_d2p_flips += 1
        return pick

    # ------------------------------------------------- Algorithm 4 (P → D)
    def try_move_prefill_to_decode(self, now: float = 0.0) -> Optional[int]:
        n_prefillers = self.pools.count(Pool.PREFILL, Pool.D2P)
        if n_prefillers <= max(1, self.cfg.min_prefill_instances):
            return None
        d2p = self.pools.members(Pool.D2P)
        pick, _ = self._min_prefill_delay(
            d2p if d2p else self.pools.members(Pool.PREFILL), now)
        if pick is None:
            return None
        self.pools.flip_to_decode(pick, self.cluster.has_pending_prefill(pick))
        self.n_p2d_flips += 1
        return pick

    # ------------------------------------- prefix-affinity candidate (§7)
    def _best_prefix_option(self, req: Request, now: float,
                            prefix_hits: Optional[List[PrefixHit]]
                            ) -> Optional[tuple]:
        """Best admissible cached-prefix holder: ACTIVE, and — when it is on
        decode duty — only if its decode load is comfortably low (the Alg. 1
        overload guard applied per-instance). Returns (predicted_ttft,
        suffix_prefill_time, hit) minimizing predicted TTFT."""
        best = None
        for h in prefix_hits or []:
            cached = min(h.cached_len, req.input_len - 1)
            if cached <= 0 or not self.pools.is_schedulable(h.iid):
                continue
            if self.pools.pool_of(h.iid) in (Pool.DECODE, Pool.P2D):
                s = self.monitor.get(h.iid)
                if s.running_tokens > self.cfg.decode_low_load_frac * \
                        self.cfg.max_running_tokens:
                    continue
            suffix = self._predict_chunk(h.iid, cached, req.input_len - cached)
            t_h = self._prefill_delay(h.iid, now) + suffix
            if best is None or t_h < best[0]:
                best = (t_h, suffix, PrefixHit(h.iid, h.rid, cached))
        return best

    # ------------------------------------------------- Algorithm 1 (prefill)
    def schedule_prefill(self, req: Request, now: float,
                         prefix_hits: Optional[List[PrefixHit]] = None
                         ) -> ScheduleOutcome:
        ttft_budget = self.cfg.ttft_threshold_frac * self.slo.ttft
        if self.cfg.proactive:
            self._arrivals.append((now, req.input_len))

        t1, d1 = self._min_prefill_delay(self.pools.members(Pool.PREFILL), now)

        # Prefix-affinity shortcut (§7, generalizing the Alg. 2 keep-local
        # rule to prefill): route to the instance holding the longest cached
        # prefix when its predicted *suffix* TTFT is within budget and beats
        # the best cold prefill-pool candidate. Eq. (2) stays exact: the
        # holder is charged only the uncached suffix.
        opt = self._best_prefix_option(req, now, prefix_hits)
        if opt is not None:
            t_h, suffix, hit = opt
            cold1 = None if t1 is None else \
                d1 + self._predict(t1, req.input_len)
            if t_h <= ttft_budget and (cold1 is None or t_h <= cold1):
                ttft = self.account_prefill_dispatch(hit.iid, now, suffix)
                return ScheduleOutcome(hit.iid, predicted_ttft=ttft,
                                       prefix_hit=hit)

        if t1 is not None and d1 + self._predict(t1, req.input_len) <= ttft_budget:
            ttft = self.account_prefill_dispatch(
                t1, now, self._predict(t1, req.input_len))
            return ScheduleOutcome(t1, predicted_ttft=ttft)

        t2, d2 = self._min_prefill_delay(self.pools.members(Pool.D2P), now)
        if t2 is not None and d2 + self._predict(t2, req.input_len) <= ttft_budget:
            ttft = self.account_prefill_dispatch(
                t2, now, self._predict(t2, req.input_len))
            return ScheduleOutcome(t2, predicted_ttft=ttft)

        # §11 deflection: before flipping a whole instance, try to absorb
        # the prefill as bounded chunks on a decode victim. Cheaper than a
        # flip (no drain, no pool change) and refused whenever the Eq.(1)/(2)
        # predictors say it would break either pool's budget.
        if self.deflection is not None:
            out = self.deflection.try_deflect(self, req, now, ttft_budget)
            if out is not None:
                return out

        flipped = None
        if self._decode_load_low():
            t3 = self.try_move_decode_to_prefill()
            if t3 is not None:
                flipped = t3
                ttft = self.account_prefill_dispatch(
                    t3, now, self._predict(t3, req.input_len))
                return ScheduleOutcome(t3, flipped=flipped, predicted_ttft=ttft)

        # fall back to t1 (or t2 / any ACTIVE instance — never a warming or
        # retiring one). When *no* ACTIVE instance exists the request is not
        # placeable: raise a descriptive error instead of the bare
        # IndexError active_ids()[0] used to produce — the runtime queues
        # the request and retries on the next activation.
        fb = t1 if t1 is not None else t2
        if fb is None:
            active = self.pools.active_ids()
            if not active:
                raise NoSchedulableInstance("prefill", self.pools)
            fb = active[0]
        ttft = self.account_prefill_dispatch(
            fb, now, self._predict(fb, req.input_len))
        return ScheduleOutcome(fb, predicted_ttft=ttft, via_fallback=True)

    # ------------------------------------------------- Algorithm 2 (decode)
    def schedule_decode(self, req: Request, now: float) -> ScheduleOutcome:
        # If the prefill instance has itself been flipped to decode duty,
        # keep the request there: the KV cache transfer vanishes. (Not when
        # it is retiring — a retiring instance accepts no new decode work.)
        pi = req.prefill_instance
        if pi is not None and self.pools.is_schedulable(pi) and \
                self.pools.pool_of(pi) in (Pool.DECODE, Pool.P2D):
            return ScheduleOutcome(pi)

        max_rt = self.cfg.max_running_tokens
        tpot_budget = self.cfg.tpot_threshold_frac * self.slo.tpot

        t1, rt1 = self._min_running_tokens(self.pools.members(Pool.DECODE))
        if t1 is not None and rt1 + req.input_len <= max_rt and \
                self.monitor.get(t1).avg_token_interval <= tpot_budget:
            return ScheduleOutcome(t1)

        t2, rt2 = self._min_running_tokens(self.pools.members(Pool.P2D))
        if t2 is not None and rt2 + req.input_len <= max_rt and \
                self.monitor.get(t2).avg_token_interval <= tpot_budget:
            return ScheduleOutcome(t2)

        # §11 symmetric pickup: an idle prefill instance hosts the decode
        # phase instead of flipping one out of the prefill pool.
        if self.deflection is not None:
            pick = self.deflection.try_pickup(self, req, now)
            if pick is not None:
                return ScheduleOutcome(pick)

        t3 = self.try_move_prefill_to_decode(now)
        if t3 is not None:
            return ScheduleOutcome(t3, flipped=t3)

        # fallback: lighter of t1/t2
        if t1 is not None and (t2 is None or rt1 <= rt2):
            return ScheduleOutcome(t1, via_fallback=True)
        if t2 is not None:
            return ScheduleOutcome(t2, via_fallback=True)
        # last resort: both decode pools empty and no flip allowed. Pick the
        # least-loaded decode-capable instance — never an arbitrary id, which
        # could be a pure-PREFILL instance with no decode duty at all.
        ids = self.pools.decode_capable() or self.pools.active_ids()
        if not ids:
            raise NoSchedulableInstance("decode", self.pools)
        pick, _ = self._min_running_tokens(ids)
        return ScheduleOutcome(pick, via_fallback=True)

    # ----------------------------------------- beyond-paper: proactive flip
    def _proactive_check(self, now: float) -> None:
        w = self.cfg.proactive_window_s
        horizon = now - 10 * w
        self._arrivals = [(t, n) for t, n in self._arrivals if t >= horizon]
        if len(self._arrivals) < 8:
            return
        short = sum(n for t, n in self._arrivals if t >= now - w) / w
        long = sum(n for t, n in self._arrivals) / (10 * w)
        if long > 0 and short > self.cfg.proactive_ratio * long and \
                self._decode_load_low():
            if self.try_move_decode_to_prefill() is not None:
                self.n_proactive_flips += 1

    # --------------------------------------------- §5.5 monitor-driven flips
    def on_monitor_tick(self, now: float) -> None:
        if self.cfg.proactive:
            self._proactive_check(now)
        # (2) sustained TPOT breach on decode side -> add decode capacity.
        # Only *pure* DECODE-pool instances vote: P→D members still draining
        # prefill chunks are expected to show long intervals transiently.
        ids = self.pools.decode_capable()
        pure = self.pools.members(Pool.DECODE)
        if pure:
            breach = [i for i in pure
                      if self.monitor.get(i).avg_token_interval >
                      self.cfg.tpot_threshold_frac * self.slo.tpot]
            if len(breach) * 2 >= len(pure) and breach:
                self.try_move_prefill_to_decode(now)
        # (3) idle prefill + busy decode -> free resources toward decode
        if self.cfg.idle_prefill_flip:
            busy = any(
                self.monitor.get(i).running_tokens >
                self.cfg.decode_low_load_frac * self.cfg.max_running_tokens
                or self.monitor.get(i).avg_token_interval >
                0.6 * self.cfg.tpot_threshold_frac * self.slo.tpot
                for i in pure) if pure else False
            if busy:
                for iid in self.pools.members(Pool.PREFILL):
                    if self.pools.count(Pool.PREFILL, Pool.D2P) <= \
                            self.cfg.min_prefill_instances:
                        break
                    if not self.cluster.has_pending_prefill(iid) and \
                            self._prefill_delay(iid, now) <= 0.0:
                        self.pools.flip_to_decode(iid, False)
                        self.n_p2d_flips += 1
        # pool-drain transitions (black edges of Fig. 5)
        for iid in self.pools.members(Pool.P2D):
            if not self.cluster.has_pending_prefill(iid):
                self.pools.on_prefill_drained(iid)
        for iid in self.pools.members(Pool.D2P):
            if not self.cluster.has_pending_decode(iid):
                self.pools.on_decode_drained(iid)
