"""Arrow's core contribution: stateless instances, elastic instance pools and
SLO-aware adaptive request/instance scheduling (paper §5), plus the unified
``ServingSystem`` streaming front-end both backends implement (DESIGN.md §1)."""
from repro.core.autoscaler import (AutoScaler, AutoScalerConfig,  # noqa: F401
                                   ScaleEvent, ScaleSignals)
from repro.core.clock import Clock, VirtualClock, WallClock  # noqa: F401
from repro.core.faults import (FaultEvent, FaultInjector,  # noqa: F401
                               FaultPlan)
from repro.core.global_scheduler import (DeflectionConfig,  # noqa: F401
                                         DeflectionPolicy, GlobalScheduler,
                                         NoSchedulableInstance,
                                         ScheduleOutcome)
from repro.core.health import HealthConfig, HealthMonitor  # noqa: F401
from repro.core.local_scheduler import IterationPlan, LocalScheduler  # noqa: F401
from repro.core.monitor import InstanceMonitor, InstanceStats  # noqa: F401
from repro.core.policies import POLICIES  # noqa: F401
from repro.core.pools import InstancePools, Lifecycle, Pool  # noqa: F401
from repro.core.prefix_index import (PrefixCacheManager, PrefixHit,  # noqa: F401
                                     PrefixIndex, content_keys, lineage_keys)
from repro.core.request import (Phase, Request, RequestState,  # noqa: F401
                                SamplingParams)
from repro.core.runtime import DecodePlacement, RuntimeCore  # noqa: F401
from repro.core.serving import (RequestHandle, ServeReport, ServingSystem,  # noqa: F401
                                SLOTier, TIERS, UndispatchableError,
                                replay_trace)
from repro.core.slo import SLO, SchedulerConfig  # noqa: F401
from repro.core.tenants import (AdmissionConfig,  # noqa: F401
                                AdmissionController, AdmissionDecision,
                                Admitted, CreditLedger, CreditLedgerConfig,
                                Deferred, Rejected, RetryQueue, Tenant,
                                TenantRegistry, default_registry)
from repro.core.ttft_predictor import TTFTPredictor  # noqa: F401
