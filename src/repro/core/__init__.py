"""Arrow's core contribution: stateless instances, elastic instance pools and
SLO-aware adaptive request/instance scheduling (paper §5)."""
from repro.core.global_scheduler import GlobalScheduler, ScheduleOutcome  # noqa: F401
from repro.core.local_scheduler import IterationPlan, LocalScheduler  # noqa: F401
from repro.core.monitor import InstanceMonitor, InstanceStats  # noqa: F401
from repro.core.pools import InstancePools, Pool  # noqa: F401
from repro.core.request import Phase, Request, RequestState  # noqa: F401
from repro.core.slo import SLO, SchedulerConfig  # noqa: F401
from repro.core.ttft_predictor import TTFTPredictor  # noqa: F401
