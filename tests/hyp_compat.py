"""Guarded hypothesis import: property tests skip cleanly when ``hypothesis``
is not installed (it is an optional test dependency — ``pip install -e
.[test]`` or ``pip install -r requirements.txt``), while plain unit tests in
the same module still collect and run.

Usage in a test module:

    from hyp_compat import HAVE_HYPOTHESIS, corpus_backed, given, settings, st

Skip-count accounting: a ``@given`` test that also replays a checked-in
regression corpus under plain pytest is not *lost* coverage when hypothesis
is absent — only the random-drawing front-end is. ``@corpus_backed(path)``
(stacked above ``@given``) rewrites the shim's skip reason to
``covered by corpus replay: <file>`` so `pytest -rs` output distinguishes
corpus-backed skips from genuinely skipped properties, and CI can assert the
corpus files it relies on are present and non-empty.
"""
import pytest

GENUINE_SKIP = "hypothesis not installed"
CORPUS_SKIP = "hypothesis not installed; covered by corpus replay: {name}"

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _StubStrategies:
        """Accepts any ``st.<strategy>(...)`` call at decoration time; the
        decorated test is skip-marked so the stub values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StubStrategies()

    def given(*a, **k):
        return pytest.mark.skip(reason=GENUINE_SKIP)

    def settings(*a, **k):
        return lambda fn: fn


def corpus_backed(corpus_path):
    """Tag a ``@given`` property test whose schedules also replay from the
    checked-in corpus at ``corpus_path``. No-op when hypothesis is present;
    with the shim active it replaces the generic skip reason so the skip is
    accounted as corpus-covered rather than lost. The corpus file must
    exist and be non-empty — a dangling tag would silently claim coverage
    that no replay test provides."""
    if HAVE_HYPOTHESIS:
        return lambda fn: fn

    def wrap(fn):
        assert corpus_path.exists() and corpus_path.stat().st_size > 2, \
            f"corpus_backed points at empty/missing corpus {corpus_path}"
        fn.pytestmark = [m for m in getattr(fn, "pytestmark", [])
                         if m.name != "skip"]
        fn.pytestmark.append(pytest.mark.skip(
            reason=CORPUS_SKIP.format(name=corpus_path.name)))
        return fn
    return wrap
