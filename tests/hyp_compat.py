"""Guarded hypothesis import: property tests skip cleanly when ``hypothesis``
is not installed (it is an optional test dependency — ``pip install -e
.[test]`` or ``pip install -r requirements.txt``), while plain unit tests in
the same module still collect and run.

Usage in a test module:

    from hyp_compat import HAVE_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _StubStrategies:
        """Accepts any ``st.<strategy>(...)`` call at decoration time; the
        decorated test is skip-marked so the stub values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StubStrategies()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn
