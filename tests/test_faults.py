"""Fault-tolerant serving (ISSUE 4, DESIGN.md §8): FaultPlan grammar, the
FAILED lifecycle, KV-loss recovery on both backends, migration aborts,
AutoScaler replacement, the no-recovery strawman, the undispatchable-drain
error, sim/engine fault parity, and the chaos acceptance run with the
invariant probe asserted after every step."""
import numpy as np
import pytest
from invariants import check_invariants

from repro.configs import get_config, get_smoke_config
from repro.core import (AutoScalerConfig, FaultEvent, FaultInjector,
                        FaultPlan, Lifecycle, Pool, Request, SLO,
                        UndispatchableError)
from repro.core.request import RequestState
from repro.core.serving import replay_trace
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

CFG = get_config("gemma-2b")


# ------------------------------------------------------- FaultPlan grammar


def test_fault_plan_parse_grammar():
    p = FaultPlan.parse("crash@20; crash@45:target=3;"
                        "slow@60:factor=4,duration=10")
    assert [e.kind for e in p.events] == ["crash", "crash", "slow"]
    assert p.events[0].target is None and p.events[1].target == 3
    assert p.events[2].factor == 4.0 and p.events[2].duration == 10.0
    with pytest.raises(ValueError, match="kind@time"):
        FaultPlan.parse("crash")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("melt@3")
    with pytest.raises(ValueError, match="unknown option"):
        FaultPlan.parse("crash@3:sev=9")


def test_fault_plan_parse_transfer_fault_grammar():
    """§14 transient-fault events ride the same grammar: droptransfer
    windows (probability + duration) and netslow windows (factor +
    duration)."""
    p = FaultPlan.parse("droptransfer@5:p=0.5,duration=3;"
                        "netslow@8:factor=4,duration=2")
    assert [e.kind for e in p.events] == ["droptransfer", "netslow"]
    assert p.events[0].p == 0.5 and p.events[0].duration == 3.0
    assert p.events[1].factor == 4.0 and p.events[1].duration == 2.0


def test_fault_plan_rejects_invalid_values():
    """ISSUE 10 satellite: negative times and non-positive factor/duration/p
    are configuration bugs — rejected with descriptive errors at parse time,
    not silently scheduled as events that never fire (or divide by zero)."""
    with pytest.raises(ValueError, match="time must be >= 0"):
        FaultPlan.parse("crash@-1")
    with pytest.raises(ValueError, match="factor=0.0 must be > 0"):
        FaultPlan.parse("slow@3:factor=0")
    with pytest.raises(ValueError, match="duration=-2.0 must be > 0"):
        FaultPlan.parse("slow@3:duration=-2")
    with pytest.raises(ValueError, match="p=0.0 must be > 0"):
        FaultPlan.parse("droptransfer@3:p=0")
    with pytest.raises(ValueError, match="factor=-4.0 must be > 0"):
        FaultPlan.parse("netslow@3:factor=-4,duration=2")


def test_monitor_drops_samples_for_removed_instances():
    """ISSUE 10 satellite: a straggling ``record_iteration``/``update_stats``
    for an instance already removed (the async engine step can finalize an
    iteration after crash teardown) is dropped silently, never a KeyError."""
    from repro.core.monitor import InstanceMonitor, InstanceStats
    m = InstanceMonitor([0, 1])
    m.record_iteration(0, 1.0, 2, 0.01)
    m.remove_instance(1)
    m.record_iteration(1, 1.0, 2, 0.01)       # removed: dropped
    m.record_iteration(7, 1.0, 2, 0.01)       # never known: dropped
    m.update_stats(InstanceStats(1))          # scrape raced removal: dropped
    m.update_stats(InstanceStats(7))
    assert 1 not in m.stats and 7 not in m.stats
    assert m.avg_token_interval(0) == pytest.approx(0.01)


def test_fault_plan_random_is_seed_deterministic():
    a = FaultPlan.random_crashes(3, 100.0, seed=7)
    b = FaultPlan.random_crashes(3, 100.0, seed=7)
    c = FaultPlan.random_crashes(3, 100.0, seed=8)
    assert a.events == b.events and a.events != c.events
    assert all(10.0 <= e.t <= 90.0 for e in a.events)


# ------------------------------------------------- FAILED lifecycle state


def test_failed_lifecycle_guards():
    from repro.core import InstancePools
    pools = InstancePools(range(4), n_prefill=2)
    pools.fail(0)                                    # ACTIVE -> FAILED
    assert pools.lifecycle_of(0) is Lifecycle.FAILED
    assert 0 not in pools.members(Pool.PREFILL)
    assert 0 not in pools.prefill_capable() + pools.decode_capable()
    assert 0 not in pools.active_ids() and 0 in pools.all_ids()
    assert pools.failed_ids() == [0]
    with pytest.raises(ValueError, match="already failed"):
        pools.fail(0)
    with pytest.raises(ValueError, match="cannot flip"):
        pools.flip_to_decode(0, has_pending_prefill=False)
    pools.begin_retire(2)
    pools.fail(2)                                    # RETIRING may crash too
    pools.add_instance(9, Pool.DECODE, warming=True)
    pools.fail(9)                                    # WARMING may crash too
    for iid in (0, 2, 9):
        pools.remove_instance(iid)                   # FAILED is removable
    assert pools.failed_ids() == []
    with pytest.raises(ValueError, match="unknown instance"):
        pools.fail(77)


# --------------------------------------------------- sim crash + recovery


def mid_decode_sim(n_requests=4, output_len=8, **kw):
    """2-instance arrow sim driven until every request decodes on instance 1
    with >= 2 tokens streamed and none finished — the deterministic barrier
    the parity test fires the crash from."""
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0), **kw)
    trace = [Request(rid=i, arrival=0.0, input_len=24, output_len=output_len)
             for i in range(n_requests)]
    handles = replay_trace(sim, trace)
    for _ in range(100000):
        if all(h.req.state is RequestState.DECODING
               and h.req.decode_instance == 1
               and 2 <= len(h.tokens) < output_len for h in handles):
            break
        assert sim.step(), "sim drained before the mid-decode barrier"
    return sim, handles


def test_sim_crash_recovers_all_requests_and_streams():
    sim, handles = mid_decode_sim()
    emitted_before = {h.rid: len(h.tokens) for h in handles}
    summary = sim.fail_instance(1, sim.clock.now())
    assert summary["lost_decode"] == 4 and summary["recovered"] == 4
    assert sim.pools.lifecycle_of(1) is Lifecycle.FAILED
    check_invariants(sim)
    report = sim.drain()
    assert report.n_finished == 4
    for h in handles:
        # nothing re-emitted, nothing dropped: exactly output_len tokens
        assert len(h.tokens) == h.req.output_len
        assert h.req.recoveries == 1
        assert h.req.resumed_tokens == emitted_before[h.rid]
        # the recovery re-prefilled prompt + streamed-minus-one tokens
        assert h.req.input_len == 24 + emitted_before[h.rid] - 1
    assert report.faults["crashes"] == 1
    assert report.faults["requests_recovered"] == 4
    assert report.faults["kv_tokens_lost"] > 0
    check_invariants(sim)
    sim.collect_stats(sim.clock.now())               # finalize the corpse
    assert 1 not in sim.pools.all_ids() and 1 not in sim.locals


def test_sim_crash_of_prefill_instance_restarts_queued_prefills():
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0))
    handles = replay_trace(sim, [Request(rid=i, arrival=0.0, input_len=4096,
                                         output_len=4) for i in range(3)])
    for _ in range(10000):
        if sim.locals[0].prefill_queue:
            break
        sim.step()
    assert sim.locals[0].prefill_queue
    sim.fail_instance(0, sim.clock.now())
    check_invariants(sim)
    report = sim.drain()
    assert report.n_finished == 3
    for h in handles:
        assert len(h.tokens) == h.req.output_len
        assert h.req.input_len == 4096          # no tokens streamed: scratch
    assert report.faults["requests_recovered"] >= 1


def test_crash_aborts_inflight_migrations_and_releases_bookkeeping():
    """A transfer in the air when its *source* dies loses the data (the
    request recovers by re-prefill); one toward a dead *destination* still
    has live KV and re-routes. Either way ``_kv_outbound``/``_kv_inbound``/
    ``_migrating_from`` are released — the invariant probe checks the books
    reconcile."""
    sim = Simulator(CFG, n_instances=3, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0))
    h = sim.submit(Request(rid=0, arrival=0.0, input_len=512, output_len=4))
    dst = None
    for _ in range(100000):
        alive = sim.step()
        if h.req.state is RequestState.MIGRATING and 0 in sim._transfers:
            dst = sim._transfers[0][1]
            break
        if not alive:
            break
    assert dst is not None, "no in-flight migration window observed"
    # destination dies mid-air: KV at the source survives, request re-routes
    kv_resident = sim.locals[dst].kv_used
    reserved = sim._transfers[0][2]
    sim.fail_instance(dst, sim.clock.now())
    assert not sim._kv_inbound.get(dst)
    if 0 in sim._transfers:                   # already re-routed in the air
        assert sim._transfers[0][1] != dst
    # the in-flight reservation is rerouted, not lost: only KV genuinely
    # resident on the victim counts as destroyed
    assert sim.report().faults["kv_tokens_lost"] == kv_resident - reserved
    assert sim.report().faults["migrations_aborted"] == 1
    check_invariants(sim)
    report = sim.drain()
    assert report.n_finished == 1 and len(h.tokens) == 4
    assert h.req.recoveries == 0              # re-routed, not re-prefilled

    # now the symmetric case: the *source* dies mid-air
    sim2 = Simulator(CFG, n_instances=3, n_prefill=1, policy="arrow",
                     slo=SLO(5.0, 2.0))
    h2 = sim2.submit(Request(rid=0, arrival=0.0, input_len=512, output_len=4))
    src = None
    for _ in range(100000):
        alive = sim2.step()
        if h2.req.state is RequestState.MIGRATING and 0 in sim2._transfers:
            src = sim2._transfers[0][0]
            break
        if not alive:
            break
    assert src is not None
    sim2.fail_instance(src, sim2.clock.now())
    assert h2.req.recoveries == 1             # data lost: re-prefilled
    check_invariants(sim2)
    report2 = sim2.drain()
    assert report2.n_finished == 1 and len(h2.tokens) == 4


def test_recovery_prefers_surviving_prefix_holder():
    """§8.2: when the lost context shares a prefix with retained KV on a
    *surviving* instance, recovery re-prefills only the uncached suffix."""
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0), prefix_cache=True)
    # the parent finishes at prefill (output_len=1), so its context is
    # retained on the PREFILL-pool instance; the child then prefills there
    # via §7 affinity but decodes on the other (decode-pool) instance
    parent = sim.submit(Request(rid=0, arrival=0.0, input_len=128,
                                output_len=1, session_id=0))
    child = sim.submit(Request(rid=1, arrival=0.0, input_len=192,
                               output_len=8, session_id=0, parent_rid=0,
                               history_len=129))
    for _ in range(100000):
        alive = sim.step()
        if parent.done and child.req.state is RequestState.DECODING and \
                child.req.decode_instance is not None and \
                len(child.tokens) >= 2:
            break
        if not alive:
            break
    holder = parent.req.prefill_instance
    victim = child.req.decode_instance
    assert parent.done and victim is not None and victim != holder
    assert child.req.cached_len > 0, "child did not reuse the parent prefix"
    assert sim.prefix_mgr.entries_on(holder), "parent prefix not retained"
    sim.fail_instance(victim, sim.clock.now())
    assert child.req.recoveries == 1
    report = sim.drain()
    assert report.n_finished == 2 and len(child.tokens) == 8
    # the recovery dispatch hit the surviving holder: only the suffix was
    # re-prefilled (strictly less than the full recovered context)
    assert child.req.cached_len > 0
    assert report.faults["re_prefill_tokens"] == \
        child.req.input_len - child.req.cached_len
    check_invariants(sim)


def test_prefill_on_retiring_decodes_in_place_when_nothing_active():
    """Crash takes the last ACTIVE instance while a retiring one is still
    draining a prefill: decode placement has no schedulable candidate, so
    the request decodes in place on its (retiring) prefill holder instead
    of crashing the drain with NoSchedulableInstance."""
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0))
    h = sim.submit(Request(rid=0, arrival=0.0, input_len=4096, output_len=4))
    for _ in range(10000):
        if sim.locals[0].prefill_queue:
            break
        sim.step()
    assert sim.locals[0].prefill_queue
    sim.begin_retire(0, sim.clock.now())      # prefill drains in place
    sim.fail_instance(1, sim.clock.now())     # last ACTIVE gone
    report = sim.drain()
    assert report.n_finished == 1 and len(h.tokens) == 4
    assert h.req.decode_instance == 0         # decoded on the retiring holder
    check_invariants(sim)
    sim.collect_stats(sim.clock.now())        # fully drained: retire closes
    assert 0 not in sim.pools.all_ids()


def test_autoscaler_spawns_replacement_within_bounds():
    sim = Simulator(CFG, n_instances=4, n_prefill=2, policy="arrow_elastic",
                    slo=SLO(3.0, 0.1),
                    autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                    max_instances=4,
                                                    warmup_s=2.0))
    # at the ceiling: a crash frees a seat, so the replacement fits — and
    # lands in the dead instance's pool
    sim.fail_instance(0, 0.0)
    assert sim.report().faults["replacements"] == 1
    new = [i for i in sim.pools.all_ids()
           if sim.pools.lifecycle_of(i) is Lifecycle.WARMING]
    assert len(new) == 1 and sim.pools.pool_of(new[0]) is Pool.PREFILL
    # every crash frees exactly the seat its replacement takes: live
    # (non-failed) never exceeds the ceiling
    sim.fail_instance(1, 0.0)
    assert sim.report().faults["replacements"] == 2
    assert len(sim.pools.all_ids()) - len(sim.pools.failed_ids()) <= 4
    # a crashed WARMING replacement: its pending activation is stale and
    # must be a no-op, and it must never be counted as capacity again
    sim.fail_instance(new[0], 0.0)
    sim.run_until(10.0)                        # activation event fires late
    assert sim.pools.lifecycle_of(new[0]) is Lifecycle.FAILED  # not activated
    sim.collect_stats(sim.clock.now())         # monitor tick buries corpses
    assert new[0] not in sim.pools.all_ids()   # finalized, never activated
    assert len(sim.pools.all_ids()) - len(sim.pools.failed_ids()) <= 4


def test_slowdown_event_stretches_iterations():
    def run(plan):
        sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                        slo=SLO(5.0, 2.0), fault_plan=plan)
        h = sim.submit(Request(rid=0, arrival=0.0, input_len=256,
                               output_len=32))
        sim.drain()
        return sim, h.req.finish_time

    _, base = run(None)
    slowed, slow_t = run(FaultPlan.parse("slow@0:factor=10,duration=1000"))
    assert slowed.report().faults["slowdowns"] == 1
    assert slow_t > 2 * base                   # 10x iterations, same tokens


def test_no_recovery_strawman_strands_requests():
    sim, handles = mid_decode_sim()
    sim.fail_instance(1, sim.clock.now(), recover=False)
    report = sim.drain()                       # terminates — nothing hangs
    assert report.n_finished == 0
    assert report.faults["requests_lost"] == 4
    assert report.faults["requests_recovered"] == 0
    assert all(not h.done for h in handles)


# ------------------------------------------ undispatchable drain() error


def test_drain_raises_descriptive_error_when_everything_failed():
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(3.0, 0.1))
    sim.fail_instance(0, 0.0)
    sim.fail_instance(1, 0.0)
    sim.submit(Request(rid=7, arrival=0.0, input_len=32, output_len=2))
    with pytest.raises(UndispatchableError, match=r"\[7\].*2 failed") as ei:
        sim.drain()
    assert ei.value.rids == [7]


def test_drain_raises_when_every_instance_is_retiring():
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(3.0, 0.1))
    sim.begin_retire(0, 0.0)
    sim.begin_retire(1, 0.0)
    sim.submit(Request(rid=3, arrival=0.0, input_len=32, output_len=2))
    with pytest.raises(UndispatchableError, match=r"\[3\].*2 retiring"):
        sim.drain()


@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.models import build_model
    cfg = get_smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    return cfg, params


def test_engine_drain_raises_instead_of_spinning_to_timeout(engine_setup):
    from repro.engine import ArrowEngineCluster
    cfg, params = engine_setup
    eng = ArrowEngineCluster(cfg, n_instances=1, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params)
    eng.fail_instance(0, eng.clock.now())
    eng.submit(Request(rid=5, arrival=0.0, input_len=16, output_len=2))
    with pytest.raises(UndispatchableError, match=r"\[5\]"):
        eng.drain(timeout=300.0)               # raises immediately, no spin


# --------------------------------------------------- sim/engine parity


def greedy_reference(cfg, model, params, prompt, n_new):
    import jax
    import jax.numpy as jnp
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_capacity=128))(params, batch)
    toks = [int(jnp.argmax(logits[0, len(prompt) - 1, :cfg.vocab_size]))]
    step = jax.jit(model.decode)
    pos = len(prompt)
    for _ in range(n_new - 1):
        db = {"token": jnp.asarray([[toks[-1]]], jnp.int32),
              "pos": jnp.asarray([pos], jnp.int32)}
        logits, cache = step(params, cache, db)
        toks.append(int(jnp.argmax(logits[0, 0, :cfg.vocab_size])))
        pos += 1
    return toks


def test_sim_engine_fault_parity(engine_setup):
    """Acceptance (ISSUE 4 satellite): the same FaultPlan applied at the
    same logical point of the same trace loses the same requests on both
    backends — recovered-rid sets and fault counters match, and the
    engine's recovered token ids equal the unfaulted greedy reference.
    (The plan fires through the real FaultInjector at a state barrier —
    every request mid-decode on instance 1 — because wall-clock timing of
    the engine makes a purely time-triggered comparison meaningless.)"""
    from repro.engine import ArrowEngineCluster
    from repro.models import build_model
    cfg, params = engine_setup
    plan = FaultPlan(events=(FaultEvent(t=0.0, kind="crash", target=1),))
    trace = [Request(rid=i, arrival=0.0, input_len=24, output_len=8)
             for i in range(3)]
    rng = np.random.default_rng(2)
    prompts = {r.rid: rng.integers(1, cfg.vocab_size, size=24).astype(
        np.int32) for r in trace}

    def drive(system, handles):
        for _ in range(100000):
            if all(h.req.state is RequestState.DECODING
                   and h.req.decode_instance == 1
                   and 2 <= len(h.tokens) < 8 for h in handles):
                break
            assert system.step(), "backend drained before the barrier"
        FaultInjector(plan, system).poll(system.clock.now())
        return system.drain(timeout=300.0)

    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0))
    h_sim = replay_trace(sim, trace)
    rep_sim = drive(sim, h_sim)

    eng = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params)
    h_eng = [eng.submit(Request(rid=r.rid, arrival=0.0, input_len=24,
                                output_len=8), prompt=prompts[r.rid])
             for r in trace]
    rep_eng = drive(eng, h_eng)

    for rep in (rep_sim, rep_eng):
        assert rep.n_finished == len(trace)
        assert rep.faults["crashes"] == 1
        assert rep.faults["requests_recovered"] == len(trace)
        assert rep.faults["requests_lost"] == 0
    recovered = lambda hs: sorted(h.rid for h in hs if h.req.recoveries)  # noqa: E731
    assert recovered(h_sim) == recovered(h_eng) == [0, 1, 2]
    model = build_model(cfg)
    for h in h_eng:                        # recovered ids == unfaulted greedy
        ref = greedy_reference(cfg, model, params, prompts[h.rid], 8)
        assert [t for t in h.tokens] == ref, f"rid {h.rid} diverged"


# --------------------------------------------------- chaos acceptance


def test_chaos_sim_spike_two_crashes_goodput_and_invariants():
    """Acceptance (ISSUE 4): spike trace, two scripted crashes under
    arrow_elastic — every request completes, the invariant probe never
    fires across every step, and goodput stays >= 80% of the fault-free
    run. Fully deterministic (virtual clock, seeded trace/plan)."""
    p = TRACE_PRESETS["spike"]
    slo = SLO(p.slo_ttft, p.slo_tpot)
    trace = load_trace("spike", rate_scale=2.0, seed=0, duration=60)

    def goodput(rep):
        return sum(1 for h in rep.handles if h.meets_slo()) / \
            max(rep.duration, 1e-9)

    base = Simulator(CFG, n_instances=6, n_prefill=3, policy="arrow_elastic",
                     slo=slo,
                     autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                     max_instances=12))
    replay_trace(base, trace)
    rep_base = base.drain()
    assert rep_base.n_finished == len(trace)

    chaos = Simulator(CFG, n_instances=6, n_prefill=3,
                      policy="arrow_elastic", slo=slo,
                      autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                      max_instances=12),
                      fault_plan=FaultPlan.parse("crash@15;crash@30"))
    replay_trace(chaos, trace)
    while chaos.step():
        check_invariants(chaos, streams=False)   # probe after every step
    check_invariants(chaos)                      # full probe incl. streams
    rep = chaos.report()
    assert rep.n_finished == len(trace), "a request was lost to the crashes"
    assert rep.faults["crashes"] == 2
    assert rep.faults["requests_recovered"] >= 1
    assert rep.faults["replacements"] >= 1
    assert goodput(rep) >= 0.8 * goodput(rep_base)


def test_chaos_engine_timed_plan_streams_match_reference(engine_setup):
    """Engine chaos: a timed FaultPlan crash lands wherever the wall clock
    says — greedy content is schedule-independent, so whatever was lost,
    every recovered stream must equal the unfaulted greedy reference.
    The main wave decodes long enough that the crash usually lands mid-
    serving, and a late straggler arrival guarantees the fault poll still
    fires even on a machine fast enough to drain the wave first (the fused
    step made this a real possibility — never assume the engine is slow)."""
    from repro.engine import ArrowEngineCluster
    from repro.models import build_model
    cfg, params = engine_setup
    eng = ArrowEngineCluster(cfg, n_instances=3, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params,
                             fault_plan=FaultPlan.parse("crash@0.1:target=1"))
    rng = np.random.default_rng(9)
    prompts = {i: rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)
               for i in range(5)}
    out_len = {i: 32 for i in range(4)}
    out_len[4] = 4                               # the straggler backstop
    handles = [eng.submit(Request(rid=i, arrival=0.0 if i < 4 else 0.5,
                                  input_len=20, output_len=out_len[i]),
                          prompt=prompts[i])
               for i in range(5)]
    report = eng.drain(timeout=300.0)
    check_invariants(eng)
    assert report.n_finished == 5
    assert report.faults["crashes"] == 1
    model = build_model(cfg)
    for h in handles:
        ref = greedy_reference(cfg, model, params, prompts[h.rid],
                               out_len[h.rid])
        assert [t for t in h.tokens] == ref, f"rid {h.rid} diverged"
    eng.collect_stats(eng.clock.now())
    assert 1 not in eng.instances and 1 not in eng.pools.all_ids()
