"""Determinism layer for replayable on-device sampling and self-speculative
decoding (DESIGN.md §12).

The contract under test: token selection is a pure function of
``(seed, rid, absolute position, logits)`` — no PRNG counter state exists
anywhere — so a stream replays bit-for-bit across runs, across step modes
(fused vs legacy), across KV migration and crash recovery, and under
self-speculative decoding (which emits exactly the tokens sequential decode
would). ``temperature<=0`` is provably the pre-sampling argmax path, pinned
against golden streams recorded at PR 8 so greedy serving can never drift.

Everything here asserts token *ids* (bit-identity), never timings, so a
loaded CI machine can only time out, not produce a wrong pass.
"""
import json
from pathlib import Path

import jax
import numpy as np
import pytest
from invariants import check_invariants

from repro.configs import get_smoke_config
from repro.core import Request, SLO, SamplingParams
from repro.core.faults import FaultPlan
from repro.engine import ArrowEngineCluster, EngineInstance
from repro.models import build_model

DRAIN_TIMEOUT = 300.0
GOLDEN = Path(__file__).parent / "data" / "golden_streams_pr8.json"


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    return cfg, model, params


def golden_prompts(cfg):
    """The prompts the golden pin was recorded with (seed fixed forever)."""
    rng = np.random.default_rng(123)
    return {i: rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(8, 28))).astype(np.int32)
            for i in range(3)}


def instance_stream(inst, rid, prompt, n_new, sp=None):
    """Sequential prefill+decode on one instance; returns n_new tokens."""
    inst.set_sampling(rid, sp)
    inst.run_prefill(rid, prompt)
    inst.local.start_local_decode(rid, len(prompt), n_new - 1)
    for _ in range(n_new - 1):
        inst.run_decode_iteration([rid])
    return [int(t) for t in inst.generated[rid][:n_new]]


def cluster_streams(cfg, params, *, sampling=None, speculate=0, seed=0,
                    fault_plan=None, n=4, out_len=8, arrivals=None,
                    chunk_tokens=None):
    cluster = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                                 capacity=128, slo=SLO(5.0, 2.0),
                                 params=params, seed=seed,
                                 speculate=speculate, fault_plan=fault_plan,
                                 chunk_tokens=chunk_tokens)
    rng = np.random.default_rng(5)
    prompts = {i: rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)
               for i in range(n)}
    handles = [cluster.submit(
        Request(rid=i, arrival=(arrivals or {}).get(i, 0.0), input_len=20,
                output_len=out_len, sampling=sampling),
        prompt=prompts[i]) for i in range(n)]
    report = cluster.drain(timeout=DRAIN_TIMEOUT)
    check_invariants(cluster)
    assert report.n_finished == n
    return {h.rid: [int(t) for t in h.tokens] for h in handles}, report


# ------------------------------------------------------------ greedy pin

def test_greedy_streams_match_golden_pin(setup):
    """temperature=0 (and sampling=None) must reproduce the argmax streams
    recorded when sampling was introduced — the regression pin that greedy
    serving is byte-identical to the pre-sampling engine."""
    cfg, model, params = setup
    golden = json.loads(GOLDEN.read_text())["greedy"]
    inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    for rid, prompt in golden_prompts(cfg).items():
        got = instance_stream(inst, rid + 100, prompt, 10)
        assert got == golden[str(rid)], f"greedy stream {rid} drifted"
        inst.drop(rid + 100)


def test_sampled_streams_match_golden_pin(setup):
    """Seeded sampled streams are part of the replay contract too: the
    exact ``fold_in(fold_in(key(seed), rid), position)`` derivation and the
    Gumbel-max nucleus rule are pinned, so any change to key order,
    position bookkeeping or the keep-mass rule shows up as a diff here."""
    cfg, model, params = setup
    golden = json.loads(GOLDEN.read_text())["sampled"]
    sp = SamplingParams(temperature=0.9, top_p=0.9, seed=77)
    inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    for rid, prompt in golden_prompts(cfg).items():
        got = instance_stream(inst, rid + 100, prompt, 10, sp=sp)
        assert got == golden[str(rid)], f"sampled stream {rid} drifted"
        inst.drop(rid + 100)


def test_temp0_param_is_exact_greedy(setup):
    """SamplingParams(temperature=0) ≡ sampling=None ≡ argmax; a nucleus
    collapsed to the top-1 token (tiny top_p) also reduces to argmax."""
    cfg, model, params = setup
    inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    prompt = golden_prompts(cfg)[0]
    base = instance_stream(inst, 1, prompt, 8, sp=None)
    inst.drop(1)
    explicit = instance_stream(inst, 1, prompt, 8,
                               sp=SamplingParams(temperature=0.0))
    inst.drop(1)
    collapsed = instance_stream(
        inst, 1, prompt, 8, sp=SamplingParams(temperature=0.7, top_p=1e-9))
    assert explicit == base
    assert collapsed == base


# -------------------------------------------------------------- replay

def test_sampled_replay_bit_identical(setup):
    """The replay guarantee: same trace + same run seed => bit-identical
    sampled streams across independent cluster runs (different wall-clock
    schedules and all); a different seed diverges."""
    cfg, _, params = setup
    sp = SamplingParams(temperature=0.8, top_p=0.9)
    s1, r1 = cluster_streams(cfg, params, sampling=sp, seed=42)
    s2, r2 = cluster_streams(cfg, params, sampling=sp, seed=42)
    assert s1 == s2
    assert r1.sampling["seed"] == 42 and r1.sampling["sampled_requests"] == 4
    s3, _ = cluster_streams(cfg, params, sampling=sp, seed=43)
    assert s3 != s1, "changing the run seed must change sampled streams"


def test_greedy_report_has_no_sampling_section(setup):
    """All-greedy runs keep the pre-PR report shape: the sampling and
    speculation detail dicts stay empty (byte-identical summaries)."""
    cfg, _, params = setup
    _, report = cluster_streams(cfg, params, sampling=None)
    assert report.sampling == {} and report.speculation == {}


def test_per_request_seed_overrides_run_seed(setup):
    """A request-level seed pins its stream regardless of the run seed;
    distinct rids draw distinct keys from the same seed."""
    cfg, _, params = setup
    inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    prompt = golden_prompts(cfg)[1]
    sp = SamplingParams(temperature=1.2, top_p=0.95, seed=11)
    a = instance_stream(inst, 1, prompt, 12, sp=sp)
    inst.drop(1)
    b = instance_stream(inst, 2, prompt, 12, sp=sp)     # same seed, new rid
    inst.drop(2)
    c = instance_stream(inst, 1, prompt, 12, sp=sp)     # exact replay
    inst.drop(1)
    d = instance_stream(inst, 1, prompt, 12,
                        sp=SamplingParams(temperature=1.2, top_p=0.95,
                                          seed=12))
    assert a == c, "same (seed, rid) must replay bit-for-bit"
    assert a != b, "distinct rids must fold to distinct key streams"
    assert a != d, "distinct seeds must fold to distinct key streams"


# ------------------------------------------------------- step-mode parity

def test_fused_vs_legacy_sampled_streams(setup):
    """Sampled streams are step-mode independent: the legacy (eager) path
    selects through the same jitted sampler as the fused step."""
    cfg, _, params = setup
    sp = SamplingParams(temperature=0.9, top_p=0.8, seed=3)
    prompt = golden_prompts(cfg)[2]
    fused = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    legacy = EngineInstance(1, cfg, params, n_slots=4, capacity=128,
                            step_mode="legacy")
    assert instance_stream(fused, 9, prompt, 10, sp=sp) \
        == instance_stream(legacy, 9, prompt, 10, sp=sp)


# ----------------------------------------------- migration / recovery

def test_migration_preserves_sampled_stream(setup):
    """KV migration mid-decode: sampling params travel with the KV and the
    keys are instance-independent, so the continued stream equals the
    uninterrupted one token-for-token."""
    cfg, _, params = setup
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=21)
    prompt = golden_prompts(cfg)[0]
    ref_inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    ref = instance_stream(ref_inst, 7, prompt, 9, sp=sp)
    a = EngineInstance(1, cfg, params, n_slots=4, capacity=128)
    b = EngineInstance(2, cfg, params, n_slots=4, capacity=128)
    a.set_sampling(7, sp)
    got = [a.run_prefill(7, prompt)]
    a.local.start_local_decode(7, len(prompt), 8)
    for _ in range(3):
        got.append(a.run_decode_iteration([7])[7])
    samp = a.kv.samp_of.get(7)
    k, v, L, last, gen = a.export_kv(7)
    assert b.import_kv(7, k, v, L, last, gen, sampling=samp)
    a.drop(7)
    b.local.start_local_decode(7, L, 5)
    for _ in range(5):
        got.append(b.run_decode_iteration([7])[7])
    assert got == ref


def test_chunked_prefill_preserves_sampled_stream(setup):
    """Chunked prefill (the §11 deflection micro-batch mechanism) samples
    its first output token at the same absolute position whole-prompt
    prefill does, so chunking never changes a sampled stream."""
    cfg, _, params = setup
    sp = SamplingParams(temperature=0.8, top_p=0.9)
    whole, _ = cluster_streams(cfg, params, sampling=sp, seed=13)
    chunked, _ = cluster_streams(cfg, params, sampling=sp, seed=13,
                                 chunk_tokens=8)
    assert chunked == whole


def test_crash_recovery_preserves_sampled_stream(setup):
    """Crash recovery re-prefills prompt+emitted tokens; the recovery o_1
    recomputes at the same absolute position the lost next-token would have
    sampled at, so recovered sampled streams are bit-identical to the
    unfaulted run (not just greedy ones — ISSUE 8 acceptance)."""
    cfg, _, params = setup
    sp = SamplingParams(temperature=0.8, top_p=0.9)
    arrivals = {3: 0.5}                     # straggler keeps the poll alive
    base, _ = cluster_streams(cfg, params, sampling=sp, seed=9, n=4,
                              out_len=24, arrivals=arrivals)
    chaos, rep = cluster_streams(
        cfg, params, sampling=sp, seed=9, n=4, out_len=24,
        arrivals=arrivals,
        fault_plan=FaultPlan.parse("crash@0.1:target=1"))
    assert rep.faults["crashes"] == 1
    assert chaos == base, "recovered sampled streams diverged"


# -------------------------------------------------------- speculation

def test_speculative_streams_bit_identical(setup):
    """Self-speculative decoding emits exactly the tokens sequential decode
    would (every accepted draft was verified against the same key and
    context) — speculation changes throughput, never content."""
    cfg, _, params = setup
    sp = SamplingParams(temperature=0.8, top_p=0.9)
    base, _ = cluster_streams(cfg, params, sampling=sp, seed=4, out_len=12)
    spec, rep = cluster_streams(cfg, params, sampling=sp, seed=4,
                                out_len=12, speculate=4)
    assert spec == base
    assert rep.speculation["rounds"] > 0
    assert rep.speculation["emitted"] > 0
    assert 0.0 <= rep.speculation["acceptance"] <= 1.0


def test_speculative_greedy_matches_golden_pin(setup):
    """Greedy + speculation still equals the pinned argmax streams."""
    cfg, _, params = setup
    golden = json.loads(GOLDEN.read_text())["greedy"]
    inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128,
                          speculate=3, draft_layers=1)
    for rid, prompt in golden_prompts(cfg).items():
        inst.run_prefill(rid + 200, prompt)
        inst.local.start_local_decode(rid + 200, len(prompt), 9)
        while len(inst.generated[rid + 200]) < 10:
            pend = inst.dispatch_step([rid + 200], [])
            inst.finalize_step(pend)
        assert inst.generated[rid + 200][:10] == golden[str(rid)]
        inst.drop(rid + 200)


# ------------------------------------------------------------- simulator

def test_sim_sampling_and_speculation_modeled():
    """The simulator mirrors the engine's accounting: sampled requests and
    run seed land in the report, speculative rounds emit the modeled
    multi-token streams (exact output lengths, strictly ordered times) and
    a same-seed replay is event-for-event identical."""
    from repro.core.serving import replay_trace
    from repro.sim import Simulator
    from repro.traces import load_trace
    cfg = get_smoke_config("qwen3-1.7b")
    trace = load_trace("azure_code", rate_scale=4.0, seed=0, duration=20.0)
    for r in trace:
        r.sampling = SamplingParams(temperature=0.7)

    def run():
        sim = Simulator(cfg, n_instances=2, n_prefill=1, seed=6,
                        speculate=4, spec_accept=0.8)
        replay_trace(sim, trace)
        rep = sim.drain()
        check_invariants(sim)
        return sim, rep

    sim1, rep1 = run()
    assert rep1.n_finished == len(trace)
    assert rep1.sampling["seed"] == 6
    assert rep1.sampling["sampled_requests"] == len(trace)
    assert rep1.speculation["rounds"] > 0
    # modeled lengths are exact: every stream has its trace output length
    for h in sim1.handles.values():
        assert len(h.tokens) == h.req.output_len
    # modeled acceptance tracks the configured per-draft acceptance
    assert 0.3 <= rep1.speculation["acceptance"] <= 1.0
    _, rep2 = run()
    assert rep1.summary() == rep2.summary(), "sim replay must be exact"
