"""Self-healing layer (ISSUE 10, DESIGN.md §14): DEGRADED lifecycle guards,
peer-median straggler detection with hysteresis/probation/escalation, the
bounded transfer retry ladder on both backends (including checksum corruption
on the engine), SLO-aware preemption at the §5.4 memory gate, sim/engine
parity at state barriers, and the health-off byte-identity guarantee."""
import numpy as np
import pytest
from invariants import check_invariants

from repro.configs import get_config, get_smoke_config
from repro.core import (FaultPlan, HealthConfig, InstancePools, Lifecycle,
                        Pool, Request, SLO)
from repro.core.request import RequestState
from repro.core.serving import replay_trace
from repro.sim import Simulator

CFG = get_config("gemma-2b")


# --------------------------------------------------------------- helpers


def barrier_sim(n_instances=2, n_prefill=1, n_requests=4, output_lens=None,
                **kw):
    """Arrow sim driven until every request decodes on instance 1 (decode
    placement deterministically concentrates there: scraped running-tokens
    are all zero between ticks, so ties break to the lowest decode id) with
    >= 2 tokens streamed and none finished — the deterministic state barrier
    the health tests fire quarantine/retire/faults from."""
    sim = Simulator(CFG, n_instances=n_instances, n_prefill=n_prefill,
                    policy="arrow", slo=SLO(5.0, 2.0), **kw)
    lens = output_lens or [8] * n_requests
    trace = [Request(rid=i, arrival=0.0, input_len=24, output_len=lens[i])
             for i in range(n_requests)]
    handles = replay_trace(sim, trace)
    for _ in range(100000):
        if all(h.req.state is RequestState.DECODING
               and h.req.decode_instance == 1
               and 2 <= len(h.tokens) < h.req.output_len for h in handles):
            break
        assert sim.step(), "sim drained before the mid-decode barrier"
    return sim, handles


def feed_intervals(system, t, victim, slow=0.060, fast=0.006, band=None):
    """Inject one synthetic TPOT sample per ACTIVE instance and tick the
    monitor (collect_stats runs the HealthMonitor right after the scrape).
    ``band`` overrides the victim's interval (hysteresis-band probing)."""
    for iid in system.pools.active_ids():
        iv = (band if band is not None else slow) if iid == victim else fast
        system.monitor.record_iteration(iid, t, 1, iv)
    system.collect_stats(t)


# --------------------------------------- DEGRADED lifecycle (core/pools)


def test_degraded_lifecycle_guards():
    pools = InstancePools(range(4), n_prefill=2)
    pools.degrade(2)                                 # ACTIVE -> DEGRADED
    assert pools.lifecycle_of(2) is Lifecycle.DEGRADED
    assert pools.degraded_ids() == [2]
    # never schedulable, but still a live member of the cluster
    assert 2 not in pools.prefill_capable() + pools.decode_capable()
    assert 2 not in pools.active_ids() and 2 in pools.all_ids()
    with pytest.raises(ValueError, match="cannot quarantine instance 2"):
        pools.degrade(2)                             # already DEGRADED
    pools.restore(2)                                 # probation passed
    assert pools.lifecycle_of(2) is Lifecycle.ACTIVE
    assert 2 in pools.decode_capable()
    with pytest.raises(ValueError, match="cannot restore instance 2"):
        pools.restore(2)                             # not DEGRADED anymore
    pools.begin_retire(3)
    with pytest.raises(ValueError, match="cannot quarantine instance 3"):
        pools.degrade(3)                             # RETIRING is terminal
    # escalation path: a DEGRADED instance may hard-fail and be removed
    pools.degrade(2)
    pools.fail(2)
    assert pools.lifecycle_of(2) is Lifecycle.FAILED
    pools.remove_instance(2)
    assert pools.degraded_ids() == [] and 2 not in pools.all_ids()


# ------------------------------- straggler detection / quarantine (sim)


def test_straggler_quarantined_evacuated_and_restored():
    """End-to-end §14 loop on the simulator: a sustained straggler is
    quarantined after ``sustain_s``, its decode residents are drained away
    through the migration manager, and probation re-admits it to ACTIVE —
    with every stream completing untouched."""
    sim, handles = barrier_sim(n_instances=4, n_prefill=1, health=True)
    for i in sim.pools.all_ids():
        sim.monitor.reset_intervals(i)      # synthetic samples only
    t0 = sim.clock.now()
    quarantined_at = None
    for k in range(1, 60):
        t = t0 + 0.1 * k
        feed_intervals(sim, t, victim=1)
        if sim.pools.lifecycle_of(1) is Lifecycle.DEGRADED:
            quarantined_at = t
            break
    assert quarantined_at is not None, "straggler never quarantined"
    # the sustain clock armed on the first slow sample: quarantine fires on
    # the first tick >= sustain_s later
    assert quarantined_at - (t0 + 0.1) == pytest.approx(
        sim.health_cfg.sustain_s, abs=0.1001)
    assert 1 not in sim.pools.decode_capable()
    assert not sim.locals[1].decode_running, "residents not evacuated"
    for h in handles:                        # planned move, not a crash
        assert h.req.state in (RequestState.MIGRATING, RequestState.DECODING)
    check_invariants(sim)
    rep = sim.drain()                        # ticks re-arm while DEGRADED
    assert rep.n_finished == len(handles)
    for h in handles:
        assert len(h.tokens) == h.req.output_len
        assert h.req.recoveries == 0         # KV moved intact, never lost
    assert sim.pools.lifecycle_of(1) is Lifecycle.ACTIVE  # probation passed
    assert rep.health["quarantines"] == 1
    assert rep.health["restores"] == 1
    assert rep.health.get("escalations", 0) == 0
    check_invariants(sim)


def test_transient_slowdown_never_quarantines():
    """A blip shorter than ``sustain_s`` clears the sustain clock (the score
    drops below ``clear_factor`` x median) and must not quarantine."""
    sim, _ = barrier_sim(n_instances=4, n_prefill=1, health=True)
    for i in sim.pools.all_ids():
        sim.monitor.reset_intervals(i)
    t0 = sim.clock.now()
    for k in range(1, 50):
        t = t0 + 0.1 * k
        # 0.3s at 3.3x the peer median: the windowed average decays below
        # clear_factor x median well before sustain_s elapses
        feed_intervals(sim, t, victim=1, band=0.020 if k <= 3 else 0.006)
    assert sim.pools.degraded_ids() == []
    assert sim.health_stats["quarantines"] == 0


def test_hysteresis_band_keeps_sustain_clock_running():
    """Once armed at >= ``straggler_factor`` x median, a score lingering in
    the hysteresis band (between ``clear_factor`` and ``straggler_factor``
    x median) keeps the sustain clock running — flapping just under the arm
    threshold cannot dodge quarantine."""
    sim, _ = barrier_sim(n_instances=4, n_prefill=1, health=True)
    for i in sim.pools.all_ids():
        sim.monitor.reset_intervals(i)
    t0 = sim.clock.now()
    for k in range(1, 60):
        t = t0 + 0.1 * k
        # two samples above 3x median arm the clock; then the windowed score
        # settles at ~2.5x median — inside the 1.5x..3x band, never clearing
        feed_intervals(sim, t, victim=1, band=0.020 if k <= 2 else 0.015)
        if sim.pools.lifecycle_of(1) is Lifecycle.DEGRADED:
            break
    assert sim.pools.lifecycle_of(1) is Lifecycle.DEGRADED

    # complement: dipping below clear_factor x median resets the clock, and
    # band-level samples alone never re-arm it
    sim2, _ = barrier_sim(n_instances=4, n_prefill=1, health=True)
    for i in sim2.pools.all_ids():
        sim2.monitor.reset_intervals(i)
    t0 = sim2.clock.now()
    for k in range(1, 60):
        t = t0 + 0.1 * k
        # arm briefly, clear with fast samples, then sit at 2x median
        band = 0.020 if k <= 2 else (0.006 if k <= 10 else 0.012)
        feed_intervals(sim2, t, victim=1, band=band)
    assert sim2.pools.degraded_ids() == []
    assert sim2.health_stats["quarantines"] == 0


def test_median_baseline_needs_peers_and_resists_self_drag():
    """Below ``min_peers`` baselines the detector stays silent; and with the
    straggler itself dominating the sample set, the *median* baseline keeps
    its own slowness from reading as peer-relative deviation."""
    # two instances with data < min_peers=3: never quarantines
    sim, _ = barrier_sim(n_instances=2, n_prefill=1, health=True)
    for i in sim.pools.all_ids():
        sim.monitor.reset_intervals(i)
    t0 = sim.clock.now()
    for k in range(1, 40):
        feed_intervals(sim, t0 + 0.1 * k, victim=1)
    assert sim.health_stats["quarantines"] == 0

    # min_peers=1, only the victim has samples: the median IS its own
    # interval, so it can never be straggler_factor x above it
    sim2, _ = barrier_sim(n_instances=4, n_prefill=1,
                          health=HealthConfig(min_peers=1))
    for i in sim2.pools.all_ids():
        sim2.monitor.reset_intervals(i)
    t0 = sim2.clock.now()
    for k in range(1, 40):
        t = t0 + 0.1 * k
        sim2.monitor.record_iteration(1, t, 1, 0.060)   # victim only
        sim2.collect_stats(t)
    assert sim2.health_stats["quarantines"] == 0


def test_relapsing_straggler_escalates_to_failure():
    """An instance that keeps re-tripping detection after each probation
    re-admission stays inside one episode; past ``deadline_s`` the monitor
    gives up and hard-fails it (§8 teardown)."""
    hc = HealthConfig(sustain_s=0.5, probation_s=0.5, deadline_s=3.0)
    sim = Simulator(CFG, n_instances=4, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0), health=hc)
    t0 = 0.0
    for k in range(1, 200):
        t = t0 + 0.1 * k
        # escalation hard-fails the instance; the corpse is finalized (and
        # removed from the pools entirely) by the same tick
        if 1 not in sim.pools.all_ids() or \
                sim.pools.lifecycle_of(1) is Lifecycle.FAILED:
            break
        feed_intervals(sim, t, victim=1)     # active_ids skips it while DEGRADED
    assert sim.health_stats["escalations"] == 1
    assert sim.health_stats["quarantines"] >= 2      # it relapsed
    assert sim.health_stats["restores"] >= 1
    sim.collect_stats(sim.clock.now())               # bury the corpse
    assert 1 not in sim.pools.all_ids()


def test_episode_closes_after_clean_probation():
    """One quarantine, then clean behaviour after re-admission: the episode
    closes after ``sustain_s`` clean and the deadline never fires."""
    hc = HealthConfig(sustain_s=0.5, probation_s=0.5, deadline_s=3.0)
    sim = Simulator(CFG, n_instances=4, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0), health=hc)
    relapsed = False
    for k in range(1, 100):
        t = 0.1 * k
        if sim.health_stats["restores"] >= 1:
            relapsed = True                  # healthy from here on
        feed_intervals(sim, t, victim=1, band=0.006 if relapsed else None)
    assert sim.health_stats["quarantines"] == 1
    assert sim.health_stats["restores"] == 1
    assert sim.health_stats["escalations"] == 0
    assert sim.pools.lifecycle_of(1) is Lifecycle.ACTIVE
    assert sim.health_monitor._episode_start == {}   # episode closed


# ----------------------------------- transfer retry ladder (sim backend)


def test_sim_retry_ladder_recovers_within_budget():
    """A short droptransfer window fails the first attempt of each
    evacuation transfer; bounded backoff retries land after the window —
    no transfer exhausts its budget and no request pays a re-prefill."""
    sim, handles = barrier_sim(n_instances=4, n_prefill=1, health=True)
    now = sim.clock.now()
    sim.apply_transfer_drop(1.0, now + 1e-9)   # only launches at `now` drop
    sim.begin_retire(1, now)
    rep = sim.drain()
    n = len(handles)
    assert rep.health["xfer_drops"] >= n                 # first attempts
    assert rep.health["xfer_retries"] == rep.health["xfer_drops"]
    assert rep.health["xfer_failures"] == 0
    assert rep.n_finished == n
    for h in handles:
        assert len(h.tokens) == h.req.output_len
        assert h.req.recoveries == 0           # the ladder saved the KV move
    check_invariants(sim)


def test_sim_retry_ladder_exhausts_to_reprefill_recovery():
    """Every attempt drops (window outlives the whole ladder): after
    ``xfer_retries`` retries the source copy is released and the request
    falls through to §8 re-prefill recovery — streams stay token-exact."""
    sim, handles = barrier_sim(n_instances=2, n_prefill=1, health=True)
    now = sim.clock.now()
    sim.apply_transfer_drop(1.0, now + 9999.0)
    sim.begin_retire(1, now)
    rep = sim.drain()
    n = len(handles)
    budget = sim.health_cfg.xfer_retries
    assert rep.health["xfer_drops"] == n * (budget + 1)
    assert rep.health["xfer_retries"] == n * budget
    assert rep.health["xfer_failures"] == n
    assert rep.n_finished == n
    for h in handles:
        assert len(h.tokens) == h.req.output_len
        assert h.req.recoveries == 1
    assert 1 not in sim.pools.all_ids()        # retire finalized regardless
    check_invariants(sim)


def test_sim_netslow_timeout_fails_transfer():
    """A degraded interconnect inflates transfer durations past the
    per-transfer timeout: each attempt times out, the ladder exhausts, and
    re-prefill recovery completes the streams."""
    hc = HealthConfig(xfer_timeout_s=0.001)
    sim, handles = barrier_sim(n_instances=2, n_prefill=1, health=hc)
    now = sim.clock.now()
    sim.apply_netslow(1e6, now + 9999.0)
    sim.begin_retire(1, now)
    rep = sim.drain()
    n = len(handles)
    assert rep.health["xfer_drops"] == n * (hc.xfer_retries + 1)
    assert rep.health["xfer_failures"] == n
    assert rep.n_finished == n
    assert all(h.req.recoveries == 1 for h in handles)
    check_invariants(sim)


def test_health_off_drop_falls_straight_to_recovery():
    """Without ``--health`` the retry budget is zero: a dropped transfer is
    not retried — it falls straight through to re-prefill recovery (the
    detection-off baseline bench_chaos measures against). The droptransfer
    window arrives through the FaultPlan grammar and the real FaultInjector
    here, fired at the state barrier (timed windows during placement would
    make every initial decode migration loop through recovery instead)."""
    from repro.core import FaultInjector
    plan = FaultPlan.parse("droptransfer@0:p=1,duration=9999")
    sim, handles = barrier_sim(n_instances=2, n_prefill=1, health=False)
    FaultInjector(plan, sim).poll(sim.clock.now())
    sim.begin_retire(1, sim.clock.now())
    rep = sim.drain()
    n = len(handles)
    assert rep.health["xfer_drops"] == n == rep.health["xfer_failures"]
    assert rep.health["xfer_retries"] == 0
    assert rep.n_finished == n
    assert all(h.req.recoveries == 1 for h in handles)
    assert all(len(h.tokens) == h.req.output_len for h in handles)
    check_invariants(sim)


# ------------------------------------ SLO-aware preemption (§5.4 gate)


def preemption_blocked_gate(system, collect_now, unclamp=True):
    """Shared driver: two residents on instance 1 (rid 0 short, rid 1 long),
    one on instance 2 (rid 2); clamp instance 1's KV capacity so rid 2's
    evacuation migration blocks the §5.4 gate, then retire instance 2.
    ``unclamp`` restores the real capacity afterwards so the preempted
    victim's re-admission can't re-block the gate (keeps the victim set a
    single deterministic request)."""
    lens = {0: 8, 1: 32, 2: 8}
    handles = [system.submit(Request(rid=i, arrival=0.0, input_len=24,
                                     output_len=lens[i]))
               for i in (0, 1)]
    for _ in range(100000):
        if all(h.req.state is RequestState.DECODING
               and h.req.decode_instance == 1
               and 2 <= len(h.tokens) < h.req.output_len for h in handles):
            break
        assert system.step(), "drained before the two-resident barrier"
    # scrape now so decode placement sees instance 1 loaded -> rid 2 lands
    # on instance 2 (stale all-zero stats would tie-break back onto 1)
    system.collect_stats(collect_now())
    handles.append(system.submit(Request(rid=2, arrival=0.0, input_len=24,
                                         output_len=8)))
    for _ in range(100000):
        h = handles[2]
        if h.req.state is RequestState.DECODING \
                and h.req.decode_instance == 2 and len(h.tokens) >= 2:
            break
        assert system.step(), "rid 2 never decoded on instance 2"
    loc1 = system.local_of(1)
    kv2 = system.local_of(2).decode_running[2].context_len
    # blocked by exactly one token: any preempted resident frees enough
    real_capacity = loc1.kv_capacity
    loc1.kv_capacity = loc1.kv_used + kv2 - 1
    system.begin_retire(2, system.clock.now())   # evacuation targets only 1
    if unclamp:
        loc1.kv_capacity = real_capacity
    return handles


def test_preemption_frees_blocked_memory_gate_sim():
    """The §5.4 gate refuses rid 2's evacuation migration and eviction can't
    help (no prefix cache): preemption releases the lowest-value resident —
    rid 1, the one with the most remaining output (least sunk progress) —
    and re-dispatches it through §8 recovery. Streams stay token-exact."""
    sim = Simulator(CFG, n_instances=3, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0),
                    health=HealthConfig(preemption=True))
    handles = preemption_blocked_gate(sim, lambda: sim.clock.now())
    assert sim.health_stats["preemptions"] == 1
    assert handles[1].req.recoveries == 1      # the long-remaining victim
    assert handles[0].req.recoveries == 0
    assert handles[2].req.recoveries == 0      # the migration was admitted
    rep = sim.drain()
    assert rep.n_finished == 3
    assert all(len(h.tokens) == h.req.output_len for h in handles)
    assert rep.health["preemptions"] == 1
    check_invariants(sim)


def test_preemption_disabled_gate_stays_blocked():
    """health on but preemption off (the default): the blocked gate refuses
    the migration and nothing is preempted — the §5.4 behaviour of PR 9."""
    sim = Simulator(CFG, n_instances=3, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0), health=True)
    handles = preemption_blocked_gate(sim, lambda: sim.clock.now(),
                                      unclamp=False)
    assert sim.health_stats["preemptions"] == 0
    assert all(h.req.recoveries == 0 for h in handles)
    assert sim.locals[1].migration_queue       # still parked at the gate
    # rids 0/1 finishing frees KV; the FCFS gate then admits rid 2
    rep = sim.drain()
    assert rep.n_finished == 3
    assert all(len(h.tokens) == h.req.output_len for h in handles)
    check_invariants(sim)


def test_preemption_victim_ordering():
    """Victim selection is (tenant credits asc, tier batch-first, remaining
    desc, rid): broke tenants before funded ones, batch before interactive,
    longest-remaining (least sunk progress) first."""
    from repro.core.tenants import TenantRegistry
    reg = TenantRegistry()
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0), tenants=reg,
                    health=HealthConfig(preemption=True))
    loc = sim.locals[1]
    tiers = {0: "interactive", 1: "standard", 2: "batch", 3: "batch"}
    for rid in range(4):
        sim.submit(Request(rid=rid, arrival=1e9, input_len=8, output_len=4),
                   tier=tiers[rid], tenant_id=f"t{rid}")
        loc.start_local_decode(rid, 100, 50 if rid == 3 else 10)
    key = lambda rid: sim._preemption_key(rid, loc)  # noqa: E731
    # equal credits: batch tier first, longest remaining breaks the tie
    assert min(loc.decode_running, key=key) == 3
    # a broke tenant outranks tier: its interactive request goes first
    reg.ledger._balance["t0"] = -50.0
    assert min(loc.decode_running, key=key) == 0


def test_preemption_rate_limiter_refuses_thrash():
    """At most ``preempt_limit`` preemptions per instance per window: a full
    window refuses further preemptions (counted, no side effects) until
    entries age out."""
    from collections import deque
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0),
                    health=HealthConfig(preemption=True, preempt_limit=1,
                                        preempt_window_s=10.0))
    loc = sim.locals[1]
    sim.submit(Request(rid=0, arrival=1e9, input_len=8, output_len=4))
    loc.start_local_decode(0, 100, 4)
    sim._preempt_log[1] = deque([sim.clock.now()])   # window already full
    assert sim._maybe_preempt(1, loc) is False
    assert sim.health_stats["preempt_refused"] == 1
    assert sim.health_stats["preemptions"] == 0
    assert 0 in loc.decode_running                   # resident untouched


# ------------------------------------------------ engine + parity tests


@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.models import build_model
    cfg = get_smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    return cfg, params


def greedy_reference(cfg, model, params, prompt, n_new):
    import jax
    import jax.numpy as jnp
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_capacity=128))(params, batch)
    toks = [int(jnp.argmax(logits[0, len(prompt) - 1, :cfg.vocab_size]))]
    step = jax.jit(model.decode)
    pos = len(prompt)
    for _ in range(n_new - 1):
        db = {"token": jnp.asarray([[toks[-1]]], jnp.int32),
              "pos": jnp.asarray([pos], jnp.int32)}
        logits, cache = step(params, cache, db)
        toks.append(int(jnp.argmax(logits[0, 0, :cfg.vocab_size])))
        pos += 1
    return toks


def test_state_checksum_detects_corruption():
    from repro.engine.instance import state_checksum
    payload = [np.arange(64, dtype=np.float32).reshape(8, 8),
               np.arange(16, dtype=np.int32)]
    ref = state_checksum(payload)
    assert ref == state_checksum([np.array(p, copy=True) for p in payload])
    flipped = [np.array(p, copy=True) for p in payload]
    flipped[0].view(np.uint8).reshape(-1)[0] ^= 0xFF
    assert state_checksum(flipped) != ref


def test_engine_import_rejects_corrupt_payload_pre_alloc(engine_setup):
    """A checksum mismatch raises before slot allocation: the importer's
    slot set and KV books are untouched, so the sender can simply retry."""
    from repro.engine.instance import (CorruptPayload, EngineInstance,
                                       state_checksum)
    cfg, params = engine_setup
    a = EngineInstance(0, cfg, params, n_slots=2, capacity=128)
    b = EngineInstance(1, cfg, params, n_slots=2, capacity=128)
    prompt = np.arange(1, 25, dtype=np.int32)
    a.run_prefill(7, prompt)
    a.local.start_local_decode(7, len(prompt), 4)
    a.run_decode_iteration([7])
    payload, L, last, gen = a.export_state(7)
    good = state_checksum(payload)
    wire = [np.array(np.asarray(p), copy=True) for p in payload]
    wire[0].view(np.uint8).reshape(-1)[0] ^= 0xFF
    with pytest.raises(CorruptPayload):
        b.import_state(7, wire, L, last, list(gen), checksum=good)
    assert 7 not in b.kv.slot_of                  # nothing allocated
    assert b.import_state(7, payload, L, last, list(gen), checksum=good)
    assert 7 in b.kv.slot_of                      # clean retry lands


def engine_barrier(eng, handles):
    for _ in range(100000):
        if all(h.req.state is RequestState.DECODING
               and h.req.decode_instance == 1
               and 2 <= len(h.tokens) < h.req.output_len for h in handles):
            break
        assert eng.step(), "engine drained before the mid-decode barrier"


def test_sim_engine_quarantine_parity(engine_setup):
    """Acceptance (ISSUE 10): identical synthetic TPOT samples at a state
    barrier produce the *same quarantine decision on the same tick* on both
    backends, and the engine's evacuated streams match the unfaulted greedy
    reference after restore."""
    from repro.engine import ArrowEngineCluster
    from repro.models import build_model
    cfg, params = engine_setup
    hc = HealthConfig(probation_s=0.2, deadline_s=1e9)
    trace = [Request(rid=i, arrival=0.0, input_len=24, output_len=8)
             for i in range(3)]
    rng = np.random.default_rng(3)
    prompts = {r.rid: rng.integers(1, cfg.vocab_size, size=24).astype(
        np.int32) for r in trace}

    def quarantine_tick(system):
        # absolute synthetic tick times on both backends: the HealthMonitor
        # only compares injected times against each other, and anchoring at
        # the engine's (large) wall clock would round the sustain comparison
        # differently than the sim's small virtual clock
        for i in system.pools.all_ids():
            system.monitor.reset_intervals(i)
        for k in range(1, 60):
            feed_intervals(system, 0.1 * k, victim=1)
            if system.pools.lifecycle_of(1) is Lifecycle.DEGRADED:
                return k
        return None

    sim = Simulator(CFG, n_instances=4, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0), health=hc)
    h_sim = replay_trace(sim, trace)
    engine_barrier(sim, h_sim)
    k_sim = quarantine_tick(sim)

    eng = ArrowEngineCluster(cfg, n_instances=4, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params,
                             health=hc)
    h_eng = [eng.submit(Request(rid=r.rid, arrival=0.0, input_len=24,
                                output_len=8), prompt=prompts[r.rid])
             for r in trace]
    engine_barrier(eng, h_eng)
    k_eng = quarantine_tick(eng)

    assert k_sim is not None and k_sim == k_eng   # same decision, same tick
    assert sim.pools.degraded_ids() == eng.pools.degraded_ids() == [1]

    # the decision parity is established; drain records *real* engine
    # iteration intervals (machine-load dependent), so raise the detection
    # threshold out of reach on both backends — otherwise a loaded CI box
    # can legitimately re-quarantine mid-drain and break quarantines == 1
    proof = HealthConfig(straggler_factor=1e9, clear_factor=1e8,
                         probation_s=0.2, deadline_s=1e9)
    sim.health_monitor.cfg = proof
    eng.health_monitor.cfg = proof

    rep_sim = sim.drain()
    rep_eng = eng.drain(timeout=300.0)
    # re-admission: the sim's tick events re-arm while DEGRADED; drive the
    # engine's monitor explicitly past probation. Continue the synthetic
    # tick series — on a warm-cache machine the whole run can finish in
    # under probation_s of *wall* time, so waiting on eng.clock.now() to
    # pass the synthetic probation deadline would hang at DEGRADED forever
    for k in range(200):
        if eng.pools.lifecycle_of(1) is Lifecycle.ACTIVE:
            break
        eng.collect_stats(0.1 * (60 + k))
    for system, rep in ((sim, rep_sim), (eng, rep_eng)):
        assert rep.n_finished == len(trace)
        assert system.health_stats["quarantines"] == 1
        assert system.pools.lifecycle_of(1) is Lifecycle.ACTIVE
        check_invariants(system)
    model = build_model(cfg)
    for h in h_eng:                 # evacuation is transparent to content
        ref = greedy_reference(cfg, model, params, prompts[h.rid], 8)
        assert [t for t in h.tokens] == ref, f"rid {h.rid} diverged"


def test_sim_engine_retry_exhaustion_parity(engine_setup):
    """Acceptance (ISSUE 10): under a total drop window the two backends
    walk the identical retry ladder — equal drop/retry/failure counters and
    the same recovered-rid set — and every engine stream (re-prefilled
    through §8 recovery) equals the unfaulted greedy reference."""
    from repro.engine import ArrowEngineCluster
    from repro.models import build_model
    cfg, params = engine_setup
    trace = [Request(rid=i, arrival=0.0, input_len=24, output_len=8)
             for i in range(3)]
    rng = np.random.default_rng(5)
    prompts = {r.rid: rng.integers(1, cfg.vocab_size, size=24).astype(
        np.int32) for r in trace}

    def drive(system, handles):
        engine_barrier(system, handles)
        now = system.clock.now()
        system.apply_transfer_drop(1.0, now + 9999.0)
        system.begin_retire(1, now)
        return system.drain(timeout=300.0)

    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0), health=True)
    rep_sim = drive(sim, replay_trace(sim, trace))

    eng = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params,
                             health=True)
    h_eng = [eng.submit(Request(rid=r.rid, arrival=0.0, input_len=24,
                                output_len=8), prompt=prompts[r.rid])
             for r in trace]
    rep_eng = drive(eng, h_eng)

    n, budget = len(trace), 3
    for rep in (rep_sim, rep_eng):
        assert rep.n_finished == n
        for key, want in (("xfer_drops", n * (budget + 1)),
                          ("xfer_retries", n * budget),
                          ("xfer_failures", n)):
            assert rep.health[key] == want, (key, rep.health)
    assert rep_eng.health["xfer_corrupt"] == n * (budget + 1)  # engine-only
    recovered = sorted(h.rid for h in h_eng if h.req.recoveries == 1)
    assert recovered == [r.rid for r in trace]
    model = build_model(cfg)
    for h in h_eng:
        ref = greedy_reference(cfg, model, params, prompts[h.rid], 8)
        assert [t for t in h.tokens] == ref, f"rid {h.rid} diverged"
    check_invariants(eng)


def test_sim_engine_preemption_victim_parity(engine_setup):
    """Acceptance (ISSUE 10): the same blocked-gate state picks the same
    preemption victim on both backends, and the engine's preempted stream
    (recovered via re-prefill) stays greedy-identical."""
    from repro.engine import ArrowEngineCluster
    from repro.models import build_model
    cfg, params = engine_setup
    sim = Simulator(CFG, n_instances=3, n_prefill=1, policy="arrow",
                    slo=SLO(5.0, 2.0),
                    health=HealthConfig(preemption=True))
    h_sim = preemption_blocked_gate(sim, lambda: sim.clock.now())
    rep_sim = sim.drain()

    eng = ArrowEngineCluster(cfg, n_instances=3, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params,
                             health=HealthConfig(preemption=True))
    # the engine path needs real prompts: mirror preemption_blocked_gate
    rng = np.random.default_rng(11)
    prompts = {i: rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)
               for i in range(3)}
    lens = {0: 8, 1: 32, 2: 8}
    h_eng = [eng.submit(Request(rid=i, arrival=0.0, input_len=24,
                                output_len=lens[i]), prompt=prompts[i])
             for i in (0, 1)]
    engine_barrier(eng, h_eng)
    eng.collect_stats(eng.clock.now())
    h_eng.append(eng.submit(Request(rid=2, arrival=0.0, input_len=24,
                                    output_len=8), prompt=prompts[2]))
    for _ in range(100000):
        h = h_eng[2]
        if h.req.state is RequestState.DECODING \
                and h.req.decode_instance == 2 and len(h.tokens) >= 2:
            break
        assert eng.step(), "rid 2 never decoded on instance 2"
    loc1 = eng.local_of(1)
    kv2 = eng.local_of(2).decode_running[2].context_len
    real_capacity = loc1.kv_capacity
    loc1.kv_capacity = loc1.kv_used + kv2 - 1
    eng.begin_retire(2, eng.clock.now())
    loc1.kv_capacity = real_capacity
    rep_eng = eng.drain(timeout=300.0)

    victims = lambda hs: sorted(h.rid for h in hs if h.req.recoveries)  # noqa: E731
    assert victims(h_sim) == victims(h_eng) == [1]
    for rep in (rep_sim, rep_eng):
        assert rep.n_finished == 3
        assert rep.health["preemptions"] == 1
    model = build_model(cfg)
    for h in h_eng:
        ref = greedy_reference(cfg, model, params, prompts[h.rid],
                               lens[h.rid])
        assert [t for t in h.tokens] == ref, f"rid {h.rid} diverged"
    check_invariants(eng)


# ------------------------------------------- health-off byte identity


def test_health_off_and_on_identical_without_faults():
    """Arming the layer must not perturb a healthy run: identical streams
    and summary either way, and the health section stays empty (so reports
    from health-off runs are byte-identical to pre-§14 builds)."""
    def run(health):
        sim = Simulator(CFG, n_instances=4, n_prefill=2, policy="arrow",
                        slo=SLO(5.0, 2.0), health=health)
        trace = [Request(rid=i, arrival=0.05 * i, input_len=64, output_len=8)
                 for i in range(12)]
        handles = replay_trace(sim, trace)
        rep = sim.drain()
        return rep, [(h.rid, len(h.tokens), h.req.finish_time)
                     for h in handles]
    rep_off, streams_off = run(False)
    rep_on, streams_on = run(True)
    assert streams_off == streams_on
    assert rep_off.summary() == rep_on.summary()
    assert rep_off.health == {} and rep_on.health == {}
