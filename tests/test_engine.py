"""Integration tests for the real-compute engine: correctness of the KV slot
cache, cross-instance KV transfer, and end-to-end Arrow serving with real JAX
forward passes (tiny dense model on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from invariants import check_invariants

from repro.configs import get_smoke_config
from repro.core import Request, SLO
from repro.engine import (ArrowEngineCluster, EngineInstance, NoFreeSlots,
                          ServeRequest)
from repro.models import build_model

# Engine runs are wall-clock driven: on a loaded CI machine jit compiles and
# cooperative round-robin passes stretch. Budget generously — assertions
# below are value/ordering based (token ids, monotone times, invariant
# probes), never exact timings and never absolute-seconds thresholds on the
# scraped metrics (deflaked in ISSUE 2, re-audited in ISSUE 4 and again in
# ISSUE 5 — which also had to deflake the *fast*-engine direction: never
# assume N engine steps cover a given wall-clock span, the fused step makes
# empty steps microsecond-cheap), so machine speed can only time out, not
# produce a wrong pass.
DRAIN_TIMEOUT = 300.0


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    return cfg, model, params


def greedy_reference(cfg, model, params, prompt, n_new):
    """Direct greedy decode with the model API — the oracle for the engine."""
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_capacity=128))(params, batch)
    toks = [int(jnp.argmax(logits[0, len(prompt) - 1, :cfg.vocab_size]))]
    step = jax.jit(model.decode)
    pos = len(prompt)
    for _ in range(n_new - 1):
        db = {"token": jnp.asarray([[toks[-1]]], jnp.int32),
              "pos": jnp.asarray([pos], jnp.int32)}
        logits, cache = step(params, cache, db)
        toks.append(int(jnp.argmax(logits[0, 0, :cfg.vocab_size])))
        pos += 1
    return toks


def test_instance_prefill_decode_matches_reference(setup):
    cfg, model, params = setup
    inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    prompt = np.arange(1, 17, dtype=np.int32)
    ref = greedy_reference(cfg, model, params, prompt, 6)
    tok = inst.run_prefill(101, prompt)
    assert tok == ref[0]
    inst.local.start_local_decode(101, len(prompt), 5)
    for i in range(5):
        out = inst.run_decode_iteration([101])
        assert out[101] == ref[i + 1], f"token {i+1}"


def test_kv_transfer_preserves_generation(setup):
    """Decode continued on another instance after a real KV transfer must
    produce identical tokens — the stateless-instance property in compute."""
    cfg, model, params = setup
    a = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    b = EngineInstance(1, cfg, params, n_slots=4, capacity=128)
    prompt = np.arange(1, 25, dtype=np.int32)
    ref = greedy_reference(cfg, model, params, prompt, 8)
    tok = a.run_prefill(7, prompt)
    assert tok == ref[0]
    # decode 3 steps on A
    a.local.start_local_decode(7, len(prompt), 7)
    got = [tok]
    for _ in range(3):
        got.append(a.run_decode_iteration([7])[7])
    # transfer to B, continue there
    k, v, L, last, gen = a.export_kv(7)
    assert L == len(prompt) + 3
    assert b.import_kv(7, k, v, L, last, gen)
    a.drop(7)
    b.local.start_local_decode(7, L, 4)
    for _ in range(4):
        got.append(b.run_decode_iteration([7])[7])
    assert got == ref


def test_batched_decode_isolation(setup):
    """Concurrent requests in one slot cache don't perturb each other."""
    cfg, model, params = setup
    inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    p1 = np.arange(1, 13, dtype=np.int32)
    p2 = np.arange(40, 60, dtype=np.int32)
    ref1 = greedy_reference(cfg, model, params, p1, 5)
    ref2 = greedy_reference(cfg, model, params, p2, 5)
    t1 = inst.run_prefill(1, p1)
    t2 = inst.run_prefill(2, p2)
    assert [t1, t2] == [ref1[0], ref2[0]]
    inst.local.start_local_decode(1, len(p1), 4)
    inst.local.start_local_decode(2, len(p2), 4)
    g1, g2 = [t1], [t2]
    for _ in range(4):
        out = inst.run_decode_iteration([1, 2])
        g1.append(out[1])
        g2.append(out[2])
    assert g1 == ref1 and g2 == ref2


def test_chunked_prefill_matches_whole_prefill(setup):
    """§5.4 chunked prefill on the engine: o_1 and subsequent decode equal
    the whole-prompt path."""
    cfg, model, params = setup
    inst = EngineInstance(0, cfg, params, n_slots=4, capacity=128)
    prompt = np.arange(1, 41, dtype=np.int32)
    ref = greedy_reference(cfg, model, params, prompt, 5)
    tok = None
    for off in range(0, len(prompt), 16):
        tok = inst.run_prefill_chunk(5, prompt[off:off + 16], off, len(prompt))
    assert tok == ref[0]
    inst.local.start_local_decode(5, len(prompt), 4)
    got = [tok]
    for _ in range(4):
        got.append(inst.run_decode_iteration([5])[5])
    assert got == ref


def test_cluster_chunked_end_to_end(setup):
    """Cluster with a small chunk budget: long prompts split across
    iterations, everything still finishes and matches the reference."""
    cfg, model, params = setup
    cluster = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                                 capacity=128, slo=SLO(ttft=5.0, tpot=2.0),
                                 params=params, chunk_tokens=16)
    rng = np.random.default_rng(3)
    reqs = [ServeRequest(
        rid=i, prompt=rng.integers(1, cfg.vocab_size, size=50).astype(np.int32),
        max_new_tokens=3) for i in range(4)]
    with pytest.deprecated_call():     # legacy batch shim, kept on purpose
        out = cluster.serve(reqs, timeout=DRAIN_TIMEOUT)
    for sr in out:
        assert sr.req.finish_time is not None
        ref = greedy_reference(cfg, model, params, sr.prompt, sr.max_new_tokens)
        assert sr.output_tokens == ref, sr.rid


def test_cluster_end_to_end_all_finish(setup):
    cfg, model, params = setup
    cluster = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                                 capacity=128, slo=SLO(ttft=5.0, tpot=2.0),
                                 params=params)
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(rid=i,
                         prompt=rng.integers(1, cfg.vocab_size, size=rng.integers(4, 20)).astype(np.int32),
                         max_new_tokens=int(rng.integers(1, 6)))
            for i in range(8)]
    with pytest.deprecated_call():     # legacy batch shim, kept on purpose
        out = cluster.serve(reqs, timeout=DRAIN_TIMEOUT)
    for sr in out:
        assert sr.req is not None and sr.req.finish_time is not None, sr.rid
        assert len(sr.output_tokens) == sr.max_new_tokens
        # ordering bounds only (wall clock): first token after arrival,
        # finish after first token — never absolute-seconds thresholds
        assert sr.req.arrival <= sr.req.first_token_time <= sr.req.finish_time
    check_invariants(cluster)          # KV books balance after the drain

    # engine outputs must equal the single-model greedy reference
    for sr in out[:3]:
        ref = greedy_reference(cfg, model, params, sr.prompt, sr.max_new_tokens)
        assert sr.output_tokens == ref, sr.rid


def test_engine_metrics_ordering_bounds_only(setup):
    """Deflake audit (ISSUE 4 satellite): the engine's scraped metrics are
    wall-clock and machine-load dependent, so this asserts only orderings,
    monotonicity and non-negativity — a loaded CI machine shifts the values
    but cannot break these bounds."""
    cfg, model, params = setup
    cluster = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                                 capacity=128, slo=SLO(ttft=5.0, tpot=2.0),
                                 params=params)
    times = {}
    handles = [cluster.submit(Request(rid=i, arrival=0.0, input_len=16,
                                      output_len=4),
                              on_token=lambda h, tok, t:
                              times.setdefault(h.rid, []).append(t))
               for i in range(4)]
    report = cluster.drain(timeout=DRAIN_TIMEOUT)
    assert report.n_finished == 4
    cluster.collect_stats(cluster.clock.now())
    for iid in cluster.pools.all_ids():
        s = cluster.monitor.get(iid)
        assert s.avg_token_interval >= 0.0          # mean of real durations
        assert 0 <= s.kv_tokens_used <= s.kv_tokens_capacity
        assert s.running_tokens >= 0 and s.prefill_backlog_tokens >= 0
    assert report.duration >= 0.0
    assert report.scaling["instance_seconds"] >= 0.0
    for h in handles:                               # stream times monotone
        ts = times[h.rid]
        assert len(ts) == h.req.output_len
        assert all(a <= b for a, b in zip(ts, ts[1:]))
        assert h.req.ttft is not None and h.req.tpot is not None
        assert h.req.ttft >= 0.0 and h.req.tpot >= 0.0


def test_retire_instance_migrates_resident_kv(setup):
    """Elastic retirement (DESIGN.md §6): retiring an instance whose slot
    cache holds live decode requests must migrate/drain them — every stream
    still matches the greedy reference exactly (nothing dropped, nothing
    duplicated) and the instance is removed once empty. Streamed token ids
    are the evidence; times are only checked for ordering (wall clock)."""
    cfg, model, params = setup
    cluster = ArrowEngineCluster(cfg, n_instances=3, n_prefill=1, n_slots=4,
                                 capacity=128, slo=SLO(ttft=5.0, tpot=2.0),
                                 params=params)
    rng = np.random.default_rng(11)
    prompts = {i: rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)
               for i in range(4)}
    events = {}

    def on_token(h, tok, t):
        events.setdefault(h.rid, []).append((tok, t))

    handles = [cluster.submit(Request(rid=i, arrival=0.0, input_len=24,
                                      output_len=10),
                              prompt=prompts[i], on_token=on_token)
               for i in range(4)]
    # run until some instance holds KV-resident decode work
    victim = None
    for _ in range(3000):
        cluster.step()
        cands = [i for i, inst in cluster.instances.items()
                 if inst.local.decode_running]
        if cands:
            victim = max(cands,
                         key=lambda i: len(cluster.instances[i]
                                           .local.decode_running))
            break
    assert victim is not None, "no decode work materialized"
    resident = list(cluster.instances[victim].local.decode_running)
    assert resident
    cluster.begin_retire(victim, cluster.clock.now())
    assert not cluster.instances[victim].local.decode_running  # evacuated
    for rid in resident:
        assert cluster.handles[rid].req.decode_instance != victim

    report = cluster.drain(timeout=DRAIN_TIMEOUT)
    assert report.n_finished == 4
    for h in handles:
        ref = greedy_reference(cfg, model, params, prompts[h.rid], 10)
        toks = [tok for tok, _ in events[h.rid]]
        assert toks == ref, f"rid {h.rid} stream diverged across retirement"
        ts = [t for _, t in events[h.rid]]
        assert all(a <= b for a, b in zip(ts, ts[1:]))  # ordering bound only
    check_invariants(cluster)
    # a final monitor pass finalizes the drained retirement
    cluster.collect_stats(cluster.clock.now())
    assert victim not in cluster.instances
    assert victim not in cluster.pools.all_ids()
    assert report.scaling["n_instances"] >= 2


# ---------------------------------------------------------------------------
# ISSUE 5: typed slot-exhaustion error (no more `assert slot is not None`)
# ---------------------------------------------------------------------------


def test_no_free_slots_is_typed_not_assert(setup):
    cfg, model, params = setup
    inst = EngineInstance(0, cfg, params, n_slots=2, capacity=64)
    prompt = np.arange(1, 17, dtype=np.int32)
    inst.run_prefill(1, prompt)
    inst.run_prefill(2, prompt)
    with pytest.raises(NoFreeSlots) as ei:
        inst.run_prefill(3, prompt)
    assert ei.value.iid == 0 and ei.value.rid == 3
    with pytest.raises(NoFreeSlots):
        inst.begin_cached_prefill(4, 1, 8)
    with pytest.raises(NoFreeSlots):
        inst.profile_prefill()
    # import keeps its soft-failure contract (migration manager retries)
    k, v, L, last, gen = inst.export_kv(1)
    assert inst.import_kv(5, k, v, L, last, gen) is False
    inst.drop(1)                                   # a slot frees up ...
    assert inst.run_prefill(3, prompt) is not None  # ... and admission works


def test_cluster_queues_on_full_slots_and_finishes(setup):
    """Slot exhaustion must queue, not crash: more concurrent requests than
    KV slots; the cluster retries admission each pass until slots free."""
    cfg, model, params = setup
    cluster = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=2,
                                 capacity=64, slo=SLO(ttft=5.0, tpot=2.0),
                                 params=params)
    rng = np.random.default_rng(12)
    prompts = {i: rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)
               for i in range(6)}
    handles = [cluster.submit(Request(rid=i, arrival=0.0, input_len=24,
                                      output_len=3), prompt=prompts[i])
               for i in range(6)]
    report = cluster.drain(timeout=DRAIN_TIMEOUT)
    assert report.n_finished == 6
    check_invariants(cluster)
    for h in handles[:2]:
        ref = greedy_reference(cfg, model, params, prompts[h.rid], 3)
        assert [t for t in h.tokens] == ref


# ---------------------------------------------------------------------------
# ISSUE 5: fused step vs the pre-fusion per-rid path — bit-identical streams
# ---------------------------------------------------------------------------


def test_fused_step_matches_legacy_streams(setup):
    """step_mode='fused' (one donated jitted call per instance pass) and
    step_mode='legacy' (the pre-PR per-rid path) must produce bit-identical
    greedy streams on the same request set."""
    cfg, model, params = setup
    rng = np.random.default_rng(21)
    reqs = [(rng.integers(1, cfg.vocab_size, size=int(rng.integers(8, 40)))
             .astype(np.int32), int(rng.integers(2, 6))) for _ in range(5)]
    streams = {}
    for mode in ("legacy", "fused"):
        cluster = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1,
                                     n_slots=4, capacity=128,
                                     slo=SLO(ttft=5.0, tpot=2.0),
                                     params=params, chunk_tokens=16,
                                     step_mode=mode)
        handles = [cluster.submit(Request(rid=i, arrival=0.0,
                                          input_len=len(p), output_len=n),
                                  prompt=p)
                   for i, (p, n) in enumerate(reqs)]
        cluster.drain(timeout=DRAIN_TIMEOUT)
        streams[mode] = {h.rid: list(h.tokens) for h in handles}
        check_invariants(cluster)
    assert streams["fused"] == streams["legacy"]
    for i, (p, n) in enumerate(reqs):               # and both match the oracle
        assert streams["fused"][i] == greedy_reference(cfg, model, params,
                                                       p, n)


# ---------------------------------------------------------------------------
# ISSUE 5: Pallas kernels on the serving path — greedy-stream parity with the
# reference attention on prefill, chunked prefill, cached-prefix prefill and
# batched decode (interpret mode on CPU; same kernel contract as Mosaic/TPU)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def pallas_pair(setup):
    """(reference instance, pallas instance) over the same params — params
    are attn_impl-independent, so any stream divergence is the kernels'."""
    cfg, model, params = setup
    ref = EngineInstance(0, cfg, params, n_slots=4, capacity=64)
    pal = EngineInstance(1, cfg.replace(attn_impl="pallas"), params,
                         n_slots=4, capacity=64)
    return cfg, ref, pal


def _decode_stream(inst, rid, ctx_len, n):
    inst.local.start_local_decode(rid, ctx_len, n)
    return [inst.run_decode_iteration([rid])[rid] for _ in range(n)]


def test_pallas_prefill_decode_parity(pallas_pair):
    cfg, ref, pal = pallas_pair
    prompt = np.arange(1, 33, dtype=np.int32)
    t_ref = ref.run_prefill(10, prompt)
    t_pal = pal.run_prefill(10, prompt)
    assert t_ref == t_pal
    s_ref = _decode_stream(ref, 10, len(prompt), 5)
    s_pal = _decode_stream(pal, 10, len(prompt), 5)
    assert s_ref == s_pal
    ref.drop(10), pal.drop(10)


def test_pallas_chunked_prefill_parity(pallas_pair):
    cfg, ref, pal = pallas_pair
    prompt = np.arange(3, 51, dtype=np.int32)      # 48 tokens, 16-token chunks
    toks = {}
    for name, inst in (("ref", ref), ("pal", pal)):
        tok = None
        for off in range(0, len(prompt), 16):
            tok = inst.run_prefill_chunk(11, prompt[off:off + 16], off,
                                         len(prompt))
        toks[name] = [tok] + _decode_stream(inst, 11, len(prompt), 4)
        inst.drop(11)
    assert toks["ref"] == toks["pal"]


def test_pallas_cached_prefix_prefill_parity(pallas_pair):
    cfg, ref, pal = pallas_pair
    base = np.arange(5, 37, dtype=np.int32)        # 32-token parent context
    full = np.concatenate([base, np.arange(100, 116, dtype=np.int32)])
    toks = {}
    for name, inst in (("ref", ref), ("pal", pal)):
        inst.run_prefill(20, base)                 # the retained "parent"
        inst.begin_cached_prefill(21, 20, len(base))
        tok = inst.run_prefill_chunk(21, full[len(base):], len(base),
                                     len(full))
        toks[name] = [tok] + _decode_stream(inst, 21, len(full), 4)
        inst.drop(20), inst.drop(21)
    assert toks["ref"] == toks["pal"]


def test_pallas_batched_decode_parity(pallas_pair):
    cfg, ref, pal = pallas_pair
    p1 = np.arange(1, 25, dtype=np.int32)
    p2 = np.arange(30, 62, dtype=np.int32)
    toks = {}
    for name, inst in (("ref", ref), ("pal", pal)):
        t1, t2 = inst.run_prefill(31, p1), inst.run_prefill(32, p2)
        inst.local.start_local_decode(31, len(p1), 4)
        inst.local.start_local_decode(32, len(p2), 4)
        g1, g2 = [t1], [t2]
        for _ in range(4):
            out = inst.run_decode_iteration([31, 32])
            g1.append(out[31])
            g2.append(out[32])
        toks[name] = (g1, g2)
        inst.drop(31), inst.drop(32)
    assert toks["ref"] == toks["pal"]


def test_pallas_cluster_end_to_end_matches_reference(setup):
    """Whole serving loop under attn_impl='pallas': invariant probe after
    every step, every stream equal to the (reference-attention) greedy
    oracle — kernels validated inside the fused step, not just in
    isolation (tests/test_kernels.py)."""
    cfg, model, params = setup
    cluster = ArrowEngineCluster(cfg.replace(attn_impl="pallas"),
                                 n_instances=2, n_prefill=1, n_slots=4,
                                 capacity=64, slo=SLO(ttft=5.0, tpot=2.0),
                                 params=params, chunk_tokens=16)
    rng = np.random.default_rng(17)
    prompts = {i: rng.integers(1, cfg.vocab_size, size=40).astype(np.int32)
               for i in range(3)}
    handles = [cluster.submit(Request(rid=i, arrival=0.0, input_len=40,
                                      output_len=3), prompt=prompts[i])
               for i in range(3)]
    import time as _time
    deadline = _time.time() + DRAIN_TIMEOUT
    while cluster.step() and _time.time() < deadline:
        check_invariants(cluster, streams=False)   # probe after each step
    report = cluster.report()
    assert report.n_finished == 3
    check_invariants(cluster)
    for h in handles:
        ref = greedy_reference(cfg, model, params, prompts[h.rid], 3)
        assert [t for t in h.tokens] == ref, f"rid {h.rid}"
