"""Unit tests for model-zoo primitives: RoPE variants, M-RoPE, masks, norms,
MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.models import common as cm


def test_rope_preserves_norm_and_relativity():
    """Rotations preserve vector norm, and q·k depends only on the position
    difference (the property RoPE exists for)."""
    D = 64
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))

    def dot_at(pq, pk):
        cos_q, sin_q = cm.rope_angles(jnp.array([[pq]]), D, 10000.0)
        cos_k, sin_k = cm.rope_angles(jnp.array([[pk]]), D, 10000.0)
        qr = cm.apply_rope(q, cos_q[:, :, None], sin_q[:, :, None], D)
        kr = cm.apply_rope(k, cos_k[:, :, None], sin_k[:, :, None], D)
        return float(jnp.sum(qr * kr))

    np.testing.assert_allclose(
        float(jnp.linalg.norm(q)),
        float(jnp.linalg.norm(cm.apply_rope(
            q, *[a[:, :, None] for a in cm.rope_angles(jnp.array([[7]]), D, 1e4)], D))),
        rtol=1e-5)
    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)


def test_partial_rope_rotates_prefix_only():
    D, frac = 64, 0.5
    rope_dim = int(D * frac)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, D))
    cos, sin = cm.rope_angles(jnp.array([[9]]), rope_dim, 10000.0)
    out = cm.apply_rope(x, cos[:, :, None], sin[:, :, None], rope_dim)
    np.testing.assert_array_equal(np.asarray(out[..., rope_dim:]),
                                  np.asarray(x[..., rope_dim:]))
    assert not np.allclose(np.asarray(out[..., :rope_dim]),
                           np.asarray(x[..., :rope_dim]))


def test_mrope_equals_standard_rope_for_text():
    """For pure text, all three M-RoPE position streams are equal and the
    result must match standard RoPE."""
    D = 64
    S = 8
    pos = jnp.arange(S)
    mpos = jnp.broadcast_to(pos[None, None], (1, 3, S))
    sections = (8, 12, 12)
    cos_m, sin_m = cm.mrope_angles(mpos, D, 10000.0, sections)
    cos_s, sin_s = cm.rope_angles(pos, D, 10000.0)
    np.testing.assert_allclose(np.asarray(cos_m[0]), np.asarray(cos_s),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sin_m[0]), np.asarray(sin_s),
                               rtol=1e-6)


def test_causal_and_window_masks():
    m = np.asarray(cm.causal_mask(4, 4))[0, 0, 0]
    assert (m[0, 1:] < -1e20).all() and m[3, :].max() == 0
    mw = np.asarray(cm.causal_mask(4, 4, window=2))[0, 0, 0]
    assert mw[3, 0] < -1e20 and mw[3, 2] == 0          # window cuts old keys
    # chunked-prefill mask == causal mask when the cache holds [0..T)
    pos_map = jnp.broadcast_to(jnp.arange(6)[None], (1, 6))
    q_pos = jnp.arange(2) + 4
    mc = np.asarray(cm.chunk_mask(pos_map, q_pos))[0, 0, 0]
    full = np.asarray(cm.causal_mask(2, 6, q_offset=4))[0, 0, 0]
    np.testing.assert_array_equal(mc, full)


def test_decode_mask_ring_semantics():
    pos_map = jnp.asarray([[8, 5, 6, 7]])   # ring buffer, slot0 newest
    m = np.asarray(cm.decode_mask(pos_map, jnp.asarray([8]), window=3))[0, 0, 0, 0]
    assert m[0] == 0          # pos 8 == query
    assert m[1] < -1e20       # pos 5 evicted by window 3 (8-3=5 excluded)
    assert m[2] == 0 and m[3] == 0


def test_norms_match_reference():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 5, 16))
    w = jax.random.normal(jax.random.PRNGKey(4), (16,)) * 0.1
    got = np.asarray(cm.rms_norm(x, w))
    ref = np.asarray(x) / np.sqrt(
        (np.asarray(x) ** 2).mean(-1, keepdims=True) + 1e-6) * (1 + np.asarray(w))
    np.testing.assert_allclose(got, ref, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 100))
def test_moe_dispatch_conservation(e_log, k, seed):
    """Every kept token-expert assignment contributes exactly its routed
    weight; grouped (G=2) and global (G=1) dispatch agree with ample
    capacity."""
    from repro.configs import get_smoke_config
    from repro.models import moe as moe_mod
    E = 2 ** e_log
    cfg = get_smoke_config("olmoe-1b-7b")
    cfg = cfg.replace(moe=cfg.moe.__class__(
        n_experts=E, top_k=min(k, E), d_ff_expert=32, capacity_factor=float(E)))
    key = jax.random.PRNGKey(seed)
    params = moe_mod.init_params(cfg.replace(n_layers=1), key)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = jax.random.normal(key, (2, 8, cfg.d_model)) * 0.5
    y1, aux1 = moe_mod.moe_ffn(cfg, lp["moe"], x)
    y2, aux2 = moe_mod.moe_ffn(cfg.replace(moe_groups=2), lp["moe"], x)
    assert np.isfinite(np.asarray(y1)).all()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
