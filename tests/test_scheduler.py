"""Unit + property tests for Arrow's core scheduling (pools, Algorithms 1-4,
TTFT predictor, local scheduler, monitor semantics)."""
import pytest
from hyp_compat import given, settings, st

from repro.core import (SLO, DeflectionConfig, DeflectionPolicy,
                        GlobalScheduler, InstanceMonitor, InstancePools,
                        InstanceStats, LocalScheduler, Pool, Request,
                        SchedulerConfig, TTFTPredictor)


class FakeCluster:
    def __init__(self):
        self.pending_prefill = set()
        self.pending_decode = set()

    def has_pending_prefill(self, iid):
        return iid in self.pending_prefill

    def has_pending_decode(self, iid):
        return iid in self.pending_decode


def make_sched(n=4, n_prefill=2, slo=SLO(1.0, 0.1), **cfg_kw):
    pools = InstancePools(range(n), n_prefill=n_prefill)
    mon = InstanceMonitor(range(n))
    for i in range(n):
        mon.update_stats(InstanceStats(instance_id=i))
    pred = TTFTPredictor.fit([(0, 0.0), (1000, 0.1), (2000, 0.3), (4000, 1.0)])
    cluster = FakeCluster()
    cfg = SchedulerConfig(max_running_tokens=10000, **cfg_kw)
    gs = GlobalScheduler(pools, mon, pred, slo, cfg, cluster)
    return gs, pools, mon, cluster


# -------------------------------------------------------------------- pools


def test_pool_transitions():
    pools = InstancePools(range(4), n_prefill=2)
    assert set(pools.members(Pool.PREFILL)) == {0, 1}
    pools.flip_to_decode(0, has_pending_prefill=True)
    assert pools.pool_of(0) is Pool.P2D
    assert 0 in pools.decode_capable()
    pools.on_prefill_drained(0)
    assert pools.pool_of(0) is Pool.DECODE
    pools.flip_to_prefill(0, has_pending_decode=False)
    assert pools.pool_of(0) is Pool.PREFILL
    assert pools.flips == 3


def test_zero_wait_flip_is_instant():
    """Stateless-instance property: a flip is a pool move, nothing else."""
    pools = InstancePools(range(2), n_prefill=1)
    before = pools.decode_capable()
    pools.flip_to_decode(0, has_pending_prefill=False)
    assert 0 in pools.decode_capable() and 0 not in pools.prefill_capable()
    assert set(before) | {0} == set(pools.decode_capable())


# ---------------------------------------------------------------- predictor


def test_predictor_fits_quadratic():
    pred = TTFTPredictor.fit([(L, 1e-7 * L * L + 1e-4 * L + 0.01)
                              for L in (100, 500, 1000, 5000, 10000)])
    assert pred.predict(2000) == pytest.approx(1e-7 * 4e6 + 0.2 + 0.01, rel=1e-3)
    # chunk additivity: chunk predictions telescope to the whole-prompt
    # prediction minus the fixed per-request constant
    whole = pred.predict(8192)
    parts = pred.predict_chunk(0, 4096) + pred.predict_chunk(4096, 4096)
    assert parts == pytest.approx(whole - pred.predict(0), rel=1e-6)


def test_predictor_linear_workload_degrades_gracefully():
    """SSM-style linear prefill: quadratic coefficient fits ~0."""
    pred = TTFTPredictor.fit([(L, 2e-5 * L + 0.01)
                              for L in (100, 1000, 4000, 16000)])
    a, b, c = pred.coeffs
    assert abs(a) < 1e-10
    assert pred.predict(8000) == pytest.approx(0.17, rel=1e-2)


def test_predictor_concave_samples_refit_linear():
    """Regression: concave profiling data (saturating runtime, e.g. a
    memory-bound short-context sweep) used to fit a < 0, whose quadratic
    peaks *inside* the profiled range — suffix chunks beyond the peak
    clamped to 0 and silently corrupted chunked-prefill cost accounting.
    ``fit`` must refit linear with a = 0 instead."""
    samples = [(1024, 1.0), (2048, 1.4), (4096, 1.75),
               (8192, 1.95), (16384, 2.0)]
    # the raw quadratic really is adversarial: its apex sits inside the range
    import numpy as np
    L = np.asarray([s[0] for s in samples], float)
    t = np.asarray([s[1] for s in samples], float)
    A = np.stack([L * L, L, np.ones_like(L)], axis=1)
    (a_raw, b_raw, _), *_ = np.linalg.lstsq(A, t, rcond=None)
    assert a_raw < 0 and 0 < -b_raw / (2 * a_raw) < 16384

    pred = TTFTPredictor.fit(samples)
    a, b, c = pred.coeffs
    assert a == 0.0 and b > 0.0
    # monotone: every suffix chunk costs > 0 (the old clamp returned 0.0
    # for chunks past the apex), and predictions never decrease in L
    assert pred.predict_chunk(12288, 4096) > 0.0
    prev = 0.0
    for L in (1024, 4096, 16384, 65536):
        cur = pred.predict(L)
        assert cur >= prev >= 0.0
        prev = cur


def test_fit_per_instance_rejects_empty_mapping():
    """Regression: an empty profiling mapping used to crash deep inside
    ``next(iter(...))`` with a bare StopIteration; it must fail fast with
    an actionable message."""
    from repro.core.ttft_predictor import PerInstancePredictor
    with pytest.raises(ValueError, match="empty samples_by_iid"):
        PerInstancePredictor.fit_per_instance({})
    # the non-empty path still works and keys per-instance predictors
    p = PerInstancePredictor.fit_per_instance(
        {7: [(0, 0.0), (1000, 0.1), (2000, 0.3), (4000, 1.0)]})
    assert p.for_instance(7).predict(2000) > 0.0


# -------------------------------------------------------------- algorithm 1


def test_prefill_scheduling_picks_min_delay():
    gs, pools, mon, cluster = make_sched()
    r = Request(rid=1, arrival=0.0, input_len=1000, output_len=10)
    out1 = gs.schedule_prefill(r, now=0.0)
    r2 = Request(rid=2, arrival=0.0, input_len=1000, output_len=10)
    out2 = gs.schedule_prefill(r2, now=0.0)
    assert out1.instance != out2.instance          # second goes to the idle one
    assert {out1.instance, out2.instance} == {0, 1}


def test_prefill_flips_decode_instance_on_predicted_violation():
    gs, pools, mon, cluster = make_sched(slo=SLO(0.5, 0.1))
    # saturate both prefill instances past the TTFT budget
    for i in (0, 1):
        gs.prefill_ready_at[i] = 10.0
    r = Request(rid=1, arrival=0.0, input_len=4000, output_len=10)
    out = gs.schedule_prefill(r, now=0.0)
    assert out.flipped is not None
    assert out.instance == out.flipped
    assert pools.pool_of(out.instance) in (Pool.PREFILL, Pool.D2P)


def test_prefill_overload_guard_respects_decode_priority():
    """§5.5: if decode load is high, do NOT steal decode instances."""
    gs, pools, mon, cluster = make_sched(slo=SLO(0.5, 0.1))
    for i in (0, 1):
        gs.prefill_ready_at[i] = 10.0
    for i in (2, 3):
        mon.update_stats(InstanceStats(instance_id=i, running_tokens=9000,
                                       n_decode_running=50))
    r = Request(rid=1, arrival=0.0, input_len=4000, output_len=10)
    out = gs.schedule_prefill(r, now=0.0)
    assert out.flipped is None and out.via_fallback
    assert pools.count(Pool.DECODE) == 2


# -------------------------------------------------------------- algorithm 2


def test_decode_stays_on_flipped_prefill_instance():
    """If the prefill instance now serves decode, keep the request there
    (KV transfer elided)."""
    gs, pools, mon, cluster = make_sched()
    r = Request(rid=1, arrival=0.0, input_len=1000, output_len=10)
    r.prefill_instance = 0
    pools.flip_to_decode(0, has_pending_prefill=False)
    out = gs.schedule_decode(r, now=0.0)
    assert out.instance == 0


def test_decode_min_running_tokens():
    gs, pools, mon, cluster = make_sched()
    mon.update_stats(InstanceStats(instance_id=2, running_tokens=5000))
    mon.update_stats(InstanceStats(instance_id=3, running_tokens=100))
    r = Request(rid=1, arrival=0.0, input_len=1000, output_len=10)
    r.prefill_instance = 0
    assert gs.schedule_decode(r, now=0.0).instance == 3


def test_decode_flips_prefill_when_overloaded():
    gs, pools, mon, cluster = make_sched(slo=SLO(1.0, 0.05))
    for i in (2, 3):
        mon.update_stats(InstanceStats(instance_id=i, running_tokens=9990,
                                       n_decode_running=10))
    r = Request(rid=1, arrival=0.0, input_len=1000, output_len=10)
    r.prefill_instance = 0
    out = gs.schedule_decode(r, now=0.0)
    assert out.flipped is not None
    assert pools.pool_of(out.instance) in (Pool.DECODE, Pool.P2D)


# ---------------------------------------------------------- algorithms 3/4


def test_never_drains_last_decode_instance():
    gs, pools, mon, cluster = make_sched(n=2, n_prefill=1)
    assert gs.try_move_decode_to_prefill() is None


def test_never_drains_last_prefill_instance():
    gs, pools, mon, cluster = make_sched(n=2, n_prefill=1)
    assert gs.try_move_prefill_to_decode(0.0) is None


def test_flip_prefers_p2d_pool():
    gs, pools, mon, cluster = make_sched(n=4, n_prefill=1)
    pools.move(1, Pool.P2D)
    mon.update_stats(InstanceStats(instance_id=1, running_tokens=50))
    got = gs.try_move_decode_to_prefill()
    assert got == 1                                 # P→D member chosen first


# ------------------------------------------------------------ local sched


def test_local_chunked_prefill_decode_first():
    loc = LocalScheduler(0, token_budget=512, mixed_chunk_budget=128)
    loc.enqueue_prefill(1, 1000)
    loc.start_local_decode(2, 300, 5)
    plan = loc.plan_iteration()
    assert plan.decode_rids == [2]
    assert plan.prefill_chunks == [(1, 0, 128)]     # capped by mixed budget
    done = loc.complete_prefill_chunk(1, 128)
    assert not done
    plan2 = loc.plan_iteration()
    assert plan2.prefill_chunks == [(1, 128, 128)]


def test_local_migration_memory_gate():
    loc = LocalScheduler(0, kv_capacity_tokens=1000)
    loc.enqueue_migration(1, 800, 10)
    loc.enqueue_migration(2, 800, 10)
    got = loc.next_migration()
    assert got == (1, 800, 10)
    loc.admit_migrated(*got)
    assert loc.next_migration() is None             # 800+800 > 1000: q2 blocks
    # finish request 1 -> memory frees -> request 2 admissible
    for _ in range(10):
        fin = loc.complete_decode_iteration(1)
    assert fin
    assert loc.next_migration() == (2, 800, 10)


# ----------------------------------------------------------- properties


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(16, 8192), st.booleans()),
                min_size=1, max_size=40))
def test_pool_invariants_under_arbitrary_schedules(ops):
    """Whatever the request stream does: pools partition instances, at least
    one instance stays prefill-capable and one decode-capable."""
    gs, pools, mon, cluster = make_sched(n=4, n_prefill=2, slo=SLO(0.3, 0.05))
    now = 0.0
    for i, (ln, is_prefill) in enumerate(ops):
        now += 0.01
        r = Request(rid=i, arrival=now, input_len=ln, output_len=8)
        if is_prefill:
            out = gs.schedule_prefill(r, now)
        else:
            r.prefill_instance = i % 4
            out = gs.schedule_decode(r, now)
        assert out.instance in range(4)
        ids = sorted(pools.all_ids())
        assert ids == [0, 1, 2, 3]
        assert pools.prefill_capable() or pools.decode_capable()
        assert pools.count(Pool.DECODE, Pool.P2D) >= 1 or \
            pools.count(Pool.PREFILL, Pool.D2P) == 4
        gs.on_monitor_tick(now)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 2000), min_size=1, max_size=30),
       st.integers(64, 2048))
def test_local_scheduler_conserves_work(lengths, budget):
    """Every enqueued prefill token is eventually planned exactly once."""
    loc = LocalScheduler(0, token_budget=budget, mixed_chunk_budget=budget)
    for i, ln in enumerate(lengths):
        loc.enqueue_prefill(i, ln)
    planned = {i: 0 for i in range(len(lengths))}
    for _ in range(100000):
        plan = loc.plan_iteration()
        if plan.is_empty:
            break
        for rid, start, ln in plan.prefill_chunks:
            assert start == planned[rid]            # in-order chunks
            planned[rid] += ln
            loc.complete_prefill_chunk(rid, ln)
    assert planned == {i: ln for i, ln in enumerate(lengths)}


# ------------------------------------------- §11 deflection (ISSUE 7)
# With make_sched's predictor fit, predict(512) ~= 0.039s and
# predict(2048) ~= 0.31s; SLO(1.0, 0.1) gives ttft_budget 0.9 and
# tpot_budget 0.09 — the numbers below lean on those magnitudes.


def arm(gs, **kw):
    gs.deflection = DeflectionPolicy(DeflectionConfig(**kw))
    return gs.deflection


def pressurize(gs, ids=(0, 1), seconds=2.0):
    """Build Eq.(2) backlog on the prefill pool so pressure > watermark."""
    for i in ids:
        gs.account_prefill_dispatch(i, 0.0, seconds)


def _req(rid=1, input_len=512):
    return Request(rid=rid, arrival=0.0, input_len=input_len, output_len=8)


def test_deflect_refused_below_watermark():
    gs, *_ = make_sched()
    pol = arm(gs)
    assert pol.try_deflect(gs, _req(), 0.0, 0.9) is None
    assert pol.stats["refused_below_watermark"] == 1


def test_deflect_refused_no_victim():
    gs, *_ = make_sched(n=2, n_prefill=2)       # no pure-DECODE instance
    pol = arm(gs)
    pressurize(gs)
    assert pol.try_deflect(gs, _req(), 0.0, 0.9) is None
    assert pol.stats["refused_no_victim"] == 1


def test_deflect_refused_tpot_budget():
    gs, pools, mon, _ = make_sched()
    pol = arm(gs)
    pressurize(gs)
    for v in (2, 3):   # victims already decode at the full TPOT budget
        # (set directly: update_stats recomputes the mean from samples)
        mon.get(v).avg_token_interval = 0.09
    assert pol.try_deflect(gs, _req(), 0.0, 0.9) is None
    assert pol.stats["refused_tpot_budget"] == 1


def test_deflect_refused_kv_headroom():
    gs, pools, mon, _ = make_sched()
    pol = arm(gs)
    pressurize(gs)
    for v in (2, 3):   # near the 10000-token cap: 9800 + 512 overflows
        mon.update_stats(InstanceStats(instance_id=v, running_tokens=9800))
    assert pol.try_deflect(gs, _req(), 0.0, 0.9) is None
    assert pol.stats["refused_kv_headroom"] == 1


def test_deflect_refused_victim_backlog():
    gs, *_ = make_sched()
    pol = arm(gs)
    pressurize(gs)
    for v in (2, 3):   # victims already owe 5s of deflected drain
        gs.account_prefill_dispatch(v, 0.0, 5.0)
    assert pol.try_deflect(gs, _req(), 0.0, 0.9) is None
    assert pol.stats["refused_victim_backlog"] == 1


def test_deflect_refusal_reasons_exhaustive():
    """Every counted refusal reason is reachable (the five tests above) and
    the stats dict carries exactly the declared reasons."""
    assert set(DeflectionPolicy.REFUSALS) == {
        "below_watermark", "no_victim", "tpot_budget", "kv_headroom",
        "victim_backlog"}
    pol = DeflectionPolicy(DeflectionConfig())
    assert {k[len("refused_"):] for k in pol.stats
            if k.startswith("refused_")} == set(DeflectionPolicy.REFUSALS)


def test_deflect_success_charges_eq2_interference():
    gs, pools, *_ = make_sched()
    pol = arm(gs)                               # ratio 0.25 -> 512/step
    pressurize(gs)
    before = dict(gs.prefill_ready_at)
    out = pol.try_deflect(gs, _req(rid=9, input_len=1024), 0.0, 0.9)
    assert out is not None and out.deflected
    v = out.instance
    assert v in pools.members(Pool.DECODE)
    assert pol.stats["requests_deflected"] == 1
    assert pol.stats["tokens_deflected"] == 1024
    # 1024 tokens at 512/step = 2 victim steps; idle victim -> the whole
    # drain is interference, charged through the same Eq.(2) bookkeeping
    chunk_t = gs._predict_chunk(v, 0, 512)
    assert pol.stats["interference_s"] == pytest.approx(2 * chunk_t)
    assert gs.prefill_ready_at[v] == pytest.approx(before[v] + 2 * chunk_t)
    assert out.predicted_ttft <= 0.9


def test_deflect_max_ratio_never_starves_host_decode():
    """ratio=1.0 -> 2048-token chunks whose per-step cost (~0.31s) exceeds
    the 0.09s TPOT budget: the TPOT guard refuses, so even the maximal knob
    cannot push a victim's decode below its SLO budget."""
    gs, *_ = make_sched()
    pol = arm(gs, ratio=1.0)
    pressurize(gs)
    assert pol.try_deflect(gs, _req(), 0.0, 0.9) is None
    assert pol.stats["refused_tpot_budget"] == 1


def test_deflect_schedule_prefill_integration():
    """Algorithm 1 reaches the deflection branch when t1/t2 miss the budget,
    and an armed-but-ratio-0 scheduler is decision-identical to an unarmed
    one (the ratio=0 control of DESIGN.md §11)."""
    armed, armed_pools, *_ = make_sched()
    arm(armed)
    pressurize(armed)
    out = armed.schedule_prefill(_req(), 0.0)
    assert out.deflected and out.instance in armed_pools.members(Pool.DECODE)

    zero, *_ = make_sched()
    pol0 = arm(zero, ratio=0.0)
    plain, *_ = make_sched()
    pressurize(zero)
    pressurize(plain)
    for rid in range(6):
        a = zero.schedule_prefill(_req(rid=rid), 0.0)
        b = plain.schedule_prefill(_req(rid=rid), 0.0)
        assert (a.instance, a.flipped, a.deflected, a.via_fallback) == \
            (b.instance, b.flipped, b.deflected, b.via_fallback)
    assert all(v == 0 for v in pol0.stats.values())


def test_deflect_idle_prefiller_picks_up_decode():
    gs, pools, mon, cluster = make_sched()
    pol = arm(gs)
    mon.update_stats(InstanceStats(instance_id=1, running_tokens=50))
    assert pol.try_pickup(gs, _req(input_len=64), 0.0) == 0  # lightest idle
    assert pol.stats["decode_pickups"] == 1
    # busy prefillers (pending work on 0, Eq.(2) backlog on 1) -> no pickup
    cluster.pending_prefill.add(0)
    gs.account_prefill_dispatch(1, 0.0, 1.0)
    assert pol.try_pickup(gs, _req(input_len=64), 0.0) is None
    # and the knob can disable the symmetric direction entirely
    off = arm(gs, idle_pickup=False)
    assert off.try_pickup(gs, _req(input_len=64), 0.0) is None


# --------------------------------------- §11 local micro-batch ratio knob


def test_local_deflected_served_after_native_from_leftover_budget():
    loc = LocalScheduler(0, token_budget=512, mixed_chunk_budget=256,
                         deflect_ratio=0.25)
    loc.enqueue_prefill(1, 200)                     # native
    loc.enqueue_prefill(2, 1000, deflected=True)    # deflected
    loc.start_local_decode(3, 300, 5)
    plan = loc.plan_iteration()
    assert plan.decode_rids == [3]                  # decode-first (Sarathi)
    # mixed budget 256: native's 200 go first, deflected gets the leftover
    # 56 (inside its deficit allowance max(1, 0.25*256) = 64)
    assert plan.prefill_chunks == [(1, 0, 200), (2, 0, 56)]
    # native absent next plan: deflected alone is capped by the allowance,
    # carrying over the 8 unspent deficit tokens from the first step
    loc.complete_prefill_chunk(1, 200)
    loc.complete_prefill_chunk(2, 56)
    plan2 = loc.plan_iteration()
    assert plan2.prefill_chunks == [(2, 56, 72)]    # 64 + (64 - 56) carry


def test_local_deflect_deficit_bounds_tokens_over_any_window():
    """Over k plans with a saturated deflected backlog, executed deflected
    tokens never exceed k*allowance + one carry-over of the budget cap."""
    ratio, mcb, k = 0.1, 256, 50
    loc = LocalScheduler(0, token_budget=4096, mixed_chunk_budget=mcb,
                         deflect_ratio=ratio)
    loc.enqueue_prefill(1, 10 ** 6, deflected=True)
    total = 0
    for _ in range(k):
        plan = loc.plan_iteration()
        for rid, _start, ln in plan.prefill_chunks:
            total += ln
            loc.complete_prefill_chunk(rid, ln)
    allowance = max(1.0, ratio * mcb)
    assert total <= k * allowance + mcb
    assert total >= k * allowance - mcb             # and it keeps moving


def test_local_tiny_ratio_still_progresses():
    """ratio so small that ratio*budget < 1 token: the one-token allowance
    floor keeps every plan non-empty until the deflected work drains (an
    empty plan would never be re-kicked by the simulator)."""
    loc = LocalScheduler(0, token_budget=512, mixed_chunk_budget=256,
                         deflect_ratio=0.001)
    loc.enqueue_prefill(1, 5, deflected=True)
    for _ in range(100):
        plan = loc.plan_iteration()
        if plan.is_empty:
            break
        ((rid, _start, ln),) = plan.prefill_chunks
        assert ln >= 1
        loc.complete_prefill_chunk(rid, ln)
    assert not loc.prefill_queue                    # drained, never hung


# --------------------------------------- §11 sim/engine deflection parity


@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    return cfg, params


def _record_placements(system):
    """Wrap the policy's place_prefill to log (rid, instance, deflected)."""
    orig = system.policy.place_prefill
    rec = []

    def place(req, now, prefix_hits=None):
        iid, hit, deflected = orig(req, now, prefix_hits=prefix_hits)
        rec.append((req.rid, iid, deflected))
        return iid, hit, deflected

    system.policy.place_prefill = place
    return rec


def test_sim_engine_deflection_parity_and_stream_identity(engine_setup):
    """Acceptance (ISSUE 7): the same burst + the same DeflectionConfig at
    the same state barrier (a pre-charged Eq.(2) backlog on the prefill
    instance, all arrivals dispatched before any step) yields the *same*
    deflected-chunk placements and policy counters on both backends —
    placement is decided by the shared Eq.(1)/(2) bookkeeping, so backend
    timing must not leak in. And the engine's greedy token streams are
    bit-identical with deflection on vs off: executing a prefill as
    deflected chunks on a decode instance is numerically the same
    computation."""
    import numpy as np
    from repro.configs import get_config
    from repro.core.autoscaler import AutoScalerConfig
    from repro.engine import ArrowEngineCluster
    from repro.sim import Simulator
    cfg, params = engine_setup
    dc_on = DeflectionConfig(ratio=0.25)
    slo = SLO(30.0, 10.0)      # victim gates pass; only t1's backlog misses
    pinned = AutoScalerConfig(min_instances=2, max_instances=2)
    N, IN, OUT = 6, 24, 6
    rng = np.random.default_rng(3)
    prompts = {i: rng.integers(1, cfg.vocab_size, size=IN).astype(np.int32)
               for i in range(N)}

    def reqs():
        return [Request(rid=i, arrival=0.0, input_len=IN, output_len=OUT)
                for i in range(N)]

    # ---- sim side: pre-charge, submit the burst, drain
    sim = Simulator(get_config("gemma-2b"), n_instances=2, n_prefill=1,
                    policy="arrow_deflect", slo=slo, autoscaler_cfg=pinned,
                    deflection=dc_on)
    sim.policy.prefill_ready_at[0] = 1000.0        # the state barrier
    rec_sim = _record_placements(sim)
    for r in reqs():
        sim.submit(r)
    rep_sim = sim.drain()

    # ---- engine side, deflection ON
    def engine(policy, deflection):
        eng = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                                 capacity=128, slo=slo, params=params,
                                 policy=policy, autoscaler_cfg=pinned,
                                 deflection=deflection)
        eng.policy.prefill_ready_at[0] = 1000.0    # same barrier
        rec = _record_placements(eng)
        handles = [eng.submit(r, prompt=prompts[r.rid]) for r in reqs()]
        rep = eng.drain(timeout=300.0)
        return rec, rep, [list(h.tokens) for h in handles]

    rec_on, rep_on, streams_on = engine("arrow_deflect", dc_on)

    # same placements, and non-vacuously deflecting
    assert rec_sim == rec_on
    assert any(d for _, _, d in rec_sim), "barrier never triggered deflection"
    for key in ("requests_deflected", "tokens_deflected",
                "chunks_executed", "chunk_tokens_executed"):
        assert rep_sim.deflection[key] == rep_on.deflection[key], key
    for rid, iid, d in rec_sim:
        if d:
            assert iid == 1                        # the only decode victim

    # ---- engine side, deflection OFF (ratio=0 control): identical streams
    rec_off, rep_off, streams_off = engine("arrow_deflect",
                                           DeflectionConfig(ratio=0.0))
    assert not rep_off.deflection                  # §11 section stays empty
    assert not any(d for _, _, d in rec_off)
    assert streams_on == streams_off, \
        "deflected execution changed greedy token ids"
    assert rep_on.n_finished == rep_off.n_finished == N
