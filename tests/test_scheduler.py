"""Unit + property tests for Arrow's core scheduling (pools, Algorithms 1-4,
TTFT predictor, local scheduler, monitor semantics)."""
import pytest
from hyp_compat import given, settings, st

from repro.core import (SLO, GlobalScheduler, InstanceMonitor, InstancePools,
                        InstanceStats, LocalScheduler, Pool, Request,
                        SchedulerConfig, TTFTPredictor)


class FakeCluster:
    def __init__(self):
        self.pending_prefill = set()
        self.pending_decode = set()

    def has_pending_prefill(self, iid):
        return iid in self.pending_prefill

    def has_pending_decode(self, iid):
        return iid in self.pending_decode


def make_sched(n=4, n_prefill=2, slo=SLO(1.0, 0.1), **cfg_kw):
    pools = InstancePools(range(n), n_prefill=n_prefill)
    mon = InstanceMonitor(range(n))
    for i in range(n):
        mon.update_stats(InstanceStats(instance_id=i))
    pred = TTFTPredictor.fit([(0, 0.0), (1000, 0.1), (2000, 0.3), (4000, 1.0)])
    cluster = FakeCluster()
    cfg = SchedulerConfig(max_running_tokens=10000, **cfg_kw)
    gs = GlobalScheduler(pools, mon, pred, slo, cfg, cluster)
    return gs, pools, mon, cluster


# -------------------------------------------------------------------- pools


def test_pool_transitions():
    pools = InstancePools(range(4), n_prefill=2)
    assert set(pools.members(Pool.PREFILL)) == {0, 1}
    pools.flip_to_decode(0, has_pending_prefill=True)
    assert pools.pool_of(0) is Pool.P2D
    assert 0 in pools.decode_capable()
    pools.on_prefill_drained(0)
    assert pools.pool_of(0) is Pool.DECODE
    pools.flip_to_prefill(0, has_pending_decode=False)
    assert pools.pool_of(0) is Pool.PREFILL
    assert pools.flips == 3


def test_zero_wait_flip_is_instant():
    """Stateless-instance property: a flip is a pool move, nothing else."""
    pools = InstancePools(range(2), n_prefill=1)
    before = pools.decode_capable()
    pools.flip_to_decode(0, has_pending_prefill=False)
    assert 0 in pools.decode_capable() and 0 not in pools.prefill_capable()
    assert set(before) | {0} == set(pools.decode_capable())


# ---------------------------------------------------------------- predictor


def test_predictor_fits_quadratic():
    pred = TTFTPredictor.fit([(L, 1e-7 * L * L + 1e-4 * L + 0.01)
                              for L in (100, 500, 1000, 5000, 10000)])
    assert pred.predict(2000) == pytest.approx(1e-7 * 4e6 + 0.2 + 0.01, rel=1e-3)
    # chunk additivity: chunk predictions telescope to the whole-prompt
    # prediction minus the fixed per-request constant
    whole = pred.predict(8192)
    parts = pred.predict_chunk(0, 4096) + pred.predict_chunk(4096, 4096)
    assert parts == pytest.approx(whole - pred.predict(0), rel=1e-6)


def test_predictor_linear_workload_degrades_gracefully():
    """SSM-style linear prefill: quadratic coefficient fits ~0."""
    pred = TTFTPredictor.fit([(L, 2e-5 * L + 0.01)
                              for L in (100, 1000, 4000, 16000)])
    a, b, c = pred.coeffs
    assert abs(a) < 1e-10
    assert pred.predict(8000) == pytest.approx(0.17, rel=1e-2)


# -------------------------------------------------------------- algorithm 1


def test_prefill_scheduling_picks_min_delay():
    gs, pools, mon, cluster = make_sched()
    r = Request(rid=1, arrival=0.0, input_len=1000, output_len=10)
    out1 = gs.schedule_prefill(r, now=0.0)
    r2 = Request(rid=2, arrival=0.0, input_len=1000, output_len=10)
    out2 = gs.schedule_prefill(r2, now=0.0)
    assert out1.instance != out2.instance          # second goes to the idle one
    assert {out1.instance, out2.instance} == {0, 1}


def test_prefill_flips_decode_instance_on_predicted_violation():
    gs, pools, mon, cluster = make_sched(slo=SLO(0.5, 0.1))
    # saturate both prefill instances past the TTFT budget
    for i in (0, 1):
        gs.prefill_ready_at[i] = 10.0
    r = Request(rid=1, arrival=0.0, input_len=4000, output_len=10)
    out = gs.schedule_prefill(r, now=0.0)
    assert out.flipped is not None
    assert out.instance == out.flipped
    assert pools.pool_of(out.instance) in (Pool.PREFILL, Pool.D2P)


def test_prefill_overload_guard_respects_decode_priority():
    """§5.5: if decode load is high, do NOT steal decode instances."""
    gs, pools, mon, cluster = make_sched(slo=SLO(0.5, 0.1))
    for i in (0, 1):
        gs.prefill_ready_at[i] = 10.0
    for i in (2, 3):
        mon.update_stats(InstanceStats(instance_id=i, running_tokens=9000,
                                       n_decode_running=50))
    r = Request(rid=1, arrival=0.0, input_len=4000, output_len=10)
    out = gs.schedule_prefill(r, now=0.0)
    assert out.flipped is None and out.via_fallback
    assert pools.count(Pool.DECODE) == 2


# -------------------------------------------------------------- algorithm 2


def test_decode_stays_on_flipped_prefill_instance():
    """If the prefill instance now serves decode, keep the request there
    (KV transfer elided)."""
    gs, pools, mon, cluster = make_sched()
    r = Request(rid=1, arrival=0.0, input_len=1000, output_len=10)
    r.prefill_instance = 0
    pools.flip_to_decode(0, has_pending_prefill=False)
    out = gs.schedule_decode(r, now=0.0)
    assert out.instance == 0


def test_decode_min_running_tokens():
    gs, pools, mon, cluster = make_sched()
    mon.update_stats(InstanceStats(instance_id=2, running_tokens=5000))
    mon.update_stats(InstanceStats(instance_id=3, running_tokens=100))
    r = Request(rid=1, arrival=0.0, input_len=1000, output_len=10)
    r.prefill_instance = 0
    assert gs.schedule_decode(r, now=0.0).instance == 3


def test_decode_flips_prefill_when_overloaded():
    gs, pools, mon, cluster = make_sched(slo=SLO(1.0, 0.05))
    for i in (2, 3):
        mon.update_stats(InstanceStats(instance_id=i, running_tokens=9990,
                                       n_decode_running=10))
    r = Request(rid=1, arrival=0.0, input_len=1000, output_len=10)
    r.prefill_instance = 0
    out = gs.schedule_decode(r, now=0.0)
    assert out.flipped is not None
    assert pools.pool_of(out.instance) in (Pool.DECODE, Pool.P2D)


# ---------------------------------------------------------- algorithms 3/4


def test_never_drains_last_decode_instance():
    gs, pools, mon, cluster = make_sched(n=2, n_prefill=1)
    assert gs.try_move_decode_to_prefill() is None


def test_never_drains_last_prefill_instance():
    gs, pools, mon, cluster = make_sched(n=2, n_prefill=1)
    assert gs.try_move_prefill_to_decode(0.0) is None


def test_flip_prefers_p2d_pool():
    gs, pools, mon, cluster = make_sched(n=4, n_prefill=1)
    pools.move(1, Pool.P2D)
    mon.update_stats(InstanceStats(instance_id=1, running_tokens=50))
    got = gs.try_move_decode_to_prefill()
    assert got == 1                                 # P→D member chosen first


# ------------------------------------------------------------ local sched


def test_local_chunked_prefill_decode_first():
    loc = LocalScheduler(0, token_budget=512, mixed_chunk_budget=128)
    loc.enqueue_prefill(1, 1000)
    loc.start_local_decode(2, 300, 5)
    plan = loc.plan_iteration()
    assert plan.decode_rids == [2]
    assert plan.prefill_chunks == [(1, 0, 128)]     # capped by mixed budget
    done = loc.complete_prefill_chunk(1, 128)
    assert not done
    plan2 = loc.plan_iteration()
    assert plan2.prefill_chunks == [(1, 128, 128)]


def test_local_migration_memory_gate():
    loc = LocalScheduler(0, kv_capacity_tokens=1000)
    loc.enqueue_migration(1, 800, 10)
    loc.enqueue_migration(2, 800, 10)
    got = loc.next_migration()
    assert got == (1, 800, 10)
    loc.admit_migrated(*got)
    assert loc.next_migration() is None             # 800+800 > 1000: q2 blocks
    # finish request 1 -> memory frees -> request 2 admissible
    for _ in range(10):
        fin = loc.complete_decode_iteration(1)
    assert fin
    assert loc.next_migration() == (2, 800, 10)


# ----------------------------------------------------------- properties


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(16, 8192), st.booleans()),
                min_size=1, max_size=40))
def test_pool_invariants_under_arbitrary_schedules(ops):
    """Whatever the request stream does: pools partition instances, at least
    one instance stays prefill-capable and one decode-capable."""
    gs, pools, mon, cluster = make_sched(n=4, n_prefill=2, slo=SLO(0.3, 0.05))
    now = 0.0
    for i, (ln, is_prefill) in enumerate(ops):
        now += 0.01
        r = Request(rid=i, arrival=now, input_len=ln, output_len=8)
        if is_prefill:
            out = gs.schedule_prefill(r, now)
        else:
            r.prefill_instance = i % 4
            out = gs.schedule_decode(r, now)
        assert out.instance in range(4)
        ids = sorted(pools.all_ids())
        assert ids == [0, 1, 2, 3]
        assert pools.prefill_capable() or pools.decode_capable()
        assert pools.count(Pool.DECODE, Pool.P2D) >= 1 or \
            pools.count(Pool.PREFILL, Pool.D2P) == 4
        gs.on_monitor_tick(now)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 2000), min_size=1, max_size=30),
       st.integers(64, 2048))
def test_local_scheduler_conserves_work(lengths, budget):
    """Every enqueued prefill token is eventually planned exactly once."""
    loc = LocalScheduler(0, token_budget=budget, mixed_chunk_budget=budget)
    for i, ln in enumerate(lengths):
        loc.enqueue_prefill(i, ln)
    planned = {i: 0 for i in range(len(lengths))}
    for _ in range(100000):
        plan = loc.plan_iteration()
        if plan.is_empty:
            break
        for rid, start, ln in plan.prefill_chunks:
            assert start == planned[rid]            # in-order chunks
            planned[rid] += ln
            loc.complete_prefill_chunk(rid, ln)
    assert planned == {i: ln for i, ln in enumerate(lengths)}
