"""Distribution-layer tests: sharding rules (pure), input specs, and one
subprocess dry-run on a small forced-device-count mesh (the full 16x16 and
2x16x16 sweeps run via launch/dryrun.py; results land in benchmarks/results)."""
import json
import os
import subprocess
import sys

import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.distributed.steps import batch_specs, cache_capacity, supports

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ----------------------------------------------------------- pure rules


def test_param_specs_divisible():
    """Every sharded dim in every arch's param specs divides the axis size."""
    import jax
    from repro.distributed.sharding import param_spec
    from repro.models import build_model
    msize = 16
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

        def walk(node, prefix=""):
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(v, f"{prefix}{k}/")
                return
            spec = param_spec(prefix[:-1], node.shape, msize)
            for dim, s in zip(node.shape, spec):
                if s == "model":
                    assert dim % msize == 0, (arch, prefix, node.shape, spec)

        walk(shapes)


def test_vocab_padding_multiple_of_256():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_supported_matrix():
    """39 of 40 combos supported; whisper long_500k is the documented skip."""
    total = supported = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            total += 1
            supported += supports(cfg, shape)
    assert total == 40
    assert supported == 39
    assert not supports(get_config("whisper-medium"), INPUT_SHAPES["long_500k"])


def test_long_context_capacity_is_subquadratic():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shape = INPUT_SHAPES["long_500k"]
        if not supports(cfg, shape):
            continue
        if cfg.family in ("ssm",):
            continue                       # O(1) state, no KV cache
        cap = cache_capacity(cfg, shape)
        assert cap <= 4096, (arch, cap)    # ring buffer, not 524288


def test_batch_specs_all_combos():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            specs = batch_specs(cfg, shape)
            assert specs, (arch, shape.name)
            for v in specs.values():
                assert all(d > 0 for d in v.shape)


# ------------------------------------------------------ subprocess dry-run


@pytest.mark.parametrize("arch,shape", [("qwen3-1.7b", "decode_32k"),
                                        ("mamba2-370m", "train_4k")])
def test_dryrun_small_mesh_subprocess(arch, shape):
    """lower+compile on a forced 8-device (4x2) mesh inside a fresh process
    (device count must be set before jax initialises)."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import INPUT_SHAPES, get_config
from repro.distributed.steps import build_dryrun
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config({arch!r}).replace(n_layers=2)
if cfg.family == "hybrid":
    cfg = cfg.replace(n_layers=3)
shape = INPUT_SHAPES[{shape!r}]
with mesh:
    fn, args = build_dryrun(cfg, shape, mesh)
    compiled = fn.lower(*args).compile()
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):      # older jaxlib returns [dict]
        c = c[0] if c else {{}}
    assert c.get("flops", 0) > 0
print("OK", c.get("flops"))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_variant_numerics_match_baseline_subprocess():
    """§Perf variants (act_shard / seq_attn / kv_seq_shard) are sharding-only:
    outputs must be bit-comparable to the unconstrained baseline on a real
    8-device mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models import build_model

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_smoke_config("qwen3-1.7b").replace(dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
with mesh:
    base, _ = jax.jit(lambda p, b: model.prefill(p, b, cache_capacity=32))(
        params, {"tokens": tokens})
    cfg2 = cfg.replace(act_batch_axes=("data",), attn_seq_axis="model")
    model2 = build_model(cfg2)
    opt, _ = jax.jit(lambda p, b: model2.prefill(p, b, cache_capacity=32))(
        params, {"tokens": tokens})
np.testing.assert_allclose(np.asarray(base), np.asarray(opt), rtol=2e-5, atol=2e-5)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_dryrun_results_schema_if_present():
    """Validate any sweep records already produced by launch/dryrun.py."""
    from repro.launch.dryrun import RESULTS_DIR
    if not RESULTS_DIR.exists():
        pytest.skip("no dry-run records yet")
    files = list(RESULTS_DIR.glob("*.json"))
    if not files:
        pytest.skip("no dry-run records yet")
    for f in files:
        rec = json.loads(f.read_text())
        assert rec["status"] in ("ok", "skipped", "error"), f.name
        if rec["status"] == "ok":
            assert rec["flops"] > 0
            assert rec["memory"]["argument_bytes"] > 0
        assert rec["status"] != "error", (f.name, rec.get("error"))
