"""The reusable invariant probe (tests/invariants.py) asserted after every
step across seeded random traces on both backends (ISSUE 4 satellite). The
chaos/fault tests in tests/test_faults.py reuse the same probe under
injected crashes; here we establish it holds on healthy runs — and that it
actually *fires* on corrupted state (a probe that can't fail proves
nothing)."""
import time

import numpy as np
import pytest
from invariants import check_invariants

from repro.configs import get_config, get_smoke_config
from repro.core import AutoScalerConfig, Request, SLO
from repro.core.serving import replay_trace
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

CFG = get_config("gemma-2b")


def run_probed(sim, trace):
    replay_trace(sim, trace)
    steps = 0
    while sim.step():
        steps += 1
        check_invariants(sim, streams=False)   # cheap probe every event
    check_invariants(sim)                      # full probe incl. streams
    report = sim.report()
    assert report.n_finished == len(trace), "trace did not complete"
    return report


def test_sim_invariants_hold_on_random_trace():
    p = TRACE_PRESETS["azure_code"]
    trace = load_trace("azure_code", rate_scale=2.0, seed=3, duration=30)
    sim = Simulator(CFG, n_instances=4, n_prefill=2, policy="arrow",
                    slo=SLO(p.slo_ttft, p.slo_tpot))
    run_probed(sim, trace)


def test_sim_invariants_hold_under_elastic_scaling():
    p = TRACE_PRESETS["spike"]
    trace = load_trace("spike", rate_scale=6.0, seed=0, duration=60)
    sim = Simulator(CFG, n_instances=3, n_prefill=1, policy="arrow_elastic",
                    slo=SLO(p.slo_ttft, p.slo_tpot),
                    autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                    max_instances=10,
                                                    up_patience=1,
                                                    cooldown_s=3.0,
                                                    warmup_s=2.0))
    rep = run_probed(sim, trace)
    assert rep.scaling["scale_ups"] >= 1       # the probe saw lifecycle churn
    assert rep.scaling["scale_downs"] >= 1


def test_sim_invariants_hold_with_prefix_cache():
    p = TRACE_PRESETS["multiturn"]
    trace = load_trace("multiturn", rate_scale=2.0, seed=1, duration=60)
    sim = Simulator(CFG, n_instances=4, n_prefill=2, policy="arrow",
                    slo=SLO(p.slo_ttft, p.slo_tpot), prefix_cache=True)
    rep = run_probed(sim, trace)
    assert rep.prefix["hits"] >= 1             # pins/retention were exercised


@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.models import build_model
    cfg = get_smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    return cfg, params


def test_engine_invariants_hold_step_by_step(engine_setup):
    from repro.engine import ArrowEngineCluster
    cfg, params = engine_setup
    rng = np.random.default_rng(5)
    trace = [Request(rid=i, arrival=0.02 * i,
                     input_len=int(rng.integers(8, 48)),
                     output_len=int(rng.integers(2, 6)))
             for i in range(6)]
    eng = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params)
    replay_trace(eng, trace)
    # deadline-bounded, not step-count-bounded: a fast engine can run many
    # thousands of (empty) steps before the last wall-clock arrival is due
    deadline = time.time() + 300.0
    while eng.step() and time.time() < deadline:
        check_invariants(eng, streams=False)
    check_invariants(eng)
    assert eng.report().n_finished == len(trace)


def test_probe_fires_on_corrupted_kv_accounting():
    sim = Simulator(CFG, n_instances=2, n_prefill=1, slo=SLO(3.0, 0.1))
    replay_trace(sim, [Request(0, 0.0, 64, 4)])
    sim.drain()
    check_invariants(sim)                      # healthy: passes
    sim.locals[0].kv_used += 7                 # corrupt the books
    with pytest.raises(AssertionError, match="kv_used"):
        check_invariants(sim)


def test_probe_fires_on_work_on_warming_instance():
    from repro.core.pools import Pool
    sim = Simulator(CFG, n_instances=2, n_prefill=1, policy="arrow_elastic",
                    slo=SLO(3.0, 0.1),
                    autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                    max_instances=6))
    iid = sim.scale_up(Pool.PREFILL, 0.0)      # WARMING (modeled delay)
    check_invariants(sim)
    sim.locals[iid].enqueue_prefill(99, 32)    # illegal: work while warming
    with pytest.raises(AssertionError, match="WARMING"):
        check_invariants(sim)
