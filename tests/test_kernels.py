"""Per-kernel correctness: sweep shapes/dtypes, assert_allclose vs the
pure-jnp oracle (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill import flash_prefill, flash_prefill_ref
from repro.kernels.paged_attention import paged_attention, paged_attention_ref
from repro.kernels.rglru_scan import rglru_scan_op, rglru_scan_ref
from repro.kernels.ssd_scan import ssd_scan_op, ssd_scan_ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ flash_prefill


@pytest.mark.parametrize("B,H,Hk,Sq,T,D", [
    (1, 4, 4, 128, 128, 64),     # MHA square
    (2, 8, 2, 128, 256, 64),     # GQA, chunked (q_offset)
    (1, 8, 1, 256, 256, 128),    # MQA
    (2, 4, 2, 64, 128, 160),     # stablelm head_dim (non-128 lane multiple)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 96])
def test_flash_prefill(B, H, Hk, Sq, T, D, dtype, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hk, T, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hk, T, D), jnp.float32).astype(dtype)
    off = T - Sq
    o = flash_prefill(q, k, v, q_offset=off, window=window, bq=64, bk=64,
                      interpret=True)
    r = flash_prefill_ref(q, k, v, q_offset=off, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **tol(dtype))


# ---------------------------------------------------------- paged_attention


@pytest.mark.parametrize("B,H,Hk,D,page,P,MP", [
    (2, 8, 2, 64, 16, 32, 4),
    (3, 8, 1, 128, 32, 16, 3),   # MQA
    (1, 16, 16, 64, 16, 64, 8),  # MHA (whisper/olmoe-style)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(B, H, Hk, D, page, P, MP, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (P, page, Hk, D), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (P, page, Hk, D), jnp.float32).astype(dtype)
    pt = jax.random.randint(ks[3], (B, MP), 0, P)
    lengths = jnp.arange(1, B + 1, dtype=jnp.int32) * (MP * page // (B + 1)) + 1
    o = paged_attention(q, kp, vp, pt, lengths, interpret=True)
    r = paged_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **tol(dtype))


def test_paged_attention_single_token_context():
    """length=1 edge case: only the first slot of the first page is live."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    B, H, Hk, D, page, P, MP = 2, 4, 2, 64, 16, 8, 2
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (P, page, Hk, D))
    vp = jax.random.normal(ks[2], (P, page, Hk, D))
    pt = jax.random.randint(ks[3], (B, MP), 0, P)
    lengths = jnp.ones((B,), jnp.int32)
    o = paged_attention(q, kp, vp, pt, lengths, interpret=True)
    r = paged_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- ssd_scan


@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 64, 3, 32, 16, 16),
    (1, 128, 2, 64, 128, 32),    # mamba2-370m-like head
    (2, 32, 1, 16, 8, 32),       # single chunk
])
def test_ssd_scan(B, S, H, P, N, Q):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    la = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.5
    Bm = jax.random.normal(ks[2], (B, S, H, N)) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, H, N)) * 0.3
    y, h = ssd_scan_op(x, la, Bm, Cm, chunk=Q, interpret=True)
    yr, hr = ssd_scan_ref(x, la, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4, atol=2e-4)


def test_ssd_scan_chunk_resume_matches_full():
    """Engine chunked prefill (DESIGN.md §13): the second chunk resumes the
    scan from the carried h0. Two chained kernel calls must equal one full
    call — under the mamba2-370m engine shapes (P=32, N=16, chunk=16)."""
    B, S, H, P, N, Q = 2, 64, 2, 32, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    la = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.5
    Bm = jax.random.normal(ks[2], (B, S, H, N)) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, H, N)) * 0.3
    y_full, h_full = ssd_scan_op(x, la, Bm, Cm, chunk=Q, interpret=True)
    cut = S // 2
    y1, h1 = ssd_scan_op(x[:, :cut], la[:, :cut], Bm[:, :cut], Cm[:, :cut],
                         chunk=Q, interpret=True)
    y2, h2 = ssd_scan_op(x[:, cut:], la[:, cut:], Bm[:, cut:], Cm[:, cut:],
                         chunk=Q, h0=h1, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
    # and the resumed half agrees with the oracle seeded the same way
    yr, hr = ssd_scan_ref(x[:, cut:], la[:, cut:], Bm[:, cut:], Cm[:, cut:],
                          h0=h1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hr),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- rglru_scan


@pytest.mark.parametrize("B,S,W,bs,bw", [
    (2, 64, 256, 16, 128),
    (1, 128, 512, 128, 512),
    (3, 32, 128, 8, 128),
])
@pytest.mark.parametrize("with_h0", [False, True])
def test_rglru_scan(B, S, W, bs, bw, with_h0):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    la = -jnp.abs(jax.random.normal(ks[0], (B, S, W))) * 0.3
    b = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W)) if with_h0 else None
    y, h = rglru_scan_op(la, b, h0, bs=bs, bw=bw, interpret=True)
    yr, hr = rglru_scan_ref(la, b, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-5, atol=1e-5)


def test_rglru_scan_chunk_resume_matches_full():
    """Chained chunks with carried h0 equal one full scan — the hybrid
    engine's chunked-prefill resume path (DESIGN.md §13)."""
    B, S, W, bs, bw = 2, 64, 256, 16, 128
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    la = -jnp.abs(jax.random.normal(ks[0], (B, S, W))) * 0.3
    b = jax.random.normal(ks[1], (B, S, W))
    y_full, h_full = rglru_scan_op(la, b, None, bs=bs, bw=bw, interpret=True)
    cut = S // 2
    y1, h1 = rglru_scan_op(la[:, :cut], b[:, :cut], None,
                           bs=bs, bw=bw, interpret=True)
    y2, h2 = rglru_scan_op(la[:, cut:], b[:, cut:], h1,
                           bs=bs, bw=bw, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------- model-path ⇄ kernel parity


def test_flash_matches_model_sdpa():
    """The model zoo's reference sdpa and the kernel agree (causal, GQA)."""
    from repro.models import common as cm
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, S, H, Hk, D = 2, 64, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    mask = cm.causal_mask(S, S)
    o_model = cm.sdpa(q, k, v, mask)                       # (B,S,H*D)
    o_kernel = flash_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), bq=32, bk=32,
                             interpret=True)
    o_kernel = o_kernel.transpose(0, 2, 1, 3).reshape(B, S, H * D)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel),
                               rtol=2e-5, atol=2e-5)
