"""Elastic cluster scaling (DESIGN.md §6): instance lifecycle state machine,
pool-flip edge cases under retirement, scheduler placement guarantees, the
AutoScaler decision loop, and the end-to-end sim acceptance run on the spike
trace (deterministic — virtual clock + seeded trace)."""
import pytest

from repro.configs import get_config
from repro.core import (SLO, AutoScalerConfig, GlobalScheduler,
                        InstanceMonitor, InstancePools, InstanceStats,
                        Lifecycle, Pool, Request, SchedulerConfig,
                        TTFTPredictor)
from repro.core.serving import replay_trace
from repro.sim import Simulator
from repro.traces import TRACE_PRESETS, load_trace

CFG = get_config("gemma-2b")


# ------------------------------------------------- lifecycle state machine


def test_lifecycle_add_activate_retire_remove():
    pools = InstancePools(range(2), n_prefill=1)
    pools.add_instance(2, Pool.DECODE, warming=True)
    assert pools.lifecycle_of(2) is Lifecycle.WARMING
    assert 2 in pools.all_ids() and 2 not in pools.members(Pool.DECODE)
    assert 2 not in pools.decode_capable()
    pools.activate(2)
    assert pools.lifecycle_of(2) is Lifecycle.ACTIVE
    assert 2 in pools.members(Pool.DECODE) and 2 in pools.decode_capable()
    pools.begin_retire(2)
    assert pools.lifecycle_of(2) is Lifecycle.RETIRING
    assert 2 not in pools.decode_capable() and 2 in pools.all_ids()
    pools.remove_instance(2)
    assert 2 not in pools.all_ids()


def test_lifecycle_guards():
    pools = InstancePools(range(2), n_prefill=1)
    with pytest.raises(ValueError, match="already exists"):
        pools.add_instance(0, Pool.PREFILL)
    with pytest.raises(ValueError, match="not warming"):
        pools.activate(0)                       # already active
    with pytest.raises(ValueError, match="retire first"):
        pools.remove_instance(0)                # must retire before removing
    pools.begin_retire(0)
    with pytest.raises(ValueError, match="cannot retire"):
        pools.begin_retire(0)                   # double-retire refused


def test_flip_of_retiring_instance_is_refused():
    pools = InstancePools(range(4), n_prefill=2)
    pools.begin_retire(0)                       # a PREFILL member
    with pytest.raises(ValueError, match="cannot flip"):
        pools.flip_to_decode(0, has_pending_prefill=False)
    pools.begin_retire(2)                       # a DECODE member
    with pytest.raises(ValueError, match="cannot flip"):
        pools.flip_to_prefill(2, has_pending_decode=True)
    # warming instances are equally unflippable
    pools.add_instance(9, Pool.PREFILL, warming=True)
    with pytest.raises(ValueError, match="cannot flip"):
        pools.flip_to_decode(9, has_pending_prefill=False)


def test_drain_transitions_during_retire_are_noops():
    """The Fig. 5 black edges must not resurrect a retiring instance into an
    active pool."""
    pools = InstancePools(range(4), n_prefill=2)
    pools.flip_to_decode(0, has_pending_prefill=True)   # 0 -> P2D
    pools.flip_to_prefill(2, has_pending_decode=True)   # 2 -> D2P
    pools.begin_retire(0)
    pools.begin_retire(2)
    flips_before = pools.flips
    pools.on_prefill_drained(0)
    pools.on_decode_drained(2)
    assert pools.pool_of(0) is Pool.P2D         # unchanged
    assert pools.pool_of(2) is Pool.D2P
    assert pools.flips == flips_before
    assert not pools.decode_capable() or 0 not in pools.decode_capable()


# --------------------------------------------- scheduler placement guards


class FakeCluster:
    def has_pending_prefill(self, iid):
        return False

    def has_pending_decode(self, iid):
        return False


def make_sched(n=4, n_prefill=2, slo=SLO(1.0, 0.1), **cfg_kw):
    pools = InstancePools(range(n), n_prefill=n_prefill)
    mon = InstanceMonitor(range(n))
    for i in range(n):
        mon.update_stats(InstanceStats(instance_id=i))
    pred = TTFTPredictor.fit([(0, 0.0), (1000, 0.1), (2000, 0.3), (4000, 1.0)])
    cfg = SchedulerConfig(max_running_tokens=10000, **cfg_kw)
    gs = GlobalScheduler(pools, mon, pred, slo, cfg, FakeCluster())
    return gs, pools, mon


def test_scheduler_never_places_work_on_retiring_instance():
    """Algorithms 1-4 must treat a retiring instance as nonexistent, even
    under pressure that would otherwise flip or fall back onto it."""
    gs, pools, mon = make_sched(n=4, n_prefill=2, slo=SLO(0.2, 0.01))
    pools.begin_retire(0)        # prefill member
    pools.begin_retire(2)        # decode member
    for i in range(40):
        r = Request(rid=i, arrival=0.01 * i, input_len=4000, output_len=8)
        out_p = gs.schedule_prefill(r, now=0.01 * i)
        assert out_p.instance not in (0, 2), f"prefill placed on retiring"
        r.prefill_instance = out_p.instance
        out_d = gs.schedule_decode(r, now=0.01 * i)
        assert out_d.instance not in (0, 2), f"decode placed on retiring"
        gs.on_monitor_tick(0.01 * i)


def test_decode_does_not_stay_on_retiring_prefill_instance():
    """Algorithm 2's keep-local shortcut (prefill instance already on decode
    duty) must not apply when that instance is retiring."""
    gs, pools, mon = make_sched()
    pools.flip_to_decode(0, has_pending_prefill=False)
    pools.begin_retire(0)
    r = Request(rid=1, arrival=0.0, input_len=500, output_len=10)
    r.prefill_instance = 0
    out = gs.schedule_decode(r, now=0.0)
    assert out.instance != 0


def test_flip_candidates_exclude_retiring():
    gs, pools, mon = make_sched(n=4, n_prefill=2)
    pools.begin_retire(2)
    pools.begin_retire(3)
    # no active decode member is spare -> no D->P flip possible
    assert gs.try_move_decode_to_prefill() is None


# ------------------------------------------------------ runtime lifecycle


def elastic_sim(**kw):
    defaults = dict(n_instances=4, n_prefill=2, policy="arrow_elastic",
                    slo=SLO(3.0, 0.1),
                    autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                    max_instances=12))
    defaults.update(kw)
    return Simulator(CFG, **defaults)


def test_sim_scale_up_warms_then_activates():
    sim = elastic_sim()
    iid = sim.scale_up(Pool.PREFILL, sim.clock.now())
    assert sim.pools.lifecycle_of(iid) is Lifecycle.WARMING
    assert iid in sim.locals and iid in sim.costs
    assert iid not in sim.pools.members(Pool.PREFILL)
    # warm-up is an event on the virtual clock
    sim.run_until(sim.autoscaler.cfg.warmup_s + 1e-6)
    assert sim.pools.lifecycle_of(iid) is Lifecycle.ACTIVE
    assert iid in sim.pools.members(Pool.PREFILL)
    assert iid in sim.policy.prefill_ready_at


def test_sim_retire_drains_and_removes():
    """begin_retire mid-run: resident decode work migrates away via the FCFS
    manager, every request still finishes exactly once, and the instance is
    eventually removed from every runtime structure."""
    sim = elastic_sim()
    trace = load_trace("azure_code", rate_scale=4.0, seed=0, duration=30)
    tokens = {}
    replay_trace(sim, trace,
                 on_token=lambda h, tok, t: tokens.setdefault(h.rid, []).append(t))
    sim.run_until(5.0)
    # retire the decode-capable instance carrying the most work
    cands = [i for i in sim.pools.decode_capable()
             if sim.locals[i].decode_running]
    victim = max(cands, key=lambda i: len(sim.locals[i].decode_running)) \
        if cands else sim.pools.decode_capable()[0]
    migrated = list(sim.locals[victim].decode_running)
    sim.begin_retire(victim, sim.clock.now())
    assert not sim.locals[victim].decode_running      # evacuated immediately
    for rid in migrated:
        assert sim.handles[rid].req.decode_instance != victim
    report = sim.drain()
    assert report.n_finished == len(trace)
    for r in trace:
        # exactly one o_1 + (output_len-1) decode tokens: nothing dropped or
        # duplicated across the retire-migration
        assert len(tokens[r.rid]) == r.output_len
        ts = tokens[r.rid]
        assert all(a <= b for a, b in zip(ts, ts[1:]))
    assert victim not in sim.pools.all_ids()
    assert victim not in sim.locals
    assert victim not in sim.policy.prefill_ready_at
    assert report.scaling["instance_seconds"] < 4 * report.duration


def test_retire_waits_for_inflight_inbound_migration():
    """Regression: a retiring instance with a KV transfer *in the air toward
    it* (admitted, not yet landed) must not be finalized — the transfer must
    land, decode drains in place, and removal happens afterwards."""
    from repro.core.request import RequestState
    sim = elastic_sim()
    h = sim.submit(Request(rid=0, arrival=0.0, input_len=512, output_len=4))
    dst = None
    for _ in range(10000):
        alive = sim.step()
        req = h.req
        if req.state is RequestState.MIGRATING and \
                req.decode_instance is not None:
            loc = sim.locals[req.decode_instance]
            if not loc.migration_queue and 0 not in loc.decode_running:
                dst = req.decode_instance        # admitted, still in flight
                break
        if not alive:
            break
    assert dst is not None, "no in-flight migration window observed"
    sim.begin_retire(dst, sim.clock.now())
    sim._maybe_finalize_retires(sim.clock.now())
    assert dst in sim.locals                     # NOT finalized mid-transfer
    report = sim.drain()
    assert report.n_finished == 1
    assert len(h.tokens) == h.req.output_len     # nothing dropped
    sim.collect_stats(sim.clock.now())           # final tick finalizes
    assert dst not in sim.pools.all_ids()


def test_autoscaler_requires_elastic_policy():
    with pytest.raises(ValueError, match="not elastic"):
        Simulator(CFG, n_instances=4, n_prefill=2, policy="arrow",
                  autoscaler_cfg=AutoScalerConfig())


def test_autoscaler_scales_up_under_pressure_and_down_when_idle():
    """Direct decision-loop check with synthetic monitor state (no trace)."""
    sim = elastic_sim(autoscaler_cfg=AutoScalerConfig(
        min_instances=2, max_instances=6, up_patience=2, down_patience=3,
        cooldown_s=0.0, warmup_s=0.0))
    asc = sim.autoscaler
    # sustained prefill pressure: queues predicted far beyond the TTFT budget
    for i in sim.pools.prefill_capable():
        sim.policy.prefill_ready_at[i] = 100.0
    n0 = len(sim.pools.all_ids())
    for t in range(4):
        sim.collect_stats(float(t))
    assert asc.n_scale_ups >= 1
    assert len(sim.pools.all_ids()) > n0
    assert asc.events[0].pool is Pool.PREFILL      # pressure picked the pool
    # now fully idle: pressure gone -> shrink toward min_instances
    for i in sim.pools.all_ids():
        sim.policy.prefill_ready_at[i] = 0.0
    for t in range(4, 40):
        sim.collect_stats(float(t))
    assert asc.n_scale_downs >= 1
    assert len(sim.pools.active_ids()) >= asc.cfg.min_instances


def test_autoscaler_respects_bounds():
    sim = elastic_sim(autoscaler_cfg=AutoScalerConfig(
        min_instances=4, max_instances=5, up_patience=1, down_patience=1,
        cooldown_s=0.0, warmup_s=0.0))
    for i in sim.pools.prefill_capable():
        sim.policy.prefill_ready_at[i] = 1e9
    for t in range(20):
        sim.collect_stats(float(t))
    assert len(sim.pools.all_ids()) <= 5           # ceiling holds
    for i in sim.pools.all_ids():
        sim.policy.prefill_ready_at[i] = 0.0
    for t in range(20, 80):
        sim.collect_stats(float(t))
    assert len(sim.pools.active_ids()) >= 4        # floor holds


# ------------------------------------------- acceptance: spike trace study


def test_elastic_matches_static_attainment_with_fewer_instance_seconds():
    """Acceptance (ISSUE 2): on the spike trace, arrow_elastic records >=1
    scale-up and >=1 scale-down, attains >= the static 8-instance arrow run,
    and pays fewer instance-seconds. Fully deterministic: virtual clock,
    seeded trace."""
    p = TRACE_PRESETS["spike"]
    slo = SLO(p.slo_ttft, p.slo_tpot)
    trace = load_trace("spike", rate_scale=4.0, seed=0)

    static = Simulator(CFG, n_instances=8, n_prefill=4, policy="arrow",
                       slo=slo)
    replay_trace(static, trace)
    rep_s = static.drain()

    elastic = Simulator(CFG, n_instances=4, n_prefill=2,
                        policy="arrow_elastic", slo=slo,
                        autoscaler_cfg=AutoScalerConfig(min_instances=2,
                                                        max_instances=12))
    replay_trace(elastic, trace)
    rep_e = elastic.drain()

    assert rep_e.scaling["scale_ups"] >= 1
    assert rep_e.scaling["scale_downs"] >= 1
    assert rep_e.n_finished == len(trace)
    assert rep_e.attainment >= rep_s.attainment
    assert rep_e.scaling["instance_seconds"] < rep_s.scaling["instance_seconds"]
