"""Unified ServingSystem API (DESIGN.md §1): sim/engine parity on one trace,
streaming-callback ordering, SLO tiers, the decode-fallback fix and the
deprecation shims."""
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core import (SLO, InstanceMonitor, InstancePools, InstanceStats,
                        Request, SchedulerConfig, TTFTPredictor)
from repro.core.global_scheduler import GlobalScheduler
from repro.core.serving import TIERS, replay_trace
from repro.sim import Simulator

SIM_CFG = get_config("gemma-2b")


def tiny_trace(n=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, arrival=0.02 * i,
                    input_len=int(rng.integers(8, 48)),
                    output_len=int(rng.integers(2, 6)))
            for i in range(n)]


@pytest.fixture(scope="module")
def engine_setup():
    import jax
    from repro.models import build_model
    cfg = get_smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    return cfg, params


# --------------------------------------------------------------- streaming


def test_streaming_tokens_monotone_and_ttft_is_first_callback():
    sim = Simulator(SIM_CFG, n_instances=4, n_prefill=2, slo=SLO(3.0, 0.1))
    events = {}

    def on_token(handle, tok, t):
        events.setdefault(handle.rid, []).append(t)

    trace = tiny_trace(8)
    handles = replay_trace(sim, trace, on_token=on_token)
    report = sim.drain()
    assert report.n_finished == len(trace)
    for h in handles:
        ts = events[h.rid]
        # one callback per output token (o_1 .. o_m), in order
        assert len(ts) == h.req.output_len
        assert all(a <= b for a, b in zip(ts, ts[1:]))
        # TTFT equals the first callback's landing time
        assert h.ttft == pytest.approx(ts[0] - h.req.arrival)


def test_on_finish_fires_once_per_request():
    sim = Simulator(SIM_CFG, n_instances=2, n_prefill=1, slo=SLO(3.0, 0.1))
    finished = []
    replay_trace(sim, tiny_trace(5), on_finish=lambda h: finished.append(h.rid))
    sim.drain()
    assert sorted(finished) == list(range(5))


# ------------------------------------------------------------------- tiers


def test_slo_tiers_scale_per_request():
    sim = Simulator(SIM_CFG, n_instances=2, n_prefill=1, slo=SLO(2.0, 0.2))
    trace = tiny_trace(4)
    h_int = replay_trace(sim, trace[:2], tier="interactive")
    h_bat = replay_trace(sim, trace[2:], tier="batch")
    report = sim.drain()
    assert h_int[0].slo == TIERS["interactive"].apply(SLO(2.0, 0.2))
    assert h_bat[0].slo == SLO(8.0, 0.8)
    assert set(report.attainment_by_tier()) == {"interactive", "batch"}


def test_unknown_tier_rejected():
    sim = Simulator(SIM_CFG, n_instances=2, n_prefill=1)
    with pytest.raises(ValueError, match="tier"):
        sim.submit(Request(0, 0.0, 16, 2), tier="platinum")


# ----------------------------------------------------------- open-loop sim


def test_sim_run_until_is_incremental():
    sim = Simulator(SIM_CFG, n_instances=2, n_prefill=1, slo=SLO(3.0, 0.1))
    handles = replay_trace(sim, tiny_trace(6))
    sim.run_until(0.01)
    assert sim.clock.now() == pytest.approx(0.01)
    n_early = sum(1 for h in handles if h.done)
    report = sim.drain()
    assert report.n_finished == 6 >= n_early


def test_sim_run_shim_still_works():
    trace = tiny_trace(6)
    sim = Simulator(SIM_CFG, n_instances=2, n_prefill=1, slo=SLO(3.0, 0.1))
    with pytest.deprecated_call():
        res = sim.run(trace)
    assert all(r.finish_time is not None for r in res.requests)


def test_sim_run_shim_identical_to_replay_trace():
    """The deprecated batch shim must produce results *identical* to the
    unified replay_trace + drain path (it is a thin delegation — the
    virtual clock makes this exact)."""
    def make():
        return Simulator(SIM_CFG, n_instances=2, n_prefill=1,
                         slo=SLO(3.0, 0.1))

    trace = tiny_trace(8, seed=11)
    sim_old = make()
    with pytest.deprecated_call():
        res = sim_old.run([Request(rid=r.rid, arrival=r.arrival,
                                   input_len=r.input_len,
                                   output_len=r.output_len) for r in trace])
    sim_new = make()
    handles = replay_trace(sim_new, trace)
    rep = sim_new.drain()
    assert rep.n_finished == len(trace) == len(res.requests)
    old = {r.rid: (r.first_token_time, r.finish_time, tuple(r.token_times))
           for r in res.requests}
    new = {h.rid: (h.req.first_token_time, h.req.finish_time,
                   tuple(h.req.token_times)) for h in handles}
    assert old == new
    assert res.sim_time == rep.duration


def test_engine_serve_shim_warns_and_matches_unified_path(engine_setup):
    """ArrowEngineCluster.serve() must emit a DeprecationWarning and stream
    the same greedy token ids as submit()+drain() with the same prompts
    (content is schedule-independent; timings are wall-clock and are not
    compared)."""
    from repro.engine import ArrowEngineCluster, ServeRequest
    cfg, params = engine_setup
    rng = np.random.default_rng(42)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (24, 40, 32)]
    outs = (4, 3, 2)

    eng1 = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                              capacity=128, slo=SLO(5.0, 2.0), params=params)
    reqs = [ServeRequest(rid=i, prompt=p, max_new_tokens=m)
            for i, (p, m) in enumerate(zip(prompts, outs))]
    with pytest.deprecated_call():
        served = eng1.serve(reqs, timeout=300.0)

    eng2 = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                              capacity=128, slo=SLO(5.0, 2.0), params=params)
    handles = [eng2.submit(Request(rid=i, arrival=0.0, input_len=len(p),
                                   output_len=m), prompt=p)
               for i, (p, m) in enumerate(zip(prompts, outs))]
    eng2.drain(timeout=300.0)

    for sr, h in zip(served, handles):
        assert sr.req.finish_time is not None and h.done
        assert sr.output_tokens == [t for t in h.tokens if t is not None]
        assert len(sr.output_tokens) == sr.max_new_tokens


# --------------------------------------------------- sim/engine parity


def test_sim_engine_parity_same_trace(engine_setup):
    """Acceptance: the same trace object completes through both backends via
    the unified API, streaming callbacks fire on both, and request-level
    scheduling-decision counts are identical under a fixed seed."""
    cfg, params = engine_setup
    trace = tiny_trace(6, seed=3)

    sim = Simulator(SIM_CFG, n_instances=2, n_prefill=1, slo=SLO(5.0, 2.0))
    sim_tokens = {}
    h_sim = replay_trace(sim, trace,
                         on_token=lambda h, tok, t:
                         sim_tokens.setdefault(h.rid, []).append(t))
    rep_sim = sim.drain()

    from repro.engine import ArrowEngineCluster
    eng = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params)
    eng_tokens = {}
    h_eng = replay_trace(eng, trace,
                         on_token=lambda h, tok, t:
                         eng_tokens.setdefault(h.rid, []).append(tok))
    rep_eng = eng.drain(timeout=300.0)

    assert rep_sim.n_finished == rep_eng.n_finished == len(trace)
    assert all(h.done for h in h_sim) and all(h.done for h in h_eng)
    # identical request-level decision counts (migrations are timing-bound)
    assert (rep_sim.decisions["prefill"], rep_sim.decisions["decode"]) == \
           (rep_eng.decisions["prefill"], rep_eng.decisions["decode"])
    # both streamed every token; the engine streamed real token ids
    for r in trace:
        assert len(sim_tokens[r.rid]) == r.output_len
        assert len(eng_tokens[r.rid]) == r.output_len
        assert all(isinstance(t, int) for t in eng_tokens[r.rid])


def test_engine_runs_colocated_baseline(engine_setup):
    """Acceptance: the engine runs a non-arrow baseline policy end-to-end
    (previously only the simulator had the POLICIES registry)."""
    from repro.engine import ArrowEngineCluster
    cfg, params = engine_setup
    eng = ArrowEngineCluster(cfg, n_instances=2, n_prefill=1, n_slots=4,
                             capacity=128, slo=SLO(5.0, 2.0), params=params,
                             policy="colocated")
    handles = replay_trace(eng, tiny_trace(4, seed=1))
    report = eng.drain(timeout=300.0)
    assert report.n_finished == 4
    # colocated: decode where you prefilled, never a KV transfer
    assert all(h.req.decode_instance == h.req.prefill_instance
               for h in handles)
    assert report.decisions["migrations"] == 0


# ------------------------------------------------- decode fallback fix


def test_schedule_decode_fallback_targets_least_loaded_decode_capable():
    """With every instance pinned to PREFILL and flips forbidden, the decode
    fallback must pick the least-loaded instance, not an arbitrary id."""

    class FakeCluster:
        def has_pending_prefill(self, iid):
            return False

        def has_pending_decode(self, iid):
            return False

    pools = InstancePools(range(3), n_prefill=3)
    mon = InstanceMonitor(range(3))
    for iid, rt in zip(range(3), (40, 5, 90)):
        mon.update_stats(InstanceStats(instance_id=iid, running_tokens=rt))
    pred = TTFTPredictor.fit([(0, 0.0), (1000, 0.1), (4000, 1.0)])
    cfg = SchedulerConfig(max_running_tokens=10,  # force t1/t2 rejection
                          min_prefill_instances=3)  # forbid P->D flip
    gs = GlobalScheduler(pools, mon, pred, SLO(1.0, 0.1), cfg, FakeCluster())
    out = gs.schedule_decode(Request(0, 0.0, 100, 8), now=0.0)
    assert out.via_fallback
    assert out.instance == 1          # least running_tokens, not all_ids()[-1]
