"""Per-architecture smoke tests: reduced configs (2-3 layers, d_model<=512,
<=4 experts) run one forward/train step on CPU; assert output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model

B, S = 2, 32


def make_batch(cfg, kind="train"):
    k = jax.random.PRNGKey(0)
    if cfg.family == "encdec":
        F = cfg.encoder.n_frames
        return {
            "audio_embeds": jax.random.normal(k, (B, F, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(S)[None, None], (B, 3, S))
        return {
            "embeds": jax.random.normal(k, (B, S, cfg.d_model), jnp.float32),
            "positions": pos,
            "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}


def decode_batch(cfg, pos_val):
    tok = jnp.ones((B, 1), jnp.int32)
    pos = jnp.full((B,), pos_val, jnp.int32)
    if cfg.family == "vlm":
        mpos = jnp.full((B, 3, 1), pos_val, jnp.int32)
        return {"token": tok, "positions": mpos, "pos": pos}
    return {"token": tok, "pos": pos}


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_smoke_config(request.param)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(request.param.__hash__() % 2**31))
    return cfg, model, params


def test_forward_loss(arch_setup):
    cfg, model, params = arch_setup
    loss = jax.jit(model.loss)(params, make_batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{cfg.arch_id}: loss not finite"


def test_train_step_grads(arch_setup):
    cfg, model, params = arch_setup
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, make_batch(cfg))
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, np.float32))), cfg.arch_id


def test_prefill_decode(arch_setup):
    cfg, model, params = arch_setup
    batch = make_batch(cfg, "prefill")
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_capacity=S + 8))(params, batch)
    assert logits.shape[:2] == (B, S)
    assert logits.shape[2] == cfg.padded_vocab
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert cache is not None

    step = jax.jit(model.decode)
    for i in range(3):
        logits, cache = step(params, cache, decode_batch(cfg, S + i))
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), cfg.arch_id


def test_decode_matches_prefill(arch_setup):
    """Property: decoding token t with the cache must equal the full-seq
    forward's logits at position t (teacher forcing)."""
    cfg, model, params = arch_setup
    if cfg.family in ("vlm",):
        pytest.skip("vlm decode embeds tokens; prefill consumes stub embeddings")
    batch = make_batch(cfg)
    tokens = batch["tokens"]
    full_logits, _ = jax.jit(
        lambda p, b: model.prefill(p, b, cache_capacity=S))(params, batch)

    half = S // 2
    pre = dict(batch)
    pre["tokens"] = tokens[:, :half]
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_capacity=S))(params, pre)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1], np.float32),
        np.asarray(full_logits[:, half - 1], np.float32),
        rtol=2e-4, atol=2e-4)

    step = jax.jit(model.decode)
    for t in range(half, min(half + 4, S)):
        db = decode_batch(cfg, t)
        db["token"] = tokens[:, t:t + 1]
        logits, cache = step(params, cache, db)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=2e-3, atol=2e-3, err_msg=f"{cfg.arch_id} step {t}")
