"""Benchmark harness integration: each module runs and emits the CSV contract
(name,us_per_call,derived); roofline consumes real dry-run records."""
import contextlib
import io
import sys

import pytest

sys.path.insert(0, ".")  # benchmarks package lives at repo root


def capture(fn, *args):
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        fn(*args)
    return buf.getvalue().strip().splitlines()


def test_trace_stats_emits_csv():
    from benchmarks import bench_trace_stats
    from repro.traces import TRACE_PRESETS
    lines = capture(bench_trace_stats.main)
    assert len(lines) == len(TRACE_PRESETS)
    for line in lines:
        name, us, derived = line.split(",", 2)
        assert name.startswith("trace_stats.")
        assert float(us) >= 0


def test_load_difference_prefill_leads():
    from benchmarks import bench_load_difference
    lines = capture(bench_load_difference.main)
    derived = lines[0].split(",", 2)[2]
    lead = float(derived.split("lead=")[1].rstrip("s"))
    assert lead > 0


def test_scalability_quick():
    from benchmarks import bench_scalability
    lines = capture(bench_scalability.main, ["--smoke"])
    assert len(lines) == 5          # n2/n4 x two strategies + overhead point
    att = {}
    for line in lines:
        name, _, derived = line.split(",", 2)
        if name.startswith("scalability.overhead"):
            assert "us_per_request=" in derived and "us_per_token=" in derived
        else:
            att[name] = float(derived.split("=")[1])
    assert att["scalability.n4.arrow"] >= att["scalability.n2.arrow"]


def test_elastic_benchmark_smoke():
    from benchmarks import bench_elastic
    lines = capture(bench_elastic.main, ["--smoke"])
    assert any(line.startswith("elastic.spike.arrow_elastic") for line in lines)
    assert any(line.startswith("elastic.spike.saving") for line in lines)
    for line in lines:
        name, us, derived = line.split(",", 2)
        assert float(us) >= 0


def test_roofline_from_records():
    from repro.launch.dryrun import RESULTS_DIR
    if not RESULTS_DIR.exists() or not list(RESULTS_DIR.glob("*.json")):
        pytest.skip("dry-run records not generated yet")
    from benchmarks import roofline
    lines = capture(roofline.main, [])
    assert len(lines) >= 10
    doms = set()
    for line in lines:
        derived = line.split(",", 2)[2]
        doms.add(derived.split(";")[0].split("=")[1])
    assert doms <= {"compute", "memory", "collective"}
    # decode must be memory- or collective-bound, never compute-bound (the
    # paper's core asymmetry, quantified)
    for line in lines:
        if ".decode_32k" in line or ".long_500k" in line:
            assert "dominant=compute" not in line


def test_model_flops_analytics_positive():
    from benchmarks.roofline import model_flops
    from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
    from repro.distributed.steps import supports
    for arch in ARCH_IDS:
        for sname, shape in INPUT_SHAPES.items():
            if not supports(get_config(arch), shape):
                continue
            assert model_flops(arch, sname) > 0, (arch, sname)
