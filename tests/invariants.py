"""Cross-backend runtime invariant probe (ISSUE 4 satellite).

``check_invariants(runtime)`` asserts structural properties that must hold
between any two steps of either ``ServingSystem`` backend — the same probe
runs against the discrete-event simulator and the real JAX engine, and the
fault/chaos tests (tests/test_faults.py) assert it after *every* step:

  1. **KV tokens conserved per instance** — each live LocalScheduler's
     ``kv_used`` equals the sum of its visible obligations: queued prefill
     footprints + decode contexts + retained prefixes + KV parked for
     outbound migrations + reservations for transfers in flight toward it.
  2. **Never schedule on non-ACTIVE** — WARMING instances hold no work at
     all; FAILED corpses are empty and hold no KV; RETIRING and DEGRADED
     (quarantined, DESIGN.md §14) instances have an empty migration queue
     (evacuated at begin_retire/quarantine, nothing new may be enqueued);
     no live request points at a WARMING/FAILED instance (pointing at a
     DEGRADED one is legal — pre-quarantine prefill drains in place).
  3. **Prefix-pin refcounts sane** — pins are never negative, entries
     doomed by invalidation are pinned (else they would have been freed),
     and every live entry matches the owning scheduler's ``retained``
     bookkeeping token-for-token.
  4. **Per-request token streams strictly ordered** — timestamps increase
     monotonically (strictly under the virtual clock), a request never
     streams more than ``output_len`` tokens, and a finished request
     streamed exactly ``output_len``.

The probe reads runtime internals on purpose: it is a test instrument, not
API surface.
"""
from __future__ import annotations

from repro.core.clock import VirtualClock
from repro.core.pools import Lifecycle
from repro.core.request import RequestState


def _fail(runtime, iid, msg):
    life = {i: runtime.pools.lifecycle_of(i).value
            for i in runtime.pools.all_ids()}
    raise AssertionError(f"invariant violated on instance {iid}: {msg} "
                         f"(lifecycles: {life})")


def _expected_kv(runtime, iid, loc) -> int:
    exp = sum(w.input_len for w in loc.prefill_queue.values())
    exp += sum(w.context_len for w in loc.decode_running.values())
    exp += sum(loc.retained.values())
    # KV parked here as the source of a not-yet-landed migration
    for rid, kv in runtime._migration_kv.items():
        req = runtime.handles[rid].req
        if req.state is RequestState.MIGRATING and \
                runtime._kv_source(rid) == iid:
            exp += kv
    # destination reservations for transfers in the air toward this instance
    exp += sum(kv for (_, dst, kv) in runtime._transfers.values()
               if dst == iid)
    return exp


def check_invariants(runtime, *, streams: bool = True) -> None:
    """Assert the runtime invariants; raises AssertionError with context.
    ``streams=False`` skips the O(total-tokens) stream scan for per-step
    probing of long runs (do a final full check at the end instead)."""
    pools = runtime.pools
    strict = isinstance(runtime.clock, VirtualClock)

    # ---- per-instance: lifecycle-vs-work and KV conservation
    for iid in pools.all_ids():
        life = pools.lifecycle_of(iid)
        if life is Lifecycle.FAILED:
            continue                      # substrate gone; checked via handles
        loc = runtime.local_of(iid)
        if life is Lifecycle.WARMING:
            if loc.prefill_queue or loc.decode_running or loc.migration_queue:
                _fail(runtime, iid, "WARMING instance holds work")
        if life in (Lifecycle.RETIRING, Lifecycle.DEGRADED) and \
                loc.migration_queue:
            _fail(runtime, iid,
                  f"{life.value} instance has queued migrations")
        if loc.kv_used < 0:
            _fail(runtime, iid, f"negative kv_used {loc.kv_used}")
        exp = _expected_kv(runtime, iid, loc)
        if loc.kv_used != exp:
            _fail(runtime, iid,
                  f"kv_used {loc.kv_used} != reconstructed {exp}")

    # the schedulable sets must never contain a non-ACTIVE instance
    for ids, name in ((pools.prefill_capable(), "prefill_capable"),
                      (pools.decode_capable(), "decode_capable"),
                      (pools.active_ids(), "active_ids")):
        for iid in ids:
            if pools.lifecycle_of(iid) is not Lifecycle.ACTIVE:
                _fail(runtime, iid, f"non-ACTIVE instance in {name}")

    # ---- per-request: placement targets and stream ordering
    for rid, handle in runtime.handles.items():
        req = handle.req
        if req.state is RequestState.REJECTED:
            # admission-rejected requests (§10) were turned away before
            # placement: they must hold nothing, anywhere, ever
            if (req.prefill_instance is not None
                    or req.decode_instance is not None
                    or handle.tokens or req.finish_time is not None
                    or handle.rejection is None):
                raise AssertionError(
                    f"rejected rid {rid} holds scheduling state "
                    f"(prefill={req.prefill_instance} "
                    f"decode={req.decode_instance} "
                    f"tokens={len(handle.tokens)} "
                    f"rejection={handle.rejection!r})")
            continue
        for attr in ("prefill_instance", "decode_instance"):
            iid = getattr(req, attr)
            if iid is None or req.state is RequestState.FINISHED:
                continue
            if iid in pools.all_ids() and pools.lifecycle_of(iid) in (
                    Lifecycle.WARMING, Lifecycle.FAILED):
                _fail(runtime, iid,
                      f"live rid {rid} ({req.state.value}) points its "
                      f"{attr} at a {pools.lifecycle_of(iid).value} instance")
        if len(handle.tokens) > req.output_len:
            raise AssertionError(
                f"rid {rid} streamed {len(handle.tokens)} tokens > "
                f"output_len {req.output_len}")
        if req.state is RequestState.FINISHED and \
                len(handle.tokens) != req.output_len:
            raise AssertionError(
                f"rid {rid} finished with {len(handle.tokens)} tokens, "
                f"expected {req.output_len}")
        if streams:
            times = ([req.first_token_time] if req.first_token_time
                     is not None else []) + list(req.token_times)
            for a, b in zip(times, times[1:]):
                if (b < a) or (strict and b <= a):
                    raise AssertionError(
                        f"rid {rid} token times not "
                        f"{'strictly ' if strict else ''}ordered: "
                        f"{a} then {b}")

    # ---- prefix cache: pin/doom/retained consistency
    mgr = runtime.prefix_mgr
    if mgr is not None:
        for iid, lru in mgr._lru.items():
            for rid, entry in lru.items():
                if entry.pins < 0:
                    _fail(runtime, iid, f"entry ({iid},{rid}) pins < 0")
                if entry.doomed:
                    if entry.pins == 0:
                        _fail(runtime, iid,
                              f"doomed unpinned entry ({iid},{rid}) not freed")
                    continue              # KV freed on last unpin
                if (iid, rid) not in mgr.index.entries:
                    _fail(runtime, iid,
                          f"live entry ({iid},{rid}) missing from the trie")
                alive = iid in pools.all_ids() and \
                    pools.lifecycle_of(iid) is not Lifecycle.FAILED
                if not alive:
                    _fail(runtime, iid,
                          f"live prefix entry on dead instance ({iid},{rid})")
                got = runtime.local_of(iid).retained.get(rid)
                if got != entry.kv_tokens:
                    _fail(runtime, iid,
                          f"entry ({iid},{rid}) kv {entry.kv_tokens} != "
                          f"scheduler retained {got}")

    # ---- migration bookkeeping counters can never underflow
    for counter, name in ((runtime._kv_outbound, "_kv_outbound"),
                          (runtime._kv_inbound, "_kv_inbound")):
        for iid, v in counter.items():
            if v < 0:
                _fail(runtime, iid, f"{name} negative ({v})")
