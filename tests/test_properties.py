"""Property-based scheduling tests over the runtime core (ISSUE 7).

Random traces + fault/flip/deflection schedules drive the ``arrow_deflect``
simulator end-to-end while asserting, between steps and after drain:

  * the tests/invariants.py structural probe (KV conservation, lifecycle
    vs work, stream ordering, counter sanity),
  * conservation of requests — submitted == finished + rejected with
    nothing left in flight after drain,
  * deflected prefill only ever *lands* on an ACTIVE instance (checked at
    placement time) and is never resident on a WARMING/FAILED one.

Runs under the hypothesis-optional shim (tests/hyp_compat.py): with
hypothesis installed the schedules are drawn and shrunk by the library, and
any minimized failing example is appended to
tests/corpus/deflection_regressions.json; without it the ``@given`` tests
skip cleanly while the checked-in corpus still replays under plain pytest —
so tier-1 executes the harness either way.
"""
import json
import pathlib
import time

import numpy as np
import pytest
from hyp_compat import (HAVE_HYPOTHESIS, corpus_backed, given, settings,
                        st)
from invariants import check_invariants

from repro.configs import get_config
from repro.core import (SLO, DeflectionConfig, HealthConfig, Lifecycle,
                        Pool, Request)
from repro.core.autoscaler import AutoScalerConfig
from repro.sim import Simulator

CORPUS = pathlib.Path(__file__).parent / "corpus" / \
    "deflection_regressions.json"
ASYNC_CORPUS = pathlib.Path(__file__).parent / "corpus" / \
    "async_step_regressions.json"
HEALTH_CORPUS = pathlib.Path(__file__).parent / "corpus" / \
    "health_regressions.json"
CFG = get_config("gemma-2b")


# ------------------------------------------------------------------ harness
def make_trace(rng, n_requests: int, rate: float):
    t, reqs = 0.0, []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        reqs.append(Request(rid=rid, arrival=t,
                            input_len=int(rng.integers(16, 2048)),
                            output_len=int(rng.integers(1, 48))))
    return reqs


def _check_deflected_residency(sim):
    """Deflected prefill work may drain on a RETIRING instance (placed while
    it was ACTIVE) but must never sit on a WARMING or FAILED one."""
    for iid in sim.pools.all_ids():
        loc = sim.locals.get(iid)
        if loc is None:
            continue
        if any(w.deflected for w in loc.prefill_queue.values()):
            life = sim.pools.lifecycle_of(iid)
            assert life not in (Lifecycle.WARMING, Lifecycle.FAILED), \
                f"deflected prefill resident on {life.value} instance {iid}"


def run_schedule(params: dict):
    """Execute one schedule described by a JSON-able ``params`` dict (the
    regression-corpus format); raises AssertionError on any violated
    property. Event steps index the simulator's event loop, so a replayed
    corpus entry fires its faults/flips at the exact same points."""
    rng = np.random.default_rng(params["seed"])
    sim = Simulator(
        CFG, n_instances=4, n_prefill=2, policy="arrow_deflect",
        slo=SLO(params.get("slo_ttft", 2.0), params.get("slo_tpot", 0.2)),
        autoscaler_cfg=AutoScalerConfig(min_instances=2, max_instances=8),
        deflection=DeflectionConfig(ratio=params["ratio"],
                                    watermark=params["watermark"]))

    orig_place = sim.policy.place_prefill

    def place(req, now, prefix_hits=None):
        iid, hit, deflected = orig_place(req, now, prefix_hits=prefix_hits)
        if deflected:
            life = sim.pools.lifecycle_of(iid)
            assert life is Lifecycle.ACTIVE, \
                f"deflected rid {req.rid} landed on {life.value} {iid}"
        return iid, hit, deflected

    sim.policy.place_prefill = place

    for r in make_trace(rng, params["n_requests"], params["rate"]):
        sim.submit(r)

    crash_at = sorted(params.get("crash_steps", []), reverse=True)
    retire_at = sorted(params.get("retire_steps", []), reverse=True)
    scale_at = sorted(params.get("scale_steps", []), reverse=True)
    check_every = params.get("check_every", 64)
    steps = 0
    while sim.step():
        steps += 1
        now = sim.clock.now()
        if crash_at and steps >= crash_at[-1]:
            crash_at.pop()
            active = sim.pools.active_ids()
            if len(active) > 1:          # never strand the whole cluster
                sim.fail_instance(int(rng.choice(active)), now)
        if retire_at and steps >= retire_at[-1]:
            retire_at.pop()
            active = sim.pools.active_ids()
            if len(active) > 2:          # leave evacuation targets
                sim.begin_retire(int(rng.choice(active)), now)
        if scale_at and steps >= scale_at[-1]:
            scale_at.pop()
            sim.scale_up(Pool.PREFILL if steps % 2 else Pool.DECODE, now)
        if steps % check_every == 0:
            check_invariants(sim, streams=False)
            _check_deflected_residency(sim)

    report = sim.drain()
    check_invariants(sim)
    _check_deflected_residency(sim)
    n_fin = sum(1 for h in report.handles if h.done)
    n_rej = sum(1 for h in report.handles if h.rejected)
    assert n_fin + n_rej == len(report.handles), (
        f"request conservation broken: {len(report.handles)} submitted != "
        f"{n_fin} finished + {n_rej} rejected "
        f"({len(report.handles) - n_fin - n_rej} in flight after drain)")
    return report


def _record_regression(params: dict) -> None:
    """Persist a (hypothesis-minimized) failing schedule into the corpus so
    it replays forever under plain pytest."""
    corpus = json.loads(CORPUS.read_text()) if CORPUS.exists() else []
    entry = dict(params)
    entry.setdefault("name", f"minimized-seed{params['seed']}")
    if all(e != entry for e in corpus):
        corpus.append(entry)
        CORPUS.write_text(json.dumps(corpus, indent=2) + "\n")


# ------------------------------------- health chaos schedules (ISSUE 10 §14)
def run_health_schedule(params: dict):
    """Execute one self-healing chaos schedule (the health-corpus format):
    netslow/droptransfer windows and direct quarantines fire at scheduled
    event-step counts while the §14 layer detects, evacuates, retries and
    restores underneath a random trace. Properties: the structural
    invariants hold between steps, requests are conserved through every
    quarantine/retry/preemption interleaving, and no instance is left
    DEGRADED once probation has had a chance to run."""
    rng = np.random.default_rng(params["seed"])
    sim = Simulator(
        CFG, n_instances=4, n_prefill=2, policy="arrow_elastic",
        slo=SLO(params.get("slo_ttft", 2.0), params.get("slo_tpot", 0.2)),
        autoscaler_cfg=AutoScalerConfig(min_instances=2, max_instances=8),
        health=HealthConfig(sustain_s=0.5, probation_s=0.5,
                            xfer_retries=2, xfer_backoff_s=0.05,
                            preemption=True))

    for r in make_trace(rng, params["n_requests"], params["rate"]):
        sim.submit(r)

    slow_at = sorted(params.get("slow_steps", []), reverse=True)
    drop_at = sorted(params.get("drop_steps", []), reverse=True)
    quar_at = sorted(params.get("quarantine_steps", []), reverse=True)
    check_every = params.get("check_every", 64)
    steps = 0
    while sim.step():
        steps += 1
        now = sim.clock.now()
        if slow_at and steps >= slow_at[-1]:
            slow_at.pop()
            sim.apply_netslow(float(rng.uniform(2.0, 8.0)),
                              now + float(rng.uniform(0.1, 1.0)))
        if drop_at and steps >= drop_at[-1]:
            drop_at.pop()
            sim.apply_transfer_drop(float(rng.uniform(0.2, 1.0)),
                                    now + float(rng.uniform(0.1, 1.0)))
        if quar_at and steps >= quar_at[-1]:
            quar_at.pop()
            decs = [i for i in sim.pools.active_ids()
                    if sim.pools.pool_of(i) is Pool.DECODE]
            # keep an evacuation target and never strand the cluster
            if len(sim.pools.active_ids()) > 2 and len(decs) > 1:
                sim.quarantine_instance(int(rng.choice(decs)), now)
        if steps % check_every == 0:
            check_invariants(sim, streams=False)

    report = sim.drain()
    check_invariants(sim)
    # probation may not have ticked since a late quarantine: give the
    # health monitor a few explicit scrapes, then nothing may stay DEGRADED
    for _ in range(5):
        if not sim.pools.degraded_ids():
            break
        sim.collect_stats(sim.clock.now())
    assert not sim.pools.degraded_ids(), (
        f"instances left DEGRADED after drain+probation: "
        f"{sorted(sim.pools.degraded_ids())}")
    n_fin = sum(1 for h in report.handles if h.done)
    n_rej = sum(1 for h in report.handles if h.rejected)
    assert n_fin + n_rej == len(report.handles), (
        f"request conservation broken: {len(report.handles)} submitted != "
        f"{n_fin} finished + {n_rej} rejected "
        f"({len(report.handles) - n_fin - n_rej} in flight after drain)")
    return report


def _record_health_regression(params: dict) -> None:
    corpus = json.loads(HEALTH_CORPUS.read_text()) \
        if HEALTH_CORPUS.exists() else []
    entry = dict(params)
    entry.setdefault("name", f"minimized-seed{params['seed']}")
    if all(e != entry for e in corpus):
        corpus.append(entry)
        HEALTH_CORPUS.write_text(json.dumps(corpus, indent=2) + "\n")


@corpus_backed(HEALTH_CORPUS)
@given(seed=st.integers(0, 2 ** 16),
       n_requests=st.integers(10, 60),
       rate=st.floats(2.0, 200.0),
       slow_steps=st.lists(st.integers(1, 1500), max_size=2),
       drop_steps=st.lists(st.integers(1, 1500), max_size=2),
       quarantine_steps=st.lists(st.integers(1, 1500), max_size=2))
@settings(max_examples=10, deadline=None)
def test_health_chaos_schedules_hold_invariants(seed, n_requests, rate,
                                                slow_steps, drop_steps,
                                                quarantine_steps):
    params = dict(seed=seed, n_requests=n_requests, rate=rate,
                  slow_steps=slow_steps, drop_steps=drop_steps,
                  quarantine_steps=quarantine_steps)
    try:
        run_health_schedule(params)
    except AssertionError:
        _record_health_regression(params)
        raise


def _load_health_corpus():
    return json.loads(HEALTH_CORPUS.read_text())


@pytest.mark.parametrize("params", _load_health_corpus(),
                         ids=lambda p: p.get("name", str(p.get("seed"))))
def test_health_regression_corpus(params):
    run_health_schedule(params)


def test_health_harness_not_vacuous():
    """The chaos harness must actually exercise the §14 layer: a schedule
    with early quarantines and a full-probability drop window produces
    quarantine/restore events and dropped-then-retried transfers, and the
    report carries the health section."""
    report = run_health_schedule(dict(
        seed=11, n_requests=40, rate=200.0,
        quarantine_steps=[40, 200], drop_steps=[30]))
    assert report.health.get("quarantines", 0) >= 1
    assert report.health.get("restores", 0) >= 1


# ----------------------------------------- async engine-step schedules (PR 8)
@pytest.fixture(scope="module")
def engine_env():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("qwen3-1.7b")
    params = build_model(cfg).init(jax.random.PRNGKey(7))
    return cfg, params


def run_async_schedule(cfg, params, sched: dict):
    """Drive the real engine cluster's async step loop under one schedule
    (the async corpus format): ``ready_p`` gates PendingStep.ready with a
    seeded coin so dispatched steps stay in flight across random numbers of
    collect polls, while crashes/retires fire at the scheduled step counts.
    Properties: runtime invariants hold throughout, every request finishes,
    and — the replay guarantee — each sampled stream is bit-identical to a
    sequential single-instance reference, no matter how the async
    interleaving, migrations and recoveries played out."""
    from repro.core import SamplingParams
    from repro.engine import ArrowEngineCluster, EngineInstance
    from repro.engine import instance as inst_mod

    rng = np.random.default_rng(sched["seed"])
    sp = SamplingParams(temperature=sched.get("temperature", 0.8),
                        top_p=0.9)
    n = sched.get("n_requests", 4)
    out_len = sched.get("out_len", 12)
    run_seed = sched.get("run_seed", 0)
    prng = np.random.default_rng(0xA5)
    prompts = {i: prng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
               for i in range(n)}

    cluster = ArrowEngineCluster(
        cfg, n_instances=3, n_prefill=1, n_slots=4, capacity=128,
        slo=SLO(5.0, 2.0), params=params, seed=run_seed,
        speculate=sched.get("speculate", 0))
    handles = [cluster.submit(Request(rid=i, arrival=0.0, input_len=16,
                                      output_len=out_len, sampling=sp),
                              prompt=prompts[i]) for i in range(n)]

    ready_p = sched.get("ready_p", 0.5)
    orig_ready = inst_mod.PendingStep.ready

    def gated_ready(self):
        return orig_ready(self) and bool(rng.random() < ready_p)

    crash_at = sorted(sched.get("crash_steps", []), reverse=True)
    retire_at = sorted(sched.get("retire_steps", []), reverse=True)
    deadline = time.time() + 300.0
    steps = 0
    inst_mod.PendingStep.ready = gated_ready
    try:
        while cluster.step() and time.time() < deadline:
            steps += 1
            now = cluster.clock.now()
            if crash_at and steps >= crash_at[-1]:
                crash_at.pop()
                victims = [i for i in cluster.pools.active_ids()
                           if cluster.pools.pool_of(i) is Pool.DECODE]
                if len(victims) > 1:     # keep the cluster recoverable
                    cluster.fail_instance(int(rng.choice(victims)), now)
            if retire_at and steps >= retire_at[-1]:
                retire_at.pop()
                victims = [i for i in cluster.pools.active_ids()
                           if cluster.pools.pool_of(i) is Pool.DECODE]
                if len(victims) > 1:     # leave an evacuation target
                    cluster.begin_retire(int(rng.choice(victims)), now)
            if steps % 32 == 0:
                check_invariants(cluster, streams=False)
    finally:
        inst_mod.PendingStep.ready = orig_ready
    report = cluster.drain()
    check_invariants(cluster)
    assert report.n_finished == n, \
        f"async schedule lost requests: {report.n_finished}/{n}"
    # Content is schedule-independent (DESIGN.md §12 replay guarantee):
    # whatever the interleaving did, the streams must equal a sequential
    # single-instance run bit-for-bit.
    ref = EngineInstance(99, cfg, params, n_slots=4, capacity=128,
                         run_seed=run_seed)
    for h in handles:
        ref.set_sampling(h.rid, sp)
        got = [ref.run_prefill(h.rid, prompts[h.rid])]
        ref.local.start_local_decode(h.rid, len(prompts[h.rid]), out_len - 1)
        for _ in range(out_len - 1):
            got.append(ref.run_decode_iteration([h.rid])[h.rid])
        assert [int(t) for t in h.tokens] == got, \
            f"rid {h.rid}: async schedule changed the stream"
        ref.drop(h.rid)
    return report


def _record_async_regression(sched: dict) -> None:
    corpus = json.loads(ASYNC_CORPUS.read_text()) \
        if ASYNC_CORPUS.exists() else []
    entry = dict(sched)
    entry.setdefault("name", f"minimized-seed{sched['seed']}")
    if all(e != entry for e in corpus):
        corpus.append(entry)
        ASYNC_CORPUS.write_text(json.dumps(corpus, indent=2) + "\n")


@corpus_backed(ASYNC_CORPUS)
@given(seed=st.integers(0, 2 ** 16),
       ready_p=st.floats(0.05, 1.0),
       speculate=st.sampled_from([0, 0, 4]),
       crash_steps=st.lists(st.integers(1, 400), max_size=1),
       retire_steps=st.lists(st.integers(1, 400), max_size=1))
@settings(max_examples=5, deadline=None)
def test_async_step_schedules_hold_invariants(engine_env, seed, ready_p,
                                              speculate, crash_steps,
                                              retire_steps):
    cfg, params = engine_env
    sched = dict(seed=seed, ready_p=ready_p, speculate=speculate,
                 crash_steps=crash_steps, retire_steps=retire_steps)
    try:
        run_async_schedule(cfg, params, sched)
    except AssertionError:
        _record_async_regression(sched)
        raise


def _load_async_corpus():
    return json.loads(ASYNC_CORPUS.read_text())


@pytest.mark.parametrize("sched", _load_async_corpus(),
                         ids=lambda s: s.get("name", str(s.get("seed"))))
def test_async_step_regression_corpus(engine_env, sched):
    run_async_schedule(*engine_env, sched)


# --------------------------------------------------- property tests (shrunk)
@corpus_backed(CORPUS)
@given(seed=st.integers(0, 2 ** 16),
       n_requests=st.integers(10, 80),
       rate=st.floats(2.0, 400.0),
       slo_ttft=st.floats(0.3, 4.0),
       ratio=st.floats(0.0, 1.0),
       watermark=st.floats(0.0, 1.2),
       crash_steps=st.lists(st.integers(1, 2000), max_size=2),
       retire_steps=st.lists(st.integers(1, 2000), max_size=2),
       scale_steps=st.lists(st.integers(1, 2000), max_size=2))
@settings(max_examples=15, deadline=None)
def test_random_schedules_hold_invariants(seed, n_requests, rate, slo_ttft,
                                          ratio, watermark, crash_steps,
                                          retire_steps, scale_steps):
    params = dict(seed=seed, n_requests=n_requests, rate=rate,
                  slo_ttft=slo_ttft, slo_tpot=slo_ttft / 10.0, ratio=ratio,
                  watermark=watermark, crash_steps=crash_steps,
                  retire_steps=retire_steps, scale_steps=scale_steps)
    try:
        run_schedule(params)
    except AssertionError:
        _record_regression(params)
        raise


# ------------------------------------------- checked-in regression corpus
def _load_corpus():
    return json.loads(CORPUS.read_text())


@pytest.mark.parametrize("params", _load_corpus(),
                         ids=lambda p: p.get("name", str(p.get("seed"))))
def test_regression_corpus(params):
    run_schedule(params)


def test_harness_not_vacuous():
    """The corpus harness must actually exercise deflection: the pressure
    entry deflects requests, and its report carries the §11 section."""
    report = run_schedule(dict(seed=7, n_requests=150, rate=400.0,
                               slo_ttft=0.5, slo_tpot=0.05,
                               ratio=0.25, watermark=0.2))
    assert report.deflection.get("requests_deflected", 0) > 0
    assert report.deflection["chunk_tokens_executed"] > 0


def test_hypothesis_shim_mode():
    """Document which mode this environment ran in (skip bookkeeping: with
    hypothesis absent the @given tests above must be skip-marked with the
    corpus-covered reason — the schedules still replay from the checked-in
    corpora, so the skips are not lost coverage)."""
    if not HAVE_HYPOTHESIS:
        for fn, corpus in (
                (test_random_schedules_hold_invariants, CORPUS),
                (test_async_step_schedules_hold_invariants, ASYNC_CORPUS),
                (test_health_chaos_schedules_hold_invariants,
                 HEALTH_CORPUS)):
            marks = [m for m in getattr(fn, "pytestmark", [])
                     if m.name == "skip"]
            assert marks, f"{fn.__name__} not skip-marked under the shim"
            reason = marks[-1].kwargs.get("reason", "")
            assert "covered by corpus replay" in reason, (
                f"{fn.__name__} skip not tagged corpus-covered: {reason!r}")
            assert corpus.name in reason
            # and the claimed corpus really replays: non-empty + collected
            assert json.loads(corpus.read_text()), \
                f"{corpus.name} is empty — corpus-covered tag is vacuous"
