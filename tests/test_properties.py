"""Property-based scheduling tests over the runtime core (ISSUE 7).

Random traces + fault/flip/deflection schedules drive the ``arrow_deflect``
simulator end-to-end while asserting, between steps and after drain:

  * the tests/invariants.py structural probe (KV conservation, lifecycle
    vs work, stream ordering, counter sanity),
  * conservation of requests — submitted == finished + rejected with
    nothing left in flight after drain,
  * deflected prefill only ever *lands* on an ACTIVE instance (checked at
    placement time) and is never resident on a WARMING/FAILED one.

Runs under the hypothesis-optional shim (tests/hyp_compat.py): with
hypothesis installed the schedules are drawn and shrunk by the library, and
any minimized failing example is appended to
tests/corpus/deflection_regressions.json; without it the ``@given`` tests
skip cleanly while the checked-in corpus still replays under plain pytest —
so tier-1 executes the harness either way.
"""
import json
import pathlib

import numpy as np
import pytest
from hyp_compat import HAVE_HYPOTHESIS, given, settings, st
from invariants import check_invariants

from repro.configs import get_config
from repro.core import SLO, DeflectionConfig, Lifecycle, Pool, Request
from repro.core.autoscaler import AutoScalerConfig
from repro.sim import Simulator

CORPUS = pathlib.Path(__file__).parent / "corpus" / \
    "deflection_regressions.json"
CFG = get_config("gemma-2b")


# ------------------------------------------------------------------ harness
def make_trace(rng, n_requests: int, rate: float):
    t, reqs = 0.0, []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        reqs.append(Request(rid=rid, arrival=t,
                            input_len=int(rng.integers(16, 2048)),
                            output_len=int(rng.integers(1, 48))))
    return reqs


def _check_deflected_residency(sim):
    """Deflected prefill work may drain on a RETIRING instance (placed while
    it was ACTIVE) but must never sit on a WARMING or FAILED one."""
    for iid in sim.pools.all_ids():
        loc = sim.locals.get(iid)
        if loc is None:
            continue
        if any(w.deflected for w in loc.prefill_queue.values()):
            life = sim.pools.lifecycle_of(iid)
            assert life not in (Lifecycle.WARMING, Lifecycle.FAILED), \
                f"deflected prefill resident on {life.value} instance {iid}"


def run_schedule(params: dict):
    """Execute one schedule described by a JSON-able ``params`` dict (the
    regression-corpus format); raises AssertionError on any violated
    property. Event steps index the simulator's event loop, so a replayed
    corpus entry fires its faults/flips at the exact same points."""
    rng = np.random.default_rng(params["seed"])
    sim = Simulator(
        CFG, n_instances=4, n_prefill=2, policy="arrow_deflect",
        slo=SLO(params.get("slo_ttft", 2.0), params.get("slo_tpot", 0.2)),
        autoscaler_cfg=AutoScalerConfig(min_instances=2, max_instances=8),
        deflection=DeflectionConfig(ratio=params["ratio"],
                                    watermark=params["watermark"]))

    orig_place = sim.policy.place_prefill

    def place(req, now, prefix_hits=None):
        iid, hit, deflected = orig_place(req, now, prefix_hits=prefix_hits)
        if deflected:
            life = sim.pools.lifecycle_of(iid)
            assert life is Lifecycle.ACTIVE, \
                f"deflected rid {req.rid} landed on {life.value} {iid}"
        return iid, hit, deflected

    sim.policy.place_prefill = place

    for r in make_trace(rng, params["n_requests"], params["rate"]):
        sim.submit(r)

    crash_at = sorted(params.get("crash_steps", []), reverse=True)
    retire_at = sorted(params.get("retire_steps", []), reverse=True)
    scale_at = sorted(params.get("scale_steps", []), reverse=True)
    check_every = params.get("check_every", 64)
    steps = 0
    while sim.step():
        steps += 1
        now = sim.clock.now()
        if crash_at and steps >= crash_at[-1]:
            crash_at.pop()
            active = sim.pools.active_ids()
            if len(active) > 1:          # never strand the whole cluster
                sim.fail_instance(int(rng.choice(active)), now)
        if retire_at and steps >= retire_at[-1]:
            retire_at.pop()
            active = sim.pools.active_ids()
            if len(active) > 2:          # leave evacuation targets
                sim.begin_retire(int(rng.choice(active)), now)
        if scale_at and steps >= scale_at[-1]:
            scale_at.pop()
            sim.scale_up(Pool.PREFILL if steps % 2 else Pool.DECODE, now)
        if steps % check_every == 0:
            check_invariants(sim, streams=False)
            _check_deflected_residency(sim)

    report = sim.drain()
    check_invariants(sim)
    _check_deflected_residency(sim)
    n_fin = sum(1 for h in report.handles if h.done)
    n_rej = sum(1 for h in report.handles if h.rejected)
    assert n_fin + n_rej == len(report.handles), (
        f"request conservation broken: {len(report.handles)} submitted != "
        f"{n_fin} finished + {n_rej} rejected "
        f"({len(report.handles) - n_fin - n_rej} in flight after drain)")
    return report


def _record_regression(params: dict) -> None:
    """Persist a (hypothesis-minimized) failing schedule into the corpus so
    it replays forever under plain pytest."""
    corpus = json.loads(CORPUS.read_text()) if CORPUS.exists() else []
    entry = dict(params)
    entry.setdefault("name", f"minimized-seed{params['seed']}")
    if all(e != entry for e in corpus):
        corpus.append(entry)
        CORPUS.write_text(json.dumps(corpus, indent=2) + "\n")


# --------------------------------------------------- property tests (shrunk)
@given(seed=st.integers(0, 2 ** 16),
       n_requests=st.integers(10, 80),
       rate=st.floats(2.0, 400.0),
       slo_ttft=st.floats(0.3, 4.0),
       ratio=st.floats(0.0, 1.0),
       watermark=st.floats(0.0, 1.2),
       crash_steps=st.lists(st.integers(1, 2000), max_size=2),
       retire_steps=st.lists(st.integers(1, 2000), max_size=2),
       scale_steps=st.lists(st.integers(1, 2000), max_size=2))
@settings(max_examples=15, deadline=None)
def test_random_schedules_hold_invariants(seed, n_requests, rate, slo_ttft,
                                          ratio, watermark, crash_steps,
                                          retire_steps, scale_steps):
    params = dict(seed=seed, n_requests=n_requests, rate=rate,
                  slo_ttft=slo_ttft, slo_tpot=slo_ttft / 10.0, ratio=ratio,
                  watermark=watermark, crash_steps=crash_steps,
                  retire_steps=retire_steps, scale_steps=scale_steps)
    try:
        run_schedule(params)
    except AssertionError:
        _record_regression(params)
        raise


# ------------------------------------------- checked-in regression corpus
def _load_corpus():
    return json.loads(CORPUS.read_text())


@pytest.mark.parametrize("params", _load_corpus(),
                         ids=lambda p: p.get("name", str(p.get("seed"))))
def test_regression_corpus(params):
    run_schedule(params)


def test_harness_not_vacuous():
    """The corpus harness must actually exercise deflection: the pressure
    entry deflects requests, and its report carries the §11 section."""
    report = run_schedule(dict(seed=7, n_requests=150, rate=400.0,
                               slo_ttft=0.5, slo_tpot=0.05,
                               ratio=0.25, watermark=0.2))
    assert report.deflection.get("requests_deflected", 0) > 0
    assert report.deflection["chunk_tokens_executed"] > 0


def test_hypothesis_shim_mode():
    """Document which mode this environment ran in (skip bookkeeping: with
    hypothesis absent the @given tests above must have been skip-marked)."""
    if not HAVE_HYPOTHESIS:
        fn = test_random_schedules_hold_invariants
        marks = getattr(fn, "pytestmark", [])
        assert any(m.name == "skip" for m in marks)
