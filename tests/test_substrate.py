"""Substrate tests: optimizer, data pipeline, checkpointing, cost model."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data import SyntheticTokenPipeline
from repro.optim import adamw_init, adamw_update
from repro.sim.cost_model import CostModel, InstanceProfile


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(
            lambda q: jnp.sum(jnp.square(q["w"])))(p)
        p, o = adamw_update(p, g, o, lr=0.1, weight_decay=0.0)
        return p, o, loss

    losses = []
    for _ in range(50):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < 0.05 * losses[0]


def test_pipeline_deterministic_and_in_range():
    a = list(zip(range(3), SyntheticTokenPipeline(1000, 32, 2, seed=5)))
    b = list(zip(range(3), SyntheticTokenPipeline(1000, 32, 2, seed=5)))
    for (_, x), (_, y) in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        assert x["tokens"].min() >= 0 and x["tokens"].max() < 1000


def test_checkpoint_roundtrip():
    from repro.models import build_model
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    opt = adamw_init(params)
    tree = {"params": params, "opt": opt}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.msgpack")
        save_checkpoint(path, tree)
        got = load_checkpoint(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- cost model


def test_prefill_cost_superlinear_for_attention_linear_for_ssm():
    dense = CostModel(get_config("gemma-2b"))
    ssm = CostModel(get_config("mamba2-370m"))
    # attention: doubling length more than doubles time at long lengths
    t1, t2 = dense.prefill_time(32768), dense.prefill_time(65536)
    assert t2 > 2.05 * t1
    # ssm: close to linear
    s1, s2 = ssm.prefill_time(32768), ssm.prefill_time(65536)
    assert s2 < 2.2 * s1


def test_decode_cost_linear_in_batch_tokens():
    cm = CostModel(get_config("gemma-2b"))
    t1 = cm.iteration_time([], [1024] * 16)
    t2 = cm.iteration_time([], [1024] * 32)
    assert t2 >= t1


def test_ssm_transfer_constant_in_seq_len():
    """DESIGN.md §4: SSM state transfer is O(1) in sequence length."""
    cm = CostModel(get_config("mamba2-370m"))
    assert cm.transfer_time(1024) == pytest.approx(cm.transfer_time(131072))
    dense = CostModel(get_config("gemma-2b"))
    assert dense.transfer_time(131072) > 10 * dense.transfer_time(1024)


def test_max_running_tokens_monotone_in_tpot():
    cm = CostModel(get_config("gemma-2b"))
    assert cm.max_running_tokens(0.2) >= cm.max_running_tokens(0.05)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(ARCH_IDS), st.integers(4, 15))
def test_cost_model_positive_everywhere(arch, log_len):
    cm = CostModel(get_config(arch), InstanceProfile(chips=4))
    L = 1 << log_len
    assert cm.prefill_time(L) > 0
    assert cm.iteration_time([(0, L)], [L, L // 2]) > 0
    assert cm.kv_capacity_tokens() > 0
